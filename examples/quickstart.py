"""Quickstart — a five-minute tour of the SARA framework.

  PYTHONPATH=src python examples/quickstart.py

1. The RSA cost model reproduces the paper's motivating trade-off (Fig. 3).
2. ADAPTNET learns the configuration space in seconds.
3. The SARA dispatcher picks a TPU tile config per GEMM and runs it through
   the Pallas RSA kernel (interpret mode on CPU).
4. A reduced LM trains a few steps through the full distributed substrate.
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np


def part1_cost_model():
    print("\n=== 1. RSA cost model (paper Fig. 3) ===")
    from repro.core import costmodel as cm
    from repro.core.hw import OS
    from repro.core.rsa import SAGAR_INSTANCE
    M, K, N = 256, 64, 256
    mono = cm.monolithic_cost(M, K, N, 128, 128, OS)
    dist = cm.distributed_cost(M, K, N, 32, 32, 16, OS)
    rsa = cm.oracle_runtime(SAGAR_INSTANCE, [M], [K], [N])[0]
    print(f"monolithic 128x128 : {float(mono.runtime):6.0f} cycles, "
          f"{float(mono.sram_reads):8.0f} reads")
    print(f"distributed 16x32x32: {float(dist.runtime):6.0f} cycles "
          f"({float(mono.runtime/dist.runtime):.2f}x), "
          f"{float(dist.sram_reads):8.0f} reads "
          f"({float(dist.sram_reads/mono.sram_reads):.1f}x)")
    print(f"RSA best config    : {rsa:6.0f} cycles "
          f"({float(mono.runtime)/rsa:.2f}x) at monolithic-level reads")


def part2_adaptnet():
    print("\n=== 2. ADAPTNET learns the config space ===")
    from repro.core import adaptnet as A, dataset as D
    ds = D.generate(30_000, seed=0)
    tr, te = ds.split()
    res = A.train(tr, te, epochs=4, log=False)
    print(f"test accuracy after 4 epochs on 27k samples: "
          f"{res.test_accuracy:.1%} (paper-scale training reaches ~90%+)")


def part3_sara_gemm():
    print("\n=== 3. Self-adaptive GEMM dispatch ===")
    from repro.core.sara import SaraDispatcher
    d = SaraDispatcher(use_pallas=True)
    for (M, K, N) in [(512, 512, 512), (128, 8000, 128)]:
        cfg = d.recommend(M, K, N)
        x = jax.random.normal(jax.random.PRNGKey(0), (M, K))
        w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
        out = d.gemm(x, w)
        err = float(jnp.max(jnp.abs(out - x @ w)))
        print(f"GEMM {M}x{K}x{N}: SARA chose [{cfg.describe()}], "
              f"pallas-vs-xla max err {err:.1e}")


def part4_train():
    print("\n=== 4. Reduced LM through the full training substrate ===")
    from repro.launch.train import train_main
    train_main(arch="llama3.2-1b", steps=15, global_batch=8, seq_len=64,
               checkpoint_dir="/tmp/quickstart_ckpt", log_every=5)


if __name__ == "__main__":
    part1_cost_model()
    part2_adaptnet()
    part3_sara_gemm()
    part4_train()
