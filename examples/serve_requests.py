"""END-TO-END DRIVER (the paper is a GEMM-inference accelerator, so the
e2e deliverable is batched serving): serve a small LM with batched request
waves through the full stack — prefill, KV-cached decode, sampling,
throughput accounting.

  PYTHONPATH=src python examples/serve_requests.py [--waves 3 --batch 8]
"""
import sys, pathlib, argparse
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.configs.registry import get_arch
from repro.launch.serve import serve_waves


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--d-model", type=int, default=256,
                    help="width of the served model (reduced family)")
    ap.add_argument("--layers", type=int, default=4)
    a = ap.parse_args()

    cfg = get_arch(a.arch).reduced().replace(
        d_model=a.d_model, head_dim=a.d_model // 4,
        d_ff=4 * a.d_model, num_layers=a.layers, vocab_size=4096)
    n_params = None
    from repro.models.api import build_model
    n_params = build_model(cfg).num_params()
    print(f"serving {cfg.name} (~{n_params/1e6:.1f}M params), "
          f"{a.waves} waves x {a.batch} requests, "
          f"{a.prompt_len}-token prompts, {a.gen}-token generations")
    outputs, stats = serve_waves(
        override_cfg=cfg, preset="as-is", batch=a.batch,
        prompt_len=a.prompt_len, gen=a.gen, waves=a.waves)
    print(f"served {sum(o.size for o in outputs)} tokens total")


if __name__ == "__main__":
    main()
