"""END-TO-END DRIVER (the paper is a GEMM-inference accelerator, so the
e2e deliverable is serving): serve a mixed-length request trace through the
continuous-batching engine — per-step admission, paged KV pool, SARA-routed
GEMM dispatch, TTFT/latency/throughput telemetry.

  PYTHONPATH=src python examples/serve_requests.py [--requests 12 --slots 4]
"""
import sys, pathlib, argparse
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import numpy as np

from repro.configs.registry import get_arch
from repro.serving import EngineConfig, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--max-gen", type=int, default=32)
    ap.add_argument("--d-model", type=int, default=256,
                    help="width of the served model (reduced family)")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()

    cfg = get_arch(a.arch).reduced().replace(
        d_model=a.d_model, head_dim=a.d_model // 4,
        d_ff=4 * a.d_model, num_layers=a.layers, vocab_size=4096)
    from repro.models.api import build_model
    n_params = build_model(cfg).num_params()

    rng = np.random.default_rng(a.seed)
    reqs = []
    for i in range(a.requests):
        plen = int(rng.integers(8, a.max_prompt + 1))
        gen = int(rng.integers(4, a.max_gen + 1))
        reqs.append(Request(
            rid=f"req-{i}",
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=gen,
            arrival_time=float(i // 2)))      # two arrivals per step
    print(f"serving {cfg.name} (~{n_params/1e6:.1f}M params): "
          f"{a.requests} mixed-length requests "
          f"(prompts 8-{a.max_prompt}, gens 4-{a.max_gen}) "
          f"on {a.slots} slots")

    engine = ServingEngine(cfg, EngineConfig(
        num_slots=a.slots, max_len=a.max_prompt + a.max_gen + 1,
        temperature=a.temperature, top_k=40, seed=a.seed,
        max_prefills_per_step=2))
    outputs = engine.run(reqs)
    total = sum(len(v) for v in outputs.values())
    print(f"served {total} tokens total")
    print(engine.metrics.report(engine.dispatcher.cache_info(),
                                engine.dispatch_stats()))
    print(f"  executed gemm plan (registry-backed, last step):")
    for site, desc in engine.gemm_plan.items():
        print(f"    {site:<24} {desc}")


if __name__ == "__main__":
    main()
