"""Training example: LM through the fault-tolerant distributed substrate.

  PYTHONPATH=src python examples/train_lm.py                  # ~8M, fast
  PYTHONPATH=src python examples/train_lm.py --preset 100m \
      --steps 300                                             # deliverable-
      # scale run (~110M params; hours on 1 CPU core, minutes on a TPU slice)

Includes an optional simulated-preemption demo (--inject-failure) showing
checkpoint-restart keeping the loss trajectory intact.
"""
import sys, pathlib, argparse
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.configs.registry import get_arch
from repro.launch.train import train_main

PRESETS = {
    # name: (d_model, layers, vocab, seq, batch)
    "8m":   (256, 6, 8192, 128, 8),
    "25m":  (512, 8, 8192, 128, 8),
    "100m": (768, 12, 32000, 256, 8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="8m", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--inject-failure", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/train_lm_ckpt")
    a = ap.parse_args()

    d, L, V, S, B = PRESETS[a.preset]
    cfg = get_arch("llama3.2-1b").reduced().replace(
        d_model=d, num_layers=L, vocab_size=V,
        num_heads=8, num_kv_heads=4, head_dim=d // 8, d_ff=4 * d,
        attn_chunk=128, loss_chunk=128)

    fired = []
    injector = None
    if a.inject_failure:
        def injector(step):
            if step == a.steps // 2 and not fired:
                fired.append(step)
                raise RuntimeError("simulated preemption")

    from repro.models.api import build_model
    print(f"training ~{build_model(cfg).num_params()/1e6:.0f}M-param LM "
          f"for {a.steps} steps (seq={S}, batch={B})")
    train_main(override_cfg=cfg, preset="as-is", steps=a.steps,
               global_batch=B, seq_len=S, checkpoint_dir=a.ckpt,
               checkpoint_every=max(10, a.steps // 6),
               log_every=max(1, a.steps // 10),
               fail_injector=injector)


if __name__ == "__main__":
    main()
