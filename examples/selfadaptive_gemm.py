"""Self-adaptive GEMM — the paper's contribution end-to-end on TPU terms.

  PYTHONPATH=src python examples/selfadaptive_gemm.py

For a stream of GEMM workloads (the paper's synthetic Table-IV set):
  1. ADAPTNET-TPU is trained on the tile-config space (once, ~1 min);
  2. each arriving GEMM is recommended a config by the ADAPTNETX Pallas
     kernel (the O(1) in-hardware lookup — no search);
  3. the GEMM executes through the rsa_gemm Pallas kernel with that config;
  4. the analytic cost of the chosen config is compared against exhaustive
     search (oracle) and against a fixed default config.
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tpu_costmodel as tcm
from repro.core import workloads as W
from repro.core.adaptnet import AdaptNetConfig
from repro.core.sara import SaraDispatcher, train_adaptnet_tpu
from repro.kernels import ops


def main():
    print("training ADAPTNET-TPU on the tile-config space ...")
    params, acc, geo = train_adaptnet_tpu(n_samples=60_000, epochs=8)
    print(f"  accuracy={acc:.1%}  geomean rel-time={geo:.4f}\n")

    layers = W.synthetic_g()[:10]
    fixed = tcm.TILE_CONFIGS[tcm.best_tile_config(512, 512, 512)]
    tot_adapt, tot_oracle, tot_fixed = 0.0, 0.0, 0.0
    print(f"{'GEMM':>18} {'ADAPTNETX choice':>28} {'vs oracle':>10} "
          f"{'vs fixed':>9}")
    for l in layers:
        ids = jnp.array([min(l.M, 10000), min(l.K, 10000),
                         min(l.N, 10000)], jnp.int32)
        logits = ops.adaptnetx_recommend(ids, params)   # fused Pallas kernel
        cid = int(jnp.argmax(logits))
        cfg = tcm.TILE_CONFIGS[cid]
        costs = tcm.tile_cost_seconds([l.M], [l.K], [l.N])[0]
        t_adapt, t_oracle = costs[cid], costs.min()
        t_fixed = costs[fixed.class_id]
        tot_adapt += t_adapt; tot_oracle += t_oracle; tot_fixed += t_fixed
        # execute through the Pallas GEMM with the chosen config
        a = jax.random.normal(jax.random.PRNGKey(0), (l.M, l.K), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (l.K, l.N), jnp.float32)
        out = ops.rsa_gemm(a, b, block_m=cfg.block_m, block_n=cfg.block_n,
                           block_k=cfg.block_k, mode=cfg.mode)
        assert out.shape == (l.M, l.N)
        print(f"{l.name:>6} {l.M:>4}x{l.K:>4}x{l.N:>4} "
              f"{cfg.describe():>28} {t_adapt/t_oracle:>9.3f}x "
              f"{t_adapt/t_fixed:>8.3f}x")
    print(f"\ntotals: ADAPTNET within {tot_adapt/tot_oracle:.3f}x of oracle; "
          f"{tot_fixed/tot_adapt:.2f}x faster than a fixed config")


if __name__ == "__main__":
    main()
