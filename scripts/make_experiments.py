"""Generate EXPERIMENTS.md from the result JSONs:

  results/dryrun2/*.json       — 80-cell dry-run + roofline baselines
  results/hillclimb/*.json     — §Perf hypothesis->change->measure logs
  benchmarks/results/*.json    — paper-claim reproductions

Usage:  PYTHONPATH=src python scripts/make_experiments.py > EXPERIMENTS.md
"""
import json
from pathlib import Path

DRY = Path("results/dryrun2")
OPT = Path("results/dryrun_opt")
HC = Path("results/hillclimb")
BR = Path("benchmarks/results")

ARCHS = ["gemma-2b", "deepseek-coder-33b", "llama3.2-1b",
         "command-r-plus-104b", "qwen2-moe-a2.7b", "deepseek-v3-671b",
         "rwkv6-1.6b", "seamless-m4t-medium", "internvl2-76b", "zamba2-7b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

BOTTLENECK_NOTES = {
    "memory": "fuse / restructure loops so working sets fit VMEM "
              "(Pallas kernel), cut recompute, narrow dtypes",
    "collective": "change the sharding layout (TP->ZeRO-3 DP), compress "
                  "gradients, sequence-parallel residuals",
    "compute": "remove remat recompute, causal-skip attention pairs",
}


def load(path):
    return json.loads(path.read_text())


def cells():
    out = {}
    for a in ARCHS:
        for s in SHAPES:
            for m in ("pod1", "pod2"):
                p = DRY / f"{a}__{s}__{m}.json"
                if p.exists():
                    out[(a, s, m)] = load(p)
    return out


def fmt_bytes(b):
    return f"{b / 1e9:.2f} GB"


def main():
    C = cells()
    print("# EXPERIMENTS — SARA / SAGAR reproduction on a JAX+Pallas "
          "multi-pod framework")
    print()
    print("Hardware model: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, "
          "50 GB/s/link ICI, 16 GB HBM, 16 MiB VMEM-credit budget "
          "(`core/hw.py`).  Meshes: single pod `(data=16, model=16)` = 256 "
          "chips; multi-pod `(pod=2, data=16, model=16)` = 512 chips.")
    print()

    # ----------------------------------------------------------------- dry-run
    print("## §Dry-run — 10 archs x 4 shapes x 2 meshes")
    print()
    print("`.lower().compile()` on the CPU backend with 512 forced host")
    print("devices; every cell records compile time, per-device memory")
    print("analysis, trip-weighted HLO FLOPs/bytes, and the parsed")
    print("collective schedule.  `skipped` = long_500k on a full-attention")
    print("arch (architecturally N/A, DESIGN.md §4).")
    print()
    print("| arch | shape | pod1 | pod2 | compile s (pod1/pod2) | "
          "HBM/device pod1 | collectives (pod1) |")
    print("|---|---|---|---|---|---|---|")
    n_ok = n_skip = 0
    for a in ARCHS:
        for s in SHAPES:
            r1, r2 = C.get((a, s, "pod1")), C.get((a, s, "pod2"))
            if r1 is None:
                continue
            st1, st2 = r1["status"], r2["status"] if r2 else "-"
            if st1 == "skipped":
                n_skip += 2
                print(f"| {a} | {s} | skipped | skipped | - | - | - |")
                continue
            n_ok += 2
            mem = fmt_bytes(r1["memory"]["per_device_hbm_bytes"])
            cc = r1["collectives"]["count_by_op"]
            cstr = " ".join(f"{k}:{v}" for k, v in sorted(cc.items()))
            print(f"| {a} | {s} | {st1} | {st2} | "
                  f"{r1['compile_s']}/{r2['compile_s']} | {mem} | {cstr} |")
    print()
    print(f"**{n_ok} cells compile, {n_skip} architecturally-N/A skips, "
          f"0 failures.**  The pod2 pass proves the `pod` axis shards "
          f"(DP over pods; per-device terms halve with 2x chips).")
    print()

    # ----------------------------------------------------------------- roofline
    print("## §Roofline — per-cell terms (single pod, 256 chips)")
    print()
    print("Terms per the assignment: `compute = HLO_FLOPs/(chips*peak)`,")
    print("`memory = HLO_bytes/(chips*HBM_bw)`, `collective =")
    print("collective_bytes/(chips*link_bw)` — all in seconds/step,")
    print("derived from the optimized HLO with the analyzer of")
    print("`launch/hlo_analysis.py` (trip-count-aware; VMEM-credit rule and")
    print("in-place-update handling documented in DESIGN.md §2.2-mm).")
    print("`frac` = MFU-style roofline fraction = time(MODEL_FLOPS at")
    print("peak)/max(term); `mem_att` = compulsory-traffic floor /")
    print("achieved memory term; `useful` = MODEL_FLOPS/HLO_FLOPs")
    print("(recompute/redundancy waste).")
    print()
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL_FLOPS | useful | frac | mem_att |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            r = C.get((a, s, "pod1"))
            if r is None or r["status"] != "ok":
                if r is not None and r["status"] == "skipped":
                    print(f"| {a} | {s} | N/A | N/A | N/A | - | - | - | - "
                          f"| - |")
                continue
            t = r["roofline"]
            print(f"| {a} | {s} | {t['compute_s']:.3f} | {t['memory_s']:.3f}"
                  f" | {t['collective_s']:.3f} | {t['dominant']} | "
                  f"{t['model_flops']:.2e} | {t['useful_flops_ratio']:.2f} | "
                  f"{t['roofline_fraction']:.4f} | "
                  f"{t['memory_attainment']:.4f} |")
    print()
    print("Per-dominant-term lever (applies to every cell with that "
          "bottleneck):")
    for k, v in BOTTLENECK_NOTES.items():
        print(f"- **{k}-bound** -> {v}.")
    print()

    # ----------------------------------------------------------------- perf
    print("## §Perf — hillclimb logs (hypothesis -> change -> measure -> "
          "verdict)")
    print()
    print("Three cells selected per the assignment: worst roofline fraction")
    print("(rwkv6-1.6b x prefill_32k, 0.006 under the first analyzer),")
    print("most collective-bound (qwen2-moe-a2.7b x train_4k), most")
    print("representative of the paper's technique (gemma-2b x train_4k —")
    print("dense GEMM LM; the SARA-TPU recommender's tiling+sharding")
    print("choices are exactly the levers).  Baselines are paper-faithful")
    print("defaults; every variant is a config override (recorded).")
    print()
    for f in sorted(HC.glob("*.json")):
        log = load(f)
        cell = f.stem.replace("__", " x ")
        base = next(e for e in log if e["variant"] == "baseline")
        bt = base["roofline"]
        print(f"### {cell}")
        print()
        print("| variant | hypothesis | compute s | memory s | collective s"
              " | dominant | frac | HBM/dev | verdict |")
        print("|---|---|---|---|---|---|---|---|---|")
        for e in log:
            t = e["roofline"]

            def d(k):
                b = bt[k]
                if e is base or b <= 0:
                    return f"{t[k]:.3f}"
                return f"{t[k]:.3f} ({(t[k] - b) / b * 100:+.0f}%)"

            if e is base:
                verdict = "baseline"
            else:
                dom = bt["dominant"] + "_s"
                rel = (t[dom] - bt[dom]) / bt[dom]
                feas = e["per_device_hbm_bytes"] <= 16e9
                if rel < -0.05 and feas:
                    verdict = "**confirmed**"
                elif not feas:
                    verdict = "refuted (exceeds 16 GB HBM)"
                elif rel > 0.05:
                    verdict = "refuted"
                else:
                    verdict = "neutral (<5%)"
            hyp = e["hypothesis"].replace("|", "/")
            print(f"| {e['variant']} | {hyp} | {d('compute_s')} | "
                  f"{d('memory_s')} | {d('collective_s')} | {t['dominant']} "
                  f"| {t['roofline_fraction']:.4f} | "
                  f"{e['per_device_hbm_bytes'] / 1e9:.1f} GB | {verdict} |")
        print()

    # --------------------------------------------------- optimized sweep
    if OPT.exists() and any(OPT.glob("*.json")):
        print("### Beyond-paper optimized configs — full-arch sweep")
        print()
        print("Per-arch optimized profiles (`configs/registry.py "
              "OPTIMIZED_OVERRIDES`, selected by the hillclimb evidence) "
              "re-swept over train_4k + prefill_32k with `dryrun "
              "--optimized`:")
        print()
        print("| arch | shape | baseline frac | optimized frac | gain | "
              "memory s (base -> opt) | collective s (base -> opt) | "
              "HBM/dev opt |")
        print("|---|---|---|---|---|---|---|---|")
        for a in ARCHS:
            for s in ("train_4k", "prefill_32k"):
                p = OPT / f"{a}__{s}__pod1.json"
                b = C.get((a, s, "pod1"))
                if not p.exists() or b is None or b["status"] != "ok":
                    continue
                o = load(p)
                if o["status"] != "ok":
                    print(f"| {a} | {s} | - | - | - | {o['status']} | - "
                          f"| - |")
                    continue
                bt, ot = b["roofline"], o["roofline"]
                gain = (ot["roofline_fraction"]
                        / max(bt["roofline_fraction"], 1e-9))
                print(f"| {a} | {s} | {bt['roofline_fraction']:.4f} | "
                      f"{ot['roofline_fraction']:.4f} | {gain:.2f}x | "
                      f"{bt['memory_s']:.2f} -> {ot['memory_s']:.2f} | "
                      f"{bt['collective_s']:.2f} -> {ot['collective_s']:.2f}"
                      f" | {o['memory']['per_device_hbm_bytes'] / 1e9:.1f} "
                      f"GB |")
        print()

    # ------------------------------------------------------------ summary
    print("### §Perf summary — paper-faithful baseline vs. beyond-paper "
          "optimized")
    print()
    print("| cell | baseline frac | optimized frac | gain | winning "
          "variant | dominant before -> after |")
    print("|---|---|---|---|---|---|")
    for f in sorted(HC.glob("*.json")):
        log = load(f)
        base = next(e for e in log if e["variant"] == "baseline")
        feas = [e for e in log
                if e["per_device_hbm_bytes"] <= 16e9 or e is base]
        best = max(feas, key=lambda e: e["roofline"]["roofline_fraction"])
        bf = base["roofline"]["roofline_fraction"]
        of = best["roofline"]["roofline_fraction"]
        print(f"| {f.stem.replace('__', ' x ')} | {bf:.4f} | {of:.4f} | "
              f"{of / bf:.1f}x | {best['variant']} | "
              f"{base['roofline']['dominant']} -> "
              f"{best['roofline']['dominant']} |")
    print()
    print("Identified next levers (unimplemented, from the converged "
          "cells' analyses): (i) prefill attends through the cache buffer "
          "with a traced offset, which blocks the flash-kernel route — a "
          "`from_scratch` static fast-path in the prefill stack would let "
          "every big-arch prefill cell take the kernel; (ii) Megatron-SP "
          "(sequence-sharded residuals) / ring-sequential state-passing "
          "for the WKV scan would halve rwkv's TP collective floor; (iii) "
          "int8 error-feedback gradient compression "
          "(`parallel/collectives.py`, implemented + unit-tested) needs a "
          "shard_map manual-DP train-step variant to replace the GSPMD "
          "gradient all-reduce.")
    print()
    print("Measurement notes (documented in DESIGN.md §2.2): (i) the CPU")
    print("XLA backend widens every bf16 dot/reduce chain to f32, inflating")
    print("non-kernel memory/collective bytes by up to 2x — a conservative")
    print("bias applied equally to baseline and optimized variants; (ii)")
    print("interpret-mode Pallas grids re-fetch revisited blocks that a")
    print("real TPU kernel keeps in VMEM across consecutive grid steps")
    print("(~1.4x conservative on kernel q/o traffic); (iii) collective")
    print("all-reduce bytes are counted 2x (reduce+broadcast wire cost).")
    print()

    # ------------------------------------------------------------ validation
    print("## §Paper-claim validation (benchmark harness outputs)")
    print()
    print("Every table/figure of the paper has a benchmark module "
          "(`benchmarks/fig*.py`, one per figure; `python -m "
          "benchmarks.run`).  Key claims vs. this reproduction:")
    print()
    print("| metric | reproduced | paper |")
    print("|---|---|---|")
    for f in sorted(BR.glob("*.json")):
        try:
            data = load(f)
        except Exception:
            continue
        if not isinstance(data, list):
            continue
        for row in data:
            if not isinstance(row, dict) or "name" not in row:
                continue
            name = str(row.get("name", ""))[:70].replace("|", "/")
            val = row.get("value", "")
            der = str(row.get("derived", "") or row.get("note", "")
                      )[:110].replace("|", "/")
            print(f"| {name} | {val} | {der} |")
    print()


if __name__ == "__main__":
    main()
