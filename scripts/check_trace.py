#!/usr/bin/env python
"""CI gate: validate a serve-smoke trace against the obs event schema.

  PYTHONPATH=src python scripts/check_trace.py /tmp/trace.json
  PYTHONPATH=src python scripts/check_trace.py --require-event cache_hit \\
      /tmp/trace.json

Loads the Chrome/Perfetto trace-event JSON written by
``repro.launch.serve --trace-out`` and runs
``repro.obs.validate_trace`` requiring at least one event of every
always-present category (request, step, dispatch, compile, arena —
``fault`` only appears when chaos/containment fired, so it is validated
but not required) — so any PR that
silently drops a whole instrumentation layer fails here, not in a
profiling session weeks later.  ``--require-event NAME`` (repeatable)
additionally demands at least one event with that name — the
prefix-cache smoke uses it to prove ``cache_hit`` instants landed on the
request tracks.  Exits non-zero with the problem list on failure.
"""
import json
import sys


def main() -> int:
    argv, require_events = sys.argv[1:], []
    while "--require-event" in argv:
        i = argv.index("--require-event")
        if i + 1 >= len(argv):
            print(__doc__)
            return 2
        require_events.append(argv[i + 1])
        del argv[i:i + 2]
    if len(argv) != 1:
        print(__doc__)
        return 2
    from repro.obs import REQUIRED_CATEGORIES, validate_trace

    path = argv[0]
    try:
        doc = json.loads(open(path).read())
    except (OSError, ValueError) as e:
        print(f"check_trace: cannot load {path}: {e}")
        return 1
    errs = validate_trace(doc, require_categories=REQUIRED_CATEGORIES)
    names = {e.get("name") for e in doc.get("traceEvents", [])}
    errs += [f"required event {name!r} absent from trace"
             for name in require_events if name not in names]
    if errs:
        print(f"check_trace: {path} FAILED ({len(errs)} problems):")
        for e in errs:
            print(f"  - {e}")
        return 1
    n = len(doc.get("traceEvents", []))
    cats = sorted({e.get("cat") for e in doc["traceEvents"] if e.get("cat")})
    print(f"check_trace: {path} OK — {n} events, categories: {', '.join(cats)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
