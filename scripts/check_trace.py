#!/usr/bin/env python
"""CI gate: validate a serve-smoke trace against the obs event schema.

  PYTHONPATH=src python scripts/check_trace.py /tmp/trace.json

Loads the Chrome/Perfetto trace-event JSON written by
``repro.launch.serve --trace-out`` and runs
``repro.obs.validate_trace`` requiring at least one event of every
category (request, step, dispatch, compile, arena) — so any PR that
silently drops a whole instrumentation layer fails here, not in a
profiling session weeks later.  Exits non-zero with the problem list on
failure.
"""
import json
import sys


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    from repro.obs import CATEGORIES, validate_trace

    path = sys.argv[1]
    try:
        doc = json.loads(open(path).read())
    except (OSError, ValueError) as e:
        print(f"check_trace: cannot load {path}: {e}")
        return 1
    errs = validate_trace(doc, require_categories=CATEGORIES)
    if errs:
        print(f"check_trace: {path} FAILED ({len(errs)} problems):")
        for e in errs:
            print(f"  - {e}")
        return 1
    n = len(doc.get("traceEvents", []))
    cats = sorted({e.get("cat") for e in doc["traceEvents"] if e.get("cat")})
    print(f"check_trace: {path} OK — {n} events, categories: {', '.join(cats)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
