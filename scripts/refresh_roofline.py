"""Refresh derived roofline metrics in stored dry-run JSONs WITHOUT
recompiling: recomputes MODEL_FLOPS (fixed enc-dec decode + SSM terms) and
MODEL_MIN_BYTES from the config, keeps the stored HLO-derived numbers
(flops / bytes / collective bytes), and rewrites the derived ratios.

Usage:  PYTHONPATH=src python scripts/refresh_roofline.py [results/dryrun2]
"""
import json
import sys
from pathlib import Path

from repro.configs.registry import get_arch
from repro.configs.shapes import SHAPES
from repro.launch.hlo_analysis import RooflineTerms
from repro.launch.steps import model_flops_estimate, model_min_bytes_estimate
from repro.models.api import build_model


def main() -> None:
    out_dir = Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun2")
    aval_cache = {}
    for f in sorted(out_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            continue
        cfg = get_arch(rec["arch"])
        shape = SHAPES[rec["shape"]]
        if rec["arch"] not in aval_cache:
            aval_cache[rec["arch"]] = build_model(cfg).init_abstract()
        params_aval = aval_cache[rec["arch"]]
        mf = model_flops_estimate(cfg, params_aval, shape)
        mb = model_min_bytes_estimate(cfg, params_aval, shape)
        old = rec["roofline"]
        terms = RooflineTerms(
            compute_s=old["compute_s"], memory_s=old["memory_s"],
            collective_s=old["collective_s"],
            hlo_flops_global=old["hlo_flops_global"],
            hlo_bytes_global=old["hlo_bytes_global"],
            collective_bytes_global=old["collective_bytes_global"],
            chips=old["chips"], model_flops=mf, model_min_bytes=mb)
        rec["roofline"] = terms.to_dict()
        f.write_text(json.dumps(rec, indent=1))
        print(f"{f.name:60} frac={terms.roofline_fraction:6.3f} "
              f"mem_att={terms.memory_attainment:6.3f} "
              f"bound_att={terms.bound_attainment:6.3f}")


if __name__ == "__main__":
    main()
