#!/usr/bin/env python
"""Docs-consistency gate (wired into scripts/check.sh).

Fails the smoke instead of letting docs rot:

  1. every package under src/repro/ is mentioned in docs/ARCHITECTURE.md
  2. every fenced ``python`` snippet in README.md and docs/*.md parses
     (``ast.parse``), and every fenced ``bash`` snippet passes ``bash -n``
  3. every relative link target referenced from README.md / docs/*.md
     exists

Run directly:  python scripts/check_docs.py
"""

from __future__ import annotations

import ast
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

LINK = re.compile(r"\]\(([^)#]+)(?:#[^)]*)?\)")


def iter_snippets(text: str):
    """Yield (lang, first_line_no, snippet) for each fenced block.

    Any line starting with ``\\`\\`\\``` toggles fence state: outside a
    block it opens one (first word of the info string is the language, so
    ````python copy```` still checks as python); inside, it closes the
    block — mis-pairing would silently skip snippets and invert
    block/prose parsing for the rest of the file."""
    lang, start, buf = None, 0, []
    for i, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            if lang is None:
                info = line.lstrip()[3:].strip()
                lang, start, buf = (info.split()[0] if info else ""), i + 1, []
            else:
                yield lang, start, "\n".join(buf)
                lang = None
        elif lang is not None:
            buf.append(line)


def main() -> int:
    errors = []

    # 1. package coverage in ARCHITECTURE.md
    arch_md = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    packages = sorted(p.name for p in (ROOT / "src" / "repro").iterdir()
                      if p.is_dir() and p.name != "__pycache__"
                      and any(p.glob("*.py")))
    for pkg in packages:
        if f"src/repro/{pkg}/" not in arch_md:
            errors.append(f"docs/ARCHITECTURE.md: package src/repro/{pkg}/ "
                          "is not documented in the module map")

    for doc in DOC_FILES:
        rel = doc.relative_to(ROOT)
        text = doc.read_text()

        # 2. snippets parse
        for lang, line, snippet in iter_snippets(text):
            if lang in ("python", "py"):
                try:
                    ast.parse(snippet)
                except SyntaxError as e:
                    errors.append(f"{rel}:{line}: python snippet does not "
                                  f"parse: {e}")
            elif lang in ("bash", "sh", "shell"):
                r = subprocess.run(["bash", "-n"], input=snippet, text=True,
                                   capture_output=True)
                if r.returncode != 0:
                    errors.append(f"{rel}:{line}: bash snippet does not "
                                  f"parse: {r.stderr.strip()}")

        # 3. relative links resolve
        for target in LINK.findall(text):
            if "://" in target or target.startswith("mailto:"):
                continue
            if not (doc.parent / target).exists() and \
                    not (ROOT / target).exists():
                errors.append(f"{rel}: broken link -> {target}")

    if errors:
        print("docs check FAILED:")
        for e in errors:
            print(f"  {e}")
        return 1
    n_docs = len(DOC_FILES)
    print(f"docs check OK ({len(packages)} packages mapped, "
          f"{n_docs} docs scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
