#!/usr/bin/env bash
# CI gate: tier-1 tests + a fast serving smoke + dispatch-parity smoke.
#   bash scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== serving smoke =="
python -m repro.launch.serve --arch llama3.2-1b --smoke

echo "== dispatch-parity smoke (xla vs pallas per-site plan) =="
python -m benchmarks.bench_gemm_dispatch --smoke

echo "== paged-decode smoke (paged KV engine == dense decode logits) =="
python -m benchmarks.bench_paged_decode --smoke

echo "== self-adaptive smoke (train -> save -> load -> serve adaptnet) =="
ADAPTNET_SMOKE_DIR="$(mktemp -d)/adaptnet_ckpt"
python -m repro.launch.train_adaptnet --samples 8000 --epochs 2 \
    --buckets 64 --out "$ADAPTNET_SMOKE_DIR" --quiet
python -m repro.launch.serve --arch llama3.2-1b --smoke \
    --dispatcher adaptnet --adaptnet-ckpt "$ADAPTNET_SMOKE_DIR"

echo "check.sh: all green"
