#!/usr/bin/env bash
# CI gate: tier-1 tests + fast serving/dispatch/paged/chunked/adaptnet
# smokes + docs-consistency check.
#   bash scripts/check.sh           # tier-1 (-m "not slow") + smokes
#   bash scripts/check.sh --full    # everything, slow markers included
set -euo pipefail
cd "$(dirname "$0")/.."

PYTEST_MARK=(-m "not slow")
if [[ "${1:-}" == "--full" ]]; then
    PYTEST_MARK=()
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== docs consistency (package map + snippet parse + links) =="
python scripts/check_docs.py

echo "== static analysis (saralint contract checks, fail on any finding) =="
python -m repro.analysis src/repro

echo "== tier-1 tests =="
python -m pytest -x -q "${PYTEST_MARK[@]}"

echo "== serving smoke =="
python -m repro.launch.serve --arch llama3.2-1b --smoke

echo "== sanitizer smoke (poison/generation/leak traps stay silent) =="
python -m repro.launch.serve --arch llama3.2-1b --smoke --sanitize \
    --kv-layout paged

echo "== trace smoke (serve --trace-out -> schema + category validation) =="
TRACE_SMOKE="$(mktemp -d)/trace.json"
python -m repro.launch.serve --arch llama3.2-1b --smoke \
    --trace-out "$TRACE_SMOKE"
python scripts/check_trace.py "$TRACE_SMOKE"

echo "== dispatch-parity smoke (xla vs pallas per-site plan) =="
python -m benchmarks.bench_gemm_dispatch --smoke

echo "== paged-decode smoke (paged KV engine == dense decode logits) =="
python -m benchmarks.bench_paged_decode --smoke

echo "== chunked-prefill smoke (chunked paged engine == dense greedy) =="
python -m benchmarks.bench_chunked_prefill --smoke

echo "== prefix-cache smoke (COW page sharing == cache-off greedy) =="
PREFIX_SMOKE="$(mktemp -d)/trace.json"
python -m repro.launch.serve --arch llama3.2-1b --smoke --prefix-cache \
    --trace-out "$PREFIX_SMOKE"
python scripts/check_trace.py --require-event cache_hit "$PREFIX_SMOKE"
python -m benchmarks.bench_prefix_cache --smoke

echo "== spec-decode smoke (speculative == plain greedy, drafts accepted) =="
python -m repro.launch.serve --arch llama3.2-1b --smoke --spec-draft self
python -m benchmarks.bench_spec_decode --smoke

echo "== chaos smoke (faults injected + contained, survivors greedy-equal) =="
CHAOS_SMOKE="$(mktemp -d)/trace.json"
python -m repro.launch.serve --arch llama3.2-1b --smoke --chaos 2 \
    --deadline 40 --trace-out "$CHAOS_SMOKE"
python scripts/check_trace.py --require-event fault "$CHAOS_SMOKE"
python -m benchmarks.bench_chaos_serving --smoke

echo "== self-adaptive smoke (train -> save -> load -> serve adaptnet) =="
ADAPTNET_SMOKE_DIR="$(mktemp -d)/adaptnet_ckpt"
python -m repro.launch.train_adaptnet --samples 8000 --epochs 2 \
    --buckets 64 --out "$ADAPTNET_SMOKE_DIR" --quiet
python -m repro.launch.serve --arch llama3.2-1b --smoke \
    --dispatcher adaptnet --adaptnet-ckpt "$ADAPTNET_SMOKE_DIR"

echo "check.sh: all green"
