#!/usr/bin/env bash
# CI gate: tier-1 tests + a fast serving smoke + dispatch-parity smoke.
#   bash scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== serving smoke =="
python -m repro.launch.serve --arch llama3.2-1b --smoke

echo "== dispatch-parity smoke (xla vs pallas per-site plan) =="
python -m benchmarks.bench_gemm_dispatch --smoke

echo "check.sh: all green"
