"""Cross-request prefix caching on a shared-prefix Poisson trace.

Serves the same request set — a fraction of prompts open with one common
token run (the system-prompt workload prefix caching is for), arrivals
drawn from a Poisson process in virtual step time — through three
engines and reports, per variant:

  * TTFT p50/p99 (virtual steps): a cache hit maps the shared prefix's
    KV pages at admission, so a recipient prefills only its suffix —
    first token lands after one cheap chunk batch instead of the full
    prompt's worth
  * prefill KV rows written into the paged arena: the tentpole claim —
    shared-prefix rows are written once by the first requester and
    refcounted into every later table, so write traffic scales with
    *distinct* tokens, not total tokens
  * prefix-cache telemetry: hit rate, reused pages, analytic prefill
    FLOPs avoided, COW copies, live shared pages
  * greedy parity: cache-on must emit exactly the cache-off tokens
    (page sharing is bitwise — same rows, same physical arena reads)

The cascade variant (``shared_prefix_decode``) additionally batches
decode attention over the group's common physical prefix.  The XLA
reference rebuilds each lane's combined table and runs ONE masked
softmax, so cascade greedy tokens are bitwise the plain tokens and the
bench ASSERTS per-request equality wherever the resolved paged impl is
``xla`` (everywhere off-TPU).  Only the Pallas kernel keeps the
two-phase online-softmax merge — streaming shared pages once per group
is its point — so on TPU the match is reported as a fraction instead.

``--smoke`` is the CI gate: hits > 0, exact greedy parity cache-on vs
cache-off, KV-write reduction > 1.4x on the tiny trace, and a bounded
engine retrace count.
"""

import argparse

import numpy as np

ARCH = "llama3.2-1b"
BLOCK = 16


def _trace(cfg, rng, n, shared_frac, prefix_len, prompt_len, gen,
           mean_gap):
    """``n`` requests; the first ``round(n * shared_frac)`` open with one
    common ``prefix_len``-token run.  Request 0 (the donor) arrives at
    t=0 with a head start of ``2 * mean_gap`` virtual steps so its pages
    are cached before the Poisson tail of recipients lands; later gaps
    are exponential (Poisson arrivals in step time)."""
    from repro.serving import Request

    shared = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    n_shared = int(round(n * shared_frac))
    reqs, t = [], 0.0
    for i in range(n):
        p = rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
        if i < n_shared:
            p[:prefix_len] = shared
        rid = f"{'shared' if i < n_shared else 'uniq'}-{i}"
        reqs.append(Request(rid, p, gen, arrival_time=t))
        t += 2 * mean_gap if i == 0 else float(rng.exponential(mean_gap))
    return reqs


def _serve(cfg, reqs, *, max_len, num_blocks, chunk,
           prefix_cache=False, cascade=False):
    from repro.serving import EngineConfig, ServingEngine
    engine = ServingEngine(cfg, EngineConfig(
        num_slots=4, max_len=max_len, block_size=BLOCK,
        num_blocks=num_blocks, temperature=0.0, kv_layout="paged",
        prefill_chunk=chunk, prefix_cache=prefix_cache,
        shared_prefix_decode=cascade))
    res = engine.run(reqs)
    if engine.prefix_cache is not None:
        engine.prefix_cache.clear()
    engine.pool.check()
    assert engine.pool.num_free == engine.pool.num_blocks
    return res, engine


def run(n: int = 16, shared_frac: float = 0.75, prefix_len: int = 64,
        prompt_len: int = 80, gen: int = 16, chunk: int = 16,
        mean_gap: float = 6.0):
    from benchmarks.common import emit
    from repro.configs.registry import get_arch

    cfg = get_arch(ARCH).reduced()
    max_len = prompt_len + gen + 1
    num_blocks = 4 * (-(-(max_len + 1) // BLOCK)) + 2 * (prefix_len // BLOCK)
    variants = [
        ("cache_off", dict()),
        ("cache_on", dict(prefix_cache=True)),
        ("cache_on_cascade", dict(prefix_cache=True, cascade=True)),
    ]
    rows, outputs, kv_rows = [], {}, {}
    for name, kw in variants:
        reqs = _trace(cfg, np.random.default_rng(0), n, shared_frac,
                      prefix_len, prompt_len, gen, mean_gap)
        res, eng = _serve(cfg, reqs, max_len=max_len,
                          num_blocks=num_blocks, chunk=chunk, **kw)
        outputs[name] = res
        s = eng.summary()
        kv_rows[name] = s["prefill_kv_write_rows"]
        rows += [
            {"name": f"bench_prefix_cache.{name}.ttft_p50_steps",
             "value": round(s["ttft_p50_s"], 3),
             "derived": "virtual step clock"},
            {"name": f"bench_prefix_cache.{name}.ttft_p99_steps",
             "value": round(s["ttft_p99_s"], 3)},
            {"name": f"bench_prefix_cache.{name}.prefill_kv_write_rows",
             "value": s["prefill_kv_write_rows"],
             "derived": "rows committed to the paged arena"},
            {"name": f"bench_prefix_cache.{name}.jit_compiles",
             "value": eng.dispatch_stats()["jit_compiles"]},
        ]
        if "prefix_cache_hit_rate" in s:
            rows += [
                {"name": f"bench_prefix_cache.{name}.hit_rate",
                 "value": round(s["prefix_cache_hit_rate"], 3),
                 "derived": "admissions matching a cached prefix"},
                {"name": f"bench_prefix_cache.{name}.reused_pages",
                 "value": s["prefix_cache_reused_pages"]},
                {"name": f"bench_prefix_cache.{name}.cache_hit_tokens",
                 "value": s["cache_hit_tokens"],
                 "derived": "prompt tokens served from cached pages"},
                {"name": f"bench_prefix_cache.{name}.prefill_flops_saved",
                 "value": float(f"{s['prefill_flops_saved']:.3e}"),
                 "derived": "analytic per-token GEMM cost avoided"},
                {"name": f"bench_prefix_cache.{name}.kv_cow_copies",
                 "value": s["kv_cow_copies"]},
            ]
        if kw.get("cascade"):
            rows.append(
                {"name": f"bench_prefix_cache.{name}.shared_prefix_steps",
                 "value": int(eng.obs.counters.get("shared_prefix_steps",
                                                   0)),
                 "derived": "decode steps batched over a common prefix"})

    # -- cross-variant claims -------------------------------------------------
    reduction = kv_rows["cache_off"] / max(kv_rows["cache_on"], 1)
    assert reduction >= 2.0, \
        f"prefill KV-write reduction {reduction:.2f}x < 2x " \
        f"({kv_rows['cache_off']} vs {kv_rows['cache_on']} rows)"
    off = {k: v for k, v in outputs["cache_off"].items()}
    for rid, toks in off.items():
        np.testing.assert_array_equal(outputs["cache_on"][rid], toks)
    from repro.kernels.ops import default_paged_impl
    if default_paged_impl() == "xla":
        # single-softmax XLA cascade: bitwise parity is a hard claim
        for rid, toks in off.items():
            np.testing.assert_array_equal(outputs["cache_on_cascade"][rid],
                                          toks)
        match = 1.0
        cascade_note = "single masked softmax; asserted bitwise"
    else:
        # Pallas keeps the two-phase online-softmax merge (reassociated)
        match = np.mean([np.array_equal(outputs["cache_on_cascade"][r], t)
                         for r, t in off.items()])
        cascade_note = "pallas two-phase merge; reported, not asserted"
    rows += [
        {"name": "bench_prefix_cache.prefill_kv_write_reduction_x",
         "value": round(reduction, 3),
         "derived": "cache_off rows / cache_on rows (claim: >= 2x)"},
        {"name": "bench_prefix_cache.greedy_parity", "value": 1,
         "derived": "cache_on tokens == cache_off tokens, exactly"},
        {"name": "bench_prefix_cache.cascade_greedy_match_frac",
         "value": round(float(match), 3), "derived": cascade_note},
    ]
    return emit(rows, "bench_prefix_cache",
                config={"n": n, "shared_frac": shared_frac,
                        "prefix_len": prefix_len, "prompt_len": prompt_len,
                        "gen": gen, "chunk": chunk, "mean_gap": mean_gap,
                        "arch": ARCH})


def smoke():
    """CI gate: cache hits happen, greedy tokens are exactly the
    cache-off tokens, KV writes drop, retraces stay bounded."""
    from repro.configs.registry import get_arch

    cfg = get_arch(ARCH).reduced()
    n, prefix_len, prompt_len, gen = 6, 16, 24, 4
    max_len = prompt_len + gen + 1
    kw = dict(max_len=max_len, num_blocks=14, chunk=8)
    reqs = _trace(cfg, np.random.default_rng(0), n, 2 / 3, prefix_len,
                  prompt_len, gen, 4.0)
    res_off, _ = _serve(cfg, reqs, **kw)
    reqs = _trace(cfg, np.random.default_rng(0), n, 2 / 3, prefix_len,
                  prompt_len, gen, 4.0)
    res_on, eng = _serve(cfg, reqs, prefix_cache=True, **kw)
    for rid in res_off:
        np.testing.assert_array_equal(res_on[rid], res_off[rid])
    s = eng.summary()
    assert s["prefix_cache_hits"] > 0, s
    assert s["cache_hit_tokens"] > 0, s
    off_rows = n * prompt_len
    reduction = off_rows / max(s["prefill_kv_write_rows"], 1)
    assert reduction > 1.4, \
        f"reduction {reduction:.2f}x ({s['prefill_kv_write_rows']} rows)"
    compiles = eng.dispatch_stats()["jit_compiles"]
    assert 2 <= compiles <= 16, f"jit_compiles={compiles}"
    print(f"prefix-cache smoke OK (greedy parity, "
          f"{s['prefix_cache_hits']} hits, "
          f"{s['cache_hit_tokens']} cached tokens, "
          f"{reduction:.2f}x fewer KV writes, {compiles} jit compiles)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--shared-frac", type=float, default=0.75)
    ap.add_argument("--prefix-len", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=80)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI parity gate (no sweep)")
    a = ap.parse_args()
    if a.smoke:
        smoke()
        return
    print("name,value,derived")
    run(n=a.n, shared_frac=a.shared_frac, prefix_len=a.prefix_len,
        prompt_len=a.prompt_len, gen=a.gen, chunk=a.chunk)


if __name__ == "__main__":
    main()
