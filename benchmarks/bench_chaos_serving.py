"""Fault-tolerant serving under chaos on a deadline-bearing Poisson trace.

Serves the same request trace — Poisson arrivals in virtual step time,
every request carrying a deadline — twice: fault-free, then with the
seed-driven chaos harness armed (injected pool OOMs, NaN-poisoned KV
pages trapped by the sanitizer, stalled decode lanes, forced mid-prefill
preemptions).  Reports, per variant:

  * goodput: requests completed *within their deadline* per engine step
    — the number load-shedding and fault containment exist to protect
  * the terminal-outcome breakdown (done / failed / expired / shed /
    cancelled): chaos converts some completions into contained failures,
    never into a crashed engine
  * fault telemetry: injections by kind, containments, step retries
  * recovery overhead: engine steps to drain the chaotic trace relative
    to the fault-free run (stalls + re-prefills after preemption)

and asserts the containment contract cross-variant: the chaotic run
terminates every request, and every request that still completed did so
with greedy tokens identical to the fault-free run (a contained fault
must not leak into any other lane's KV state).

``--smoke`` is the CI gate: >= 1 fault injected and contained, zero
uncaught exceptions, survivor greedy parity, pool fully reclaimed.
"""

import argparse

import numpy as np

ARCH = "llama3.2-1b"
BLOCK = 8
OUTCOMES = ("done", "failed", "expired", "shed", "cancelled")


def _trace(cfg, rng, n, prompt_len, gen, mean_gap, deadline):
    from repro.serving import Request

    reqs, t = [], 0.0
    for i in range(n):
        p = rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
        reqs.append(Request(f"req-{i}", p, gen, arrival_time=t,
                            deadline_s=deadline))
        t += float(rng.exponential(mean_gap))
    return reqs


def _serve(cfg, reqs, *, max_len, chunk, chaos=None):
    from repro.serving import EngineConfig, ServingEngine

    engine = ServingEngine(cfg, EngineConfig(
        num_slots=2, max_len=max_len, block_size=BLOCK, temperature=0.0,
        kv_layout="paged", prefill_chunk=chunk, sanitize=True,
        max_prefills_per_step=2, chaos=chaos))
    res = engine.run(reqs)          # the error boundary makes this total:
    engine.pool.check()             # injected faults fail requests, not runs
    assert engine.pool.num_free == engine.pool.num_blocks
    return res, engine


def _chaos(seed):
    from repro.serving import ChaosConfig
    return ChaosConfig(seed=seed, pool_oom_p=0.1, poison_p=0.1,
                       stall_p=0.08, stall_steps=2, preempt_p=0.08)


def run(n: int = 16, prompt_len: int = 24, gen: int = 8, chunk: int = 8,
        mean_gap: float = 2.0, deadline: float = 40.0, seed: int = 2):
    from benchmarks.common import emit
    from repro.configs.registry import get_arch

    cfg = get_arch(ARCH).reduced()
    max_len = prompt_len + gen + 1
    variants = [("fault_free", None), ("chaos", _chaos(seed))]
    rows, outputs, engines = [], {}, {}
    for name, chaos in variants:
        reqs = _trace(cfg, np.random.default_rng(0), n, prompt_len, gen,
                      mean_gap, deadline)
        res, eng = _serve(cfg, reqs, max_len=max_len, chunk=chunk,
                          chaos=chaos)
        outputs[name], engines[name] = res, eng
        s = eng.summary()
        outcomes = {o: sum(1 for r in eng.requests.values()
                           if r.outcome == o) for o in OUTCOMES}
        steps = eng._step_idx
        rows += [
            {"name": f"bench_chaos_serving.{name}.goodput_req_per_step",
             "value": round(s["completed_in_deadline"] / max(steps, 1), 4),
             "derived": "in-deadline completions per engine step"},
            {"name": f"bench_chaos_serving.{name}.completed_in_deadline",
             "value": s["completed_in_deadline"]},
            {"name": f"bench_chaos_serving.{name}.engine_steps",
             "value": steps},
            {"name": f"bench_chaos_serving.{name}.ttft_p50_steps",
             "value": round(s["ttft_p50_s"], 3) if s["ttft_p50_s"]
             is not None else None, "derived": "virtual step clock"},
        ]
        rows += [{"name": f"bench_chaos_serving.{name}.outcome.{o}",
                  "value": c} for o, c in outcomes.items() if c]
        if chaos is not None:
            rows += [
                {"name": "bench_chaos_serving.chaos.faults_injected",
                 "value": s["faults_injected"]},
                {"name": "bench_chaos_serving.chaos.faults_contained",
                 "value": s["faults_contained"],
                 "derived": "attributed faults absorbed by the step "
                            "error boundary"},
                {"name": "bench_chaos_serving.chaos.kv_poison_hits",
                 "value": s["kv_poison_hits"],
                 "derived": "poisoned pages trapped by the sanitizer"},
                {"name": "bench_chaos_serving.chaos.engine_step_retries",
                 "value": s["engine_step_retries"]},
            ]
            rows += [{"name": f"bench_chaos_serving.chaos.{k}", "value": v}
                     for k, v in sorted(s.items())
                     if k.startswith("chaos_") and v]

    # -- cross-variant claims -------------------------------------------------
    eng = engines["chaos"]
    assert all(r.outcome for r in eng.requests.values()), \
        "chaos left a request without a terminal outcome"
    assert eng.summary()["faults_injected"] >= 1
    survivors = [r.rid for r in eng.requests.values() if r.outcome == "done"]
    for rid in survivors:
        np.testing.assert_array_equal(outputs["chaos"][rid],
                                      outputs["fault_free"][rid])
    overhead = (engines["chaos"]._step_idx
                / max(engines["fault_free"]._step_idx, 1))
    rows += [
        {"name": "bench_chaos_serving.recovery_overhead_x",
         "value": round(overhead, 3),
         "derived": "chaos engine steps / fault-free engine steps"},
        {"name": "bench_chaos_serving.survivor_greedy_parity", "value": 1,
         "derived": f"{len(survivors)} surviving requests token-identical "
                    "to the fault-free run"},
    ]
    return emit(rows, "bench_chaos_serving",
                config={"n": n, "prompt_len": prompt_len, "gen": gen,
                        "chunk": chunk, "mean_gap": mean_gap,
                        "deadline": deadline, "seed": seed, "arch": ARCH})


def smoke():
    """CI gate: the chaotic trace finishes with zero uncaught exceptions,
    at least one fault injected *and* contained, survivors greedy-equal
    to the fault-free run, every page reclaimed."""
    from repro.configs.registry import get_arch

    cfg = get_arch(ARCH).reduced()
    n, prompt_len, gen = 5, 12, 5
    kw = dict(max_len=prompt_len + gen + 1, chunk=8)
    reqs = _trace(cfg, np.random.default_rng(0), n, prompt_len, gen,
                  2.0, 40.0)
    res_base, _ = _serve(cfg, reqs, **kw)
    reqs = _trace(cfg, np.random.default_rng(0), n, prompt_len, gen,
                  2.0, 40.0)
    res, eng = _serve(cfg, reqs, chaos=_chaos(2), **kw)
    s = eng.summary()
    assert s["faults_injected"] >= 1, s
    assert s["faults_contained"] >= 1, s
    outcomes = [r.outcome for r in eng.requests.values()]
    assert all(outcomes), outcomes
    survivors = [r.rid for r in eng.requests.values()
                 if r.outcome == "done"]
    for rid in survivors:
        np.testing.assert_array_equal(res[rid], res_base[rid])
    print(f"chaos-serving smoke OK ({int(s['faults_injected'])} injected, "
          f"{int(s['faults_contained'])} contained, "
          f"outcomes={sorted(outcomes)}, greedy parity for "
          f"{len(survivors)} survivors)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--deadline", type=float, default=40.0)
    ap.add_argument("--seed", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI containment gate (no sweep)")
    a = ap.parse_args()
    if a.smoke:
        smoke()
        return
    print("name,value,derived")
    run(n=a.n, prompt_len=a.prompt_len, gen=a.gen, chunk=a.chunk,
        deadline=a.deadline, seed=a.seed)


if __name__ == "__main__":
    main()
