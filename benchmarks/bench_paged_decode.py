"""Paged vs masked-dense decode cost as a function of live-token occupancy.

Fixed slot count and per-slot capacity; sweep the fraction of each slot that
actually holds live tokens (1/16, 1/4, ~1/1) and measure, per decode step:

  * wall clock of the jitted decode entry point (vmapped dense decode_step
    vs the batched paged_decode_step reading K/V through block tables)
  * analytic KV bytes streamed: the dense path touches every slot's full
    ``capacity`` rows per layer; the paged path touches only each lane's
    live pages — the tentpole claim that decode cost scales with live
    tokens, not slot capacity.

``--smoke`` is the CI parity gate: a paged-layout engine must generate
exactly the greedy tokens of a dense-layout engine (and the analytic
reduction at 1/16 occupancy must be >= 4x).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

ARCH = "llama3.2-1b"


def _time_per_step(fn, steps: int) -> float:
    fn()                                   # compile + warm the trace
    t0 = time.perf_counter()
    for _ in range(steps):
        fn()
    return (time.perf_counter() - t0) / steps * 1e3     # ms


def _bench(cfg, model, params, slots, capacity, block_size, live, steps):
    """Returns (dense_ms, paged_ms, dense_rows, paged_rows) per step."""
    dense_step = jax.jit(jax.vmap(model.decode_step, in_axes=(None, 0, 0)))
    paged_step = jax.jit(model.paged_decode_step)
    toks = jnp.zeros((slots, 1), jnp.int32)

    # masked-dense: stacked per-slot caches at `live` of `capacity` tokens
    cache = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (slots,) + a.shape).copy(),
        model.init_cache(1, capacity))
    cache["pos"] = jnp.full((slots,), live, jnp.int32)
    cache["layers"] = cache["layers"]._replace(
        length=jnp.full(cache["layers"].length.shape, live, jnp.int32))
    state = {"cache": cache}

    def dense_fn():
        logits, state["cache"] = dense_step(params, toks[:, :, None],
                                            state["cache"])
        jax.block_until_ready(logits)

    dense_ms = _time_per_step(dense_fn, steps)
    state["cache"] = None                 # free before the arena allocates

    # paged: one contiguous table per lane, width sized like the engine
    # (live pages + headroom for the timed steps, rounded up to pow2)
    from repro.serving import KVBlockPool
    cap_blocks = -(-capacity // block_size)
    arena = model.init_paged_arena(slots * cap_blocks + 1, block_size)
    need = -(-(live + steps + 1) // block_size)
    width = KVBlockPool.table_width(need, cap_blocks)
    tables = np.zeros((slots, width), np.int32)
    for s in range(slots):
        ids = np.arange(s * cap_blocks, s * cap_blocks + width)
        tables[s] = ids
    tables = jnp.asarray(tables)
    wm = jnp.ones((slots,), jnp.int32)
    pstate = {"arena": arena, "kv": np.full((slots,), live, np.int32)}

    def paged_fn():
        logits, pstate["arena"] = paged_step(
            params, toks, {}, pstate["arena"], tables,
            jnp.asarray(pstate["kv"]), wm)
        pstate["kv"] = pstate["kv"] + 1
        jax.block_until_ready(logits)

    paged_ms = _time_per_step(paged_fn, steps)

    dense_rows = slots * capacity
    paged_rows = slots * (-(-(live + 1) // block_size)) * block_size
    return dense_ms, paged_ms, dense_rows, paged_rows


def run(slots: int = 4, capacity: int = 256, block_size: int = 16,
        steps: int = 16):
    from benchmarks.common import emit
    from repro.configs.registry import get_arch
    from repro.models.api import build_model

    cfg = get_arch(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    row_bytes = (2 * cfg.num_kv_heads * cfg.head_dim *
                 jnp.dtype(cfg.compute_dtype).itemsize * cfg.num_layers)

    # full-occupancy case leaves headroom for the timed steps themselves
    cases = [("1_16", max(1, capacity // 16)), ("1_4", max(1, capacity // 4)),
             ("1_1", max(1, capacity - steps - 2))]
    rows = []
    for label, live in cases:
        d_ms, p_ms, d_rows, p_rows = _bench(
            cfg, model, params, slots, capacity, block_size, live, steps)
        red = d_rows / p_rows
        rows += [
            {"name": f"bench_paged_decode.occ_{label}.dense_step_ms",
             "value": round(d_ms, 3)},
            {"name": f"bench_paged_decode.occ_{label}.paged_step_ms",
             "value": round(p_ms, 3)},
            {"name": f"bench_paged_decode.occ_{label}.dense_kv_bytes",
             "value": d_rows * row_bytes,
             "derived": f"{slots} slots x {capacity} rows"},
            {"name": f"bench_paged_decode.occ_{label}.paged_kv_bytes",
             "value": p_rows * row_bytes,
             "derived": f"live={live} block={block_size}"},
            {"name": f"bench_paged_decode.occ_{label}.kv_read_reduction_x",
             "value": round(red, 2)},
            {"name": f"bench_paged_decode.occ_{label}.wallclock_ratio",
             "value": round(d_ms / max(p_ms, 1e-9), 3),
             "derived": "dense_ms / paged_ms (>1 = paged faster)"},
        ]
    return emit(rows, "bench_paged_decode",
                config={"slots": slots, "capacity": capacity,
                        "block_size": block_size, "steps": steps})


def smoke():
    """CI gate: paged engine == dense engine greedy, and the analytic
    KV-traffic win is visible in the engine's own metrics."""
    from repro.configs.registry import get_arch
    from repro.serving import EngineConfig, Request, ServingEngine

    cfg = get_arch(ARCH).reduced()
    rng = np.random.default_rng(0)
    plens = [7, 8, 9]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in plens]

    def serve(layout):
        eng = ServingEngine(cfg, EngineConfig(
            num_slots=2, max_len=64, block_size=4, temperature=0.0,
            max_prefills_per_step=2, kv_layout=layout))
        res = eng.run([Request(f"r{i}", p, 5)
                       for i, p in enumerate(prompts)])
        eng.pool.check()
        assert eng.pool.num_free == eng.pool.num_blocks
        return res, eng.summary()

    res_p, sum_p = serve("paged")
    res_d, _ = serve("dense")
    for rid in res_d:
        np.testing.assert_array_equal(res_p[rid], res_d[rid])
    # 64-token slots holding <= 14 live tokens: the paged read must be a
    # small fraction of the dense equivalent (>= 4x at ~1/5 occupancy;
    # the 1/16 sweep point in run() is proportionally larger)
    assert sum_p["kv_read_reduction_x"] >= 4.0, sum_p
    print(f"paged-decode smoke OK (greedy parity, "
          f"kv read reduction {sum_p['kv_read_reduction_x']:.1f}x)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI parity gate (no sweep)")
    a = ap.parse_args()
    if a.smoke:
        smoke()
        return
    print("name,value,derived")
    run(slots=a.slots, capacity=a.capacity, block_size=a.block_size,
        steps=a.steps)


if __name__ == "__main__":
    main()
