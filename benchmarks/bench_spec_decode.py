"""Speculative decoding on the paged arena: parity + speedup claims.

Serves one Poisson arrival trace (mixed prompt lengths and generation
budgets — the shape-diverse workload the paper motivates) twice through
the continuous-batching engine:

  plain   chunked prefill + paged greedy decode, one token per step
  spec    the same engine with ``spec_draft="self"``: a draft model
          drafts K tokens per lane per step, one target verify pass
          scores all K+1 rows through the ragged chunked-prefill path,
          and the longest matching prefix plus the corrected token
          commit together

and asserts the two claims that make speculation shippable:

  * greedy parity — every committed token is a target verify argmax, so
    the spec run's tokens are BITWISE the plain run's tokens (asserted
    per request, not sampled)
  * progress — accepted tokens per spec step > 1.0, and end-to-end
    decode throughput at least matches plain decode (self-speculation
    accepts most drafts, so each verify step commits multiple tokens
    for roughly one step's latency)

Reported per variant: decode steps, wall-clock decode tok/s, TTFT /
latency percentiles, and for spec the draft/accept telemetry
(drafted, accepted, bonus tokens, accept rate, accepted/step, draft
preempts).

``--smoke`` is the CI gate: tiny trace, parity asserted, >= 1 accepted
draft token, accepted/step > 1.0.

CPU note: reduced preset, XLA paged kernels (no Pallas on this path),
~1 min at defaults.
"""

import argparse
import time

import numpy as np

try:
    from benchmarks.common import emit
except ModuleNotFoundError:  # direct: python benchmarks/bench_spec_decode.py
    import pathlib
    import sys
    _root = pathlib.Path(__file__).parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))
    from benchmarks.common import emit

ARCH = "llama3.2-1b"
BLOCK = 8


def _trace(n, seed=0, rate=0.5, prompt_range=(8, 33), gen_range=(4, 25)):
    """Poisson arrivals (step units) with mixed prompt/gen lengths."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    return [Request(rid=f"r{i}",
                    prompt=rng.integers(1, 500,
                                        int(rng.integers(*prompt_range))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(*gen_range)),
                    arrival_time=float(arrivals[i]))
            for i in range(n)]


def _serve(cfg, reqs, *, max_len, chunk, slots, spec_k=0, warm=None):
    """Serve ``reqs``; with ``warm`` (a small compile-warm-up trace) the
    timed run starts with every jit shape already compiled, so the
    returned tok/s is steady-state end-to-end serving throughput (full
    engine loop: scheduler, prefill, draft, verify, bookkeeping)."""
    from repro.serving import EngineConfig, ServingEngine

    kw = dict(num_slots=slots, max_len=max_len, block_size=BLOCK,
              temperature=0.0, kv_layout="paged", prefill_chunk=chunk,
              max_prefills_per_step=2, seed=0)
    if spec_k:
        kw.update(spec_draft="self", spec_k=spec_k)
    eng = ServingEngine(cfg, EngineConfig(**kw))
    if warm is None:
        res, tok_s = eng.run(reqs), None
    else:
        eng.run(warm())
        # best-of-2: the engine loop is sub-second at bench sizes, so a
        # single timing is at the mercy of machine noise
        dt = float("inf")
        res = None
        for _ in range(2):
            fresh = reqs if res is None else warm()
            t0 = time.perf_counter()
            res = eng.run(fresh)
            dt = min(dt, time.perf_counter() - t0)
        tok_s = sum(len(v) for v in res.values()) / dt
    eng.pool.check()
    assert eng.pool.num_free == eng.pool.num_blocks
    return res, eng.summary(), tok_s


def run(n: int = 16, spec_k: int = 5, chunk: int = 8, slots: int = 4,
        seed: int = 0):
    from repro.configs.registry import get_arch

    cfg = get_arch(ARCH).reduced()
    max_len = 64
    # warm with an identical trace so every jit shape the timed runs hit
    # is already compiled (the timed numbers are steady-state serving)
    warm = lambda: _trace(n, seed)
    plain, s_plain, tps_plain = _serve(
        cfg, _trace(n, seed), max_len=max_len, chunk=chunk, slots=slots,
        warm=warm)
    spec, s_spec, tps_spec = _serve(
        cfg, _trace(n, seed), max_len=max_len, chunk=chunk, slots=slots,
        spec_k=spec_k, warm=warm)

    # claim 1: bitwise greedy parity, every request
    for rid, toks in plain.items():
        np.testing.assert_array_equal(spec[rid], toks)
    # claim 2: speculation makes progress
    aps = s_spec["spec_accepted_per_step"]
    assert aps is not None and aps > 1.0, \
        f"accepted tokens/step {aps} <= 1.0"
    assert tps_spec >= tps_plain, \
        f"spec {tps_spec:.1f} tok/s end-to-end < plain {tps_plain:.1f}"

    rows = []
    for name, s, tps in (("plain", s_plain, tps_plain),
                         ("spec", s_spec, tps_spec)):
        rows.append({"name": f"bench_spec_decode.{name}.e2e_tok_s",
                     "value": round(tps, 1),
                     "derived": "generated tokens / serve wall time, "
                                "compile-warm"})
        for k in ("decode_steps", "decode_tok_s", "ttft_p50_s",
                  "latency_p50_s", "latency_p99_s"):
            rows.append({"name": f"bench_spec_decode.{name}.{k}",
                         "value": round(float(s[k]), 4)})
    rows += [
        {"name": "bench_spec_decode.greedy_parity", "value": 1,
         "derived": "spec tokens == plain tokens, bitwise, per request"},
        {"name": "bench_spec_decode.spec.drafted_tokens",
         "value": s_spec["spec_drafted_tokens"]},
        {"name": "bench_spec_decode.spec.accepted_tokens",
         "value": s_spec["spec_accepted_tokens"]},
        {"name": "bench_spec_decode.spec.bonus_tokens",
         "value": s_spec["spec_bonus_tokens"],
         "derived": "corrected/final-row tokens (one free per verify)"},
        {"name": "bench_spec_decode.spec.accept_rate",
         "value": round(float(s_spec["spec_accept_rate"]), 4),
         "derived": "accepted / drafted"},
        {"name": "bench_spec_decode.spec.accepted_per_step",
         "value": round(float(aps), 4),
         "derived": "committed tokens per verify step (claim: > 1.0)"},
        {"name": "bench_spec_decode.spec.draft_preempts",
         "value": s_spec["spec_draft_preempts"]},
        {"name": "bench_spec_decode.step_reduction",
         "value": round(1.0 - s_spec["decode_steps"]
                        / max(s_plain["decode_steps"], 1), 4),
         "derived": "fewer decode steps vs plain"},
        {"name": "bench_spec_decode.tok_s_speedup_x",
         "value": round(tps_spec / max(tps_plain, 1e-9), 3),
         "derived": "end-to-end; claim: >= 1.0 (one fused draft dispatch"
                    " + one verify replace k+1 decode dispatches)"},
    ]
    return emit(rows, "bench_spec_decode",
                config={"n": n, "spec_k": spec_k, "chunk": chunk,
                        "slots": slots, "seed": seed, "arch": ARCH})


def smoke():
    """CI gate: bitwise parity on a tiny Poisson trace, at least one
    accepted draft token, > 1 committed token per verify step."""
    from repro.configs.registry import get_arch

    cfg = get_arch(ARCH).reduced()
    kw = dict(max_len=40, chunk=8, slots=2)
    plain, _, _ = _serve(cfg, _trace(5, seed=2, prompt_range=(6, 20),
                                     gen_range=(3, 9)), **kw)
    spec, s, _ = _serve(cfg, _trace(5, seed=2, prompt_range=(6, 20),
                                    gen_range=(3, 9)), spec_k=3, **kw)
    for rid in plain:
        np.testing.assert_array_equal(spec[rid], plain[rid])
    assert s["spec_accepted_tokens"] >= 1, s
    assert s["spec_accepted_per_step"] > 1.0, s
    print(f"spec-decode smoke OK (greedy parity, "
          f"{s['spec_accepted_tokens']} accepted draft tokens, "
          f"{s['spec_accepted_per_step']:.2f} committed/step, "
          f"{s['decode_steps']} verify steps)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--spec-k", type=int, default=5)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI parity gate (no sweep)")
    a = ap.parse_args()
    if a.smoke:
        smoke()
        return
    print("name,value,derived")
    run(n=a.n, spec_k=a.spec_k, chunk=a.chunk, slots=a.slots, seed=a.seed)


if __name__ == "__main__":
    main()
