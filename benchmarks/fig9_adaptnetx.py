"""Paper Fig. 9: ADAPTNETX cycles vs systolic-cells + prediction quality."""
import numpy as np

from repro.core import dataset as D
from repro.core.adaptnetx_model import (AdaptNetXDesign, sweep_multipliers)
from repro.core.rsa import SAGAR_INSTANCE, enumerate_configs
from benchmarks.common import emit


def run(shared=None):
    rows = []
    n_classes = len(enumerate_configs(SAGAR_INSTANCE))
    for classes in (n_classes, 858):
        sw = sweep_multipliers(classes)
        best_sc = min(sw["systolic_cells"].items(), key=lambda kv: kv[1])
        best_ax = min(sw["adaptnetx"].items(), key=lambda kv: kv[1])
        rows.append({"name": f"fig9a.systolic_cells_{classes}cls.best_cycles",
                     "value": best_sc[1],
                     "derived": f"at {best_sc[0]} multipliers "
                                f"(paper @858cls: 1134@1024)"})
        rows.append({"name": f"fig9a.adaptnetx_{classes}cls.best_cycles",
                     "value": best_ax[1],
                     "derived": f"at {best_ax[0]} multipliers "
                                f"(paper @858cls: 576@512)"})
    d = AdaptNetXDesign()
    rows.append({"name": "fig9.adaptnetx.model_bytes",
                 "value": d.model_bytes(n_classes),
                 "derived": "fits the 512KB ADAPTNETX SRAM (paper §IV-B)"})
    rows.append({"name": "fig9.adaptnetx.latency_us",
                 "value": round(d.cycles(n_classes) / 1000.0, 3),
                 "derived": "@1GHz; ~6 orders below software search"})
    if shared and "geo" in shared:
        rows.append({"name": "fig9c.relative_performance_geomean",
                     "value": round(100.0 / shared["geo"], 3),
                     "derived": "% of oracle EDP (paper: 99.93% runtime)"})
    return emit(rows, "fig9")
