"""Paper Fig. 12: histogram of favorable array sizes (distributed system)
for synthetic G1-G20 + the three DNN workloads."""
import collections

import numpy as np

from repro.core import costmodel as cm
from repro.core import workloads as W
from repro.core.rsa import SAGAR_INSTANCE, enumerate_configs
from benchmarks.common import emit


def run():
    cfgs = enumerate_configs(SAGAR_INSTANCE)
    rows = []
    for net in ("synthetic", "alphagozero", "deepspeech2", "fasterrcnn"):
        M, K, N = W.layer_dims(W.WORKLOADS[net]())
        best = cm.best_config(SAGAR_INSTANCE, M, K, N, objective="runtime",
                              system=cm.DISTRIBUTED)
        hist = collections.Counter(
            f"{cfgs[b].sub_rows}x{cfgs[b].sub_cols}" for b in best)
        top = ", ".join(f"{k}:{v}" for k, v in hist.most_common(4))
        rows.append({"name": f"fig12.{net}.distinct_best_sizes",
                     "value": len(hist), "derived": top})
    return emit(rows, "fig12")
