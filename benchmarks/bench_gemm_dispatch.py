"""GEMM dispatch bench: executed per-site plan, XLA vs Pallas.

For a sweep of live-token counts m, every GEMM site of the model (the
``gemm_sites`` analytic enumeration) executes through the dispatch layer
under both backends:

  xla     — jnp.einsum (the baseline the parity suite checks against)
  pallas  — the RSA kernel with the SARA-recommended tiling.  Off-TPU this
            runs in interpret mode (a *validation* wall-clock, not a TPU
            number); on TPU the same call compiles.  The analytic column
            (TPU tile cost model) is the roofline-relevant number.

Also reports the recommendation-cache plan hit-rate and the number of
plan reconfigurations across the m sweep (how often the executed plan
actually changes as batch composition shifts — the quantity the serving
engine's ``plan_changes`` tracks).

``--smoke`` runs a tiny sweep and asserts xla/pallas parity per site
(the CI dispatch-parity smoke in scripts/check.sh).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import dispatch
from repro.configs.registry import get_arch
from repro.core import tpu_costmodel as tcm
from repro.core.sara import SaraDispatcher
from repro.dispatch import SiteRegistry
from repro.serving.engine import gemm_sites


def _timed(fn, a, b, reps):
    jax.block_until_ready(fn(a, b))          # warm (compile/trace)
    t0 = time.time()
    for _ in range(reps):
        out = fn(a, b)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def run(smoke: bool = False, arch: str = "llama3.2-1b"):
    rows = []
    cfg = get_arch(arch).reduced()
    disp = SaraDispatcher()
    reg = SiteRegistry()
    m_sweep = (1, 16) if smoke else (1, 16, 64, 256)
    reps = 1 if smoke else 3

    prev_plan, reconfigs = None, 0
    max_err = 0.0
    for m in m_sweep:
        sites = gemm_sites(cfg, m)
        t_backend = {"xla": 0.0, "pallas": 0.0}
        analytic = 0.0
        scope = f"m{m}"
        for name, M, K, N in sites:
            rng = np.random.default_rng(hash((name, m)) % 2 ** 31)
            a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
            b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
            outs = {}
            for backend in ("xla", "pallas"):
                with dispatch.use(disp, execute=backend, registry=reg), \
                        reg.scope(scope if backend == "pallas" else "_ref"):
                    f = jax.jit(lambda x, w, s=name: dispatch.gemm(x, w,
                                                                   site=s))
                    t_backend[backend] += _timed(f, a, b, reps)
                    outs[backend] = np.asarray(f(a, b))
            max_err = max(max_err, float(np.max(np.abs(
                outs["pallas"] - outs["xla"]))))
            c = disp.recommend(M, K, N)
            analytic += float(tcm.tile_cost_seconds([M], [K], [N])
                              [0, c.class_id])
        plan = reg.plan(scope)
        if plan != prev_plan and prev_plan is not None:
            reconfigs += 1
        prev_plan = plan
        rows.append({"name": f"dispatch.m{m}.xla_ms",
                     "value": round(t_backend["xla"] * 1e3, 3),
                     "derived": f"{len(sites)} sites"})
        rows.append({"name": f"dispatch.m{m}.pallas_ms",
                     "value": round(t_backend["pallas"] * 1e3, 3),
                     "derived": "interpret mode off-TPU (validation, "
                                "not a TPU number)"})
        rows.append({"name": f"dispatch.m{m}.analytic_tpu_us",
                     "value": round(analytic * 1e6, 3),
                     "derived": "TPU tile cost model, executed plan"})

    info = disp.cache_info()
    total = info["hits"] + info["misses"]
    rows.append({"name": "dispatch.plan_hit_rate",
                 "value": round(info["hits"] / total, 4) if total else 0.0,
                 "derived": f"{info['size']} distinct shapes"})
    rows.append({"name": "dispatch.reconfigurations",
                 "value": reconfigs,
                 "derived": f"plan changes across m sweep {list(m_sweep)}"})
    rows.append({"name": "dispatch.parity_max_err",
                 "value": max_err, "derived": "pallas vs xla, all sites"})
    if smoke:
        assert max_err < 1e-4, f"dispatch parity broke: {max_err}"
        print(f"# dispatch smoke OK (max err {max_err:.2e})")
    return emit(rows, "gemm_dispatch", config={"arch": arch, "smoke": smoke})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arch", default="llama3.2-1b")
    a = ap.parse_args()
    run(smoke=a.smoke, arch=a.arch)
