"""Paper Fig. 7e: ADAPTNET vs classical classifiers on the RSA config
space (XGBoost/SVC/keras-MLP stand-ins per DESIGN.md §2.1)."""
from repro.core import adaptnet as A
from repro.core import baselines as B
from repro.core import dataset as D
from benchmarks.common import emit, timer

N_SAMPLES = 400_000
EPOCHS = 20


def run(shared=None):
    ds = shared["dataset"] if shared else D.generate(N_SAMPLES, seed=42)
    tr, te = ds.split()
    rows = []
    for fn in (B.logistic_regression, B.knn, B.plain_mlp, B.random_forest):
        r = fn(tr, te)
        rows.append({"name": f"fig7e.{r.name}.accuracy",
                     "value": round(r.accuracy, 4),
                     "derived": f"train_s={r.train_seconds:.1f}"})
    res = shared["adaptnet"] if shared else A.train(tr, te, epochs=EPOCHS,
                                                    log=False)
    rows.append({"name": "fig7e.ADAPTNET.accuracy",
                 "value": round(res.test_accuracy, 4),
                 "derived": f"train_s={res.train_seconds:.1f} "
                            f"(paper: 95% vs XGB 87%)"})
    return emit(rows, "fig7")
