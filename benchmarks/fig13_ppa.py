"""Paper Fig. 13: post-PnR PPA arithmetic (published constants)."""
from repro.core import ppa
from benchmarks.common import emit


def run():
    r = ppa.headline_ratios()
    paper = {"density_vs_distributed": 3.2,
             "power_eff_vs_distributed": 3.5,
             "area_overhead_vs_monolithic": 0.08,
             "power_overhead_vs_monolithic": 0.50,
             "adaptnetx_area_frac": 0.0865,
             "adaptnetx_power_frac": 0.0136,
             "sigma_compute_eq_power_saving": 0.43,
             "sigma_compute_eq_area_saving": 0.30}
    rows = [{"name": f"fig13.{k}", "value": round(v, 4),
             "derived": f"paper={paper[k]}"} for k, v in r.items()]
    rows.append({"name": "fig13.sagar.tops", "value": ppa.SAGAR.tops,
                 "derived": f"area={ppa.SAGAR.area_mm2}mm2 "
                            f"power={ppa.SAGAR.power_w}W @28nm 1GHz"})
    return emit(rows, "fig13")
