"""Paper Fig. 11: runtime/reads/energy/EDP on AlphaGoZero, DeepSpeech2,
FasterRCNN (+ sensitivity nets) for monolithic, distributed, SAGAR."""
import numpy as np

from repro.core import costmodel as cm
from repro.core import workloads as W
from repro.core.rsa import SAGAR_INSTANCE
from benchmarks.common import emit


def _system_costs(M, K, N):
    mono = cm.best_dataflow_cost(
        lambda m, k, n, df: cm.monolithic_cost(m, k, n, 128, 128, df),
        M, K, N)
    dist = cm.best_dataflow_cost(
        lambda m, k, n, df: cm.distributed_cost(m, k, n, 4, 4, 1024, df),
        M, K, N)
    best = cm.best_config(SAGAR_INSTANCE, M, K, N, objective="edp")
    sc = cm.sweep_configs(SAGAR_INSTANCE, M, K, N)
    take = lambda a: np.take_along_axis(a, best[:, None], -1)[:, 0]
    sagar = {"runtime": take(sc.runtime), "sram_reads": take(sc.sram_reads),
             "energy_pj": take(sc.energy_pj), "edp": take(sc.edp)}
    return mono, dist, sagar


def run():
    rows = []
    for net in ("alphagozero", "deepspeech2", "fasterrcnn",
                "resnet50", "bert_base"):
        M, K, N = W.layer_dims(W.WORKLOADS[net]())
        mono, dist, sagar = _system_costs(M, K, N)
        for metric in ("runtime", "sram_reads", "energy_pj", "edp"):
            m, d_, s = (float(x[metric].sum())
                        for x in (mono, dist, sagar))
            rows.append({
                "name": f"fig11.{net}.{metric}.sagar_vs_mono",
                "value": round(s / m, 4),
                "derived": f"sagar_vs_dist={s/d_:.4f} "
                           f"(mono={m:.3e} dist={d_:.3e} sagar={s:.3e})"})
    return emit(rows, "fig11")
