"""Kernel microbench: interpret-mode validation timing + analytic TPU cost
of SARA-chosen tile configs (wall-clock on CPU interpret mode is NOT a TPU
number; the analytic column is the §Roofline-relevant one)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tpu_costmodel as tcm
from repro.core.hw import OS
from repro.core.sara import SaraDispatcher
from repro.kernels import ops, ref
from benchmarks.common import emit


def run():
    rows = []
    d = SaraDispatcher()
    for (M, K, N) in [(512, 512, 512), (2048, 1024, 256), (300, 7000, 120)]:
        cfg = d.recommend(M, K, N)
        t = tcm.tile_cost_seconds([M], [K], [N])[0, cfg.class_id]
        flops = 2 * M * K * N
        rows.append({
            "name": f"kernels.rsa_gemm.{M}x{K}x{N}.analytic_us",
            "value": round(float(t) * 1e6, 3),
            "derived": f"config=({cfg.describe()}) "
                       f"util={flops / (t * 197e12):.2f} of peak"})
        a = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
        out = ops.rsa_gemm(a, b, block_m=cfg.block_m, block_n=cfg.block_n,
                           block_k=cfg.block_k, mode=cfg.mode)
        err = float(jnp.max(jnp.abs(out - ref.rsa_gemm_ref(a, b))))
        rows.append({
            "name": f"kernels.rsa_gemm.{M}x{K}x{N}.interpret_max_err",
            "value": err, "derived": "vs ref.py oracle"})
    # adaptnetx recommendation latency (cycle model) + correctness
    from repro.core.adaptnet import AdaptNetConfig, init_params
    from repro.core.adaptnetx_model import AdaptNetXDesign
    p = init_params(jax.random.PRNGKey(0), AdaptNetConfig(num_classes=108))
    ids = jnp.array([256, 64, 256], jnp.int32)
    lg = ops.adaptnetx_recommend(ids, p)
    gold = ref.adaptnetx_ref(ids, p["emb_m"], p["emb_k"], p["emb_n"],
                             p["w1"], p["b1"], p["w2"], p["b2"])
    rows.append({"name": "kernels.adaptnetx.max_err",
                 "value": float(jnp.max(jnp.abs(lg - gold))),
                 "derived": f"cycles@1GHz={AdaptNetXDesign().cycles(108)}"})
    return emit(rows, "kernels", config={"shapes": "512^3,2048x1024x256,300x7000x120"})
