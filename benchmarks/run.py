"""Benchmark harness: one function per paper table/figure.

Prints ``name,value,derived`` CSV rows; JSON persisted per figure under
benchmarks/results/ (EXPERIMENTS.md cites these).
"""
import time


def main() -> None:
    t0 = time.time()
    from benchmarks import (bench_adaptnet_serving, bench_chunked_prefill,
                            bench_gemm_dispatch, bench_kernels,
                            bench_paged_decode, bench_sara_tpu,
                            bench_serving, fig3_motivation, fig7_classifiers,
                            fig8_adaptnet, fig9_adaptnetx, fig11_workloads,
                            fig12_histograms, fig13_ppa, fig14_sigma,
                            tab2_bandwidth)
    print("name,value,derived")
    fig3_motivation.run()
    tab2_bandwidth.run()
    _, shared = fig8_adaptnet.run()          # trains ADAPTNETs (slowest)
    fig7_classifiers.run(shared)
    fig9_adaptnetx.run(shared)
    fig11_workloads.run()
    fig12_histograms.run()
    fig13_ppa.run()
    fig14_sigma.run()
    bench_kernels.run()
    bench_gemm_dispatch.run()
    bench_sara_tpu.run()
    bench_serving.run()
    bench_paged_decode.run()
    bench_chunked_prefill.run()
    bench_adaptnet_serving.run()
    print(f"# benchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
