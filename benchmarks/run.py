"""Benchmark harness: one function per paper table/figure.

Prints ``name,value,derived`` CSV rows; JSON persisted per figure under
benchmarks/results/ (EXPERIMENTS.md cites these).  Each benchmark also
writes the standardized ``<name>.result.json`` schema
(``{name, config, metrics, suite_rev}`` — see ``benchmarks/common.py``);
``aggregate()`` merges every standardized result into
``results/trajectory.jsonl`` (one line per suite snapshot) so the perf
history of the repo accumulates across revisions instead of being
overwritten in place.

  python -m benchmarks.run               # full suite + aggregate
  python -m benchmarks.run --aggregate   # only merge existing results
"""
import argparse
import json
import time
from pathlib import Path


def aggregate(quiet: bool = False) -> dict:
    """Merge benchmarks/results/*.result.json into one trajectory
    snapshot appended to results/trajectory.jsonl.  Invalid documents
    are reported and skipped, never silently merged."""
    from benchmarks.common import RESULTS_DIR, suite_rev, validate_result

    snapshot = {"record": "suite_snapshot", "suite_rev": suite_rev(),
                "wall_time": time.time(), "results": {}}
    skipped = []
    for path in sorted(RESULTS_DIR.glob("*.result.json")):
        doc = json.loads(path.read_text())
        errs = validate_result(doc)
        if errs:
            skipped.append((path.name, errs))
            continue
        snapshot["results"][doc["name"]] = {"config": doc["config"],
                                            "metrics": doc["metrics"],
                                            "suite_rev": doc["suite_rev"]}
    out = Path(RESULTS_DIR) / "trajectory.jsonl"
    with out.open("a") as f:
        f.write(json.dumps(snapshot) + "\n")
    if not quiet:
        print(f"# trajectory: {len(snapshot['results'])} results "
              f"@ {snapshot['suite_rev']} -> {out}")
        for name, errs in skipped:
            print(f"# trajectory: SKIPPED {name}: {'; '.join(errs)}")
    return snapshot


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--aggregate", action="store_true",
                    help="only merge existing results/*.result.json into "
                         "the trajectory file (no benchmarks run)")
    args = ap.parse_args()
    if args.aggregate:
        aggregate()
        return

    t0 = time.time()
    from benchmarks import (bench_adaptnet_serving, bench_chaos_serving,
                            bench_chunked_prefill,
                            bench_gemm_dispatch, bench_kernels,
                            bench_paged_decode, bench_prefix_cache,
                            bench_sara_tpu, bench_spec_decode,
                            bench_serving, fig3_motivation, fig7_classifiers,
                            fig8_adaptnet, fig9_adaptnetx, fig11_workloads,
                            fig12_histograms, fig13_ppa, fig14_sigma,
                            tab2_bandwidth)
    print("name,value,derived")
    fig3_motivation.run()
    tab2_bandwidth.run()
    _, shared = fig8_adaptnet.run()          # trains ADAPTNETs (slowest)
    fig7_classifiers.run(shared)
    fig9_adaptnetx.run(shared)
    fig11_workloads.run()
    fig12_histograms.run()
    fig13_ppa.run()
    fig14_sigma.run()
    bench_kernels.run()
    bench_gemm_dispatch.run()
    bench_sara_tpu.run()
    bench_serving.run()
    bench_paged_decode.run()
    bench_chunked_prefill.run()
    bench_prefix_cache.run()
    bench_spec_decode.run()
    bench_chaos_serving.run()
    bench_adaptnet_serving.run()
    aggregate()
    print(f"# benchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
