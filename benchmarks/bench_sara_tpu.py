"""Beyond-paper: ADAPTNET-TPU (tile space) + distributed sharding planner."""
import numpy as np

from repro.core import tpu_costmodel as tcm
from repro.core.sara import train_adaptnet_tpu
from benchmarks.common import emit


def run():
    rows = []
    params, acc, geo = train_adaptnet_tpu(n_samples=120_000, epochs=12)
    rows.append({"name": "sara_tpu.adaptnet_tile.accuracy",
                 "value": round(acc, 4),
                 "derived": f"geomean_rel_time={geo:.4f} over "
                            f"{tcm.NUM_TILE_CLASSES} tile classes"})
    for dims in [(8192, 8192, 8192), (4096, 128, 4096), (256, 256, 256),
                 (32768, 4096, 16384)]:
        p = tcm.plan_gemm_sharding(*dims)
        rows.append({"name": f"sara_tpu.shard_plan.{dims[0]}x{dims[1]}x{dims[2]}",
                     "value": p.name,
                     "derived": f"t={p.time_s:.2e}s comm={p.comm_bytes:.2e}B"})
    return emit(rows, "sara_tpu", config={"n_samples": 120_000, "epochs": 12})
