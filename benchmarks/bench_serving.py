"""Wave-based vs continuous-batching serving on a Poisson arrival trace.

Same request set — Poisson arrivals, mixed prompt lengths and generation
budgets — served twice:

  wave        static batching: FCFS waves of `slots` requests; a wave
              prefills together (prompts padded to the wave max) and decodes
              until its LONGEST member finishes, then the next wave starts
  continuous  the ServingEngine: per-step admission into fixed slots, paged
              KV pool, retire-on-finish

Time is accounted in engine steps (1 step = one batched decode invocation,
prefill = 1 step) so the comparison is deterministic and CPU-safe; token
throughputs come from real wall time of the jitted compute.  The wave path
pays the shape-diversity tax the paper motivates: short requests idle their
slot while the longest member keeps decoding.

CPU note: `interpret=True`-safe — everything runs through jitted XLA (no
Pallas kernel is on this path), reduced preset, ~1 min.
"""

import numpy as np

try:
    from benchmarks.common import emit, timer
except ModuleNotFoundError:     # direct: python benchmarks/bench_serving.py
    import pathlib
    import sys
    _root = pathlib.Path(__file__).parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))
    from benchmarks.common import emit, timer


def make_trace(n_requests: int, seed: int = 0, rate: float = 0.5,
               prompt_range=(8, 33), gen_range=(4, 25)):
    """Poisson arrivals (step units) with mixed prompt/gen lengths."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(*prompt_range))
        gen = int(rng.integers(*gen_range))
        reqs.append(Request(
            rid=f"r{i}",
            prompt=rng.integers(0, 512, plen).astype(np.int32),
            max_new_tokens=gen,
            arrival_time=float(arrivals[i])))
    return reqs


def wave_serve(cfg, requests, slots: int, seed: int = 0):
    """Static-batching baseline over an arbitrary request set: FCFS waves,
    wave prompts padded to the wave max, decode until the longest member's
    budget.  Returns step-accounted metrics."""
    import jax
    import jax.numpy as jnp
    from repro.models.api import build_model

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    ttft, latency = [], []
    decode_steps = 0
    decode_tokens = 0
    decode_s = 0.0
    occupancy = []
    clock = 0.0
    reqs = sorted(requests, key=lambda r: r.arrival_time)
    for w0 in range(0, len(reqs), slots):
        wave = reqs[w0:w0 + slots]
        plen = max(r.prompt_len for r in wave)
        gen = max(r.max_new_tokens for r in wave)
        clock = max(clock, max(r.arrival_time for r in wave))

        prompts = np.zeros((len(wave), plen), np.int32)
        for i, r in enumerate(wave):
            prompts[i, :r.prompt_len] = r.prompt
        cache = model.init_cache(len(wave), plen + gen + 1)
        logits, cache = jax.block_until_ready(
            prefill(params, {"tokens": jnp.asarray(prompts)}, cache))
        clock += 1.0                       # prefill = 1 step
        for r in wave:
            ttft.append(clock - r.arrival_time)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        with timer() as t:
            for _ in range(gen - 1):
                logits, cache = decode(params, tok, cache)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            jax.block_until_ready(tok)
        decode_s += t.seconds
        decode_steps += gen - 1
        clock += gen - 1
        for step in range(gen - 1):
            live = sum(r.max_new_tokens > step + 1 for r in wave)
            decode_tokens += live
            occupancy.append(live / slots)
        for r in wave:
            latency.append(clock - r.arrival_time)

    def pct(xs, q):
        return float(np.percentile(xs, q)) if xs else 0.0

    return {
        "decode_steps": decode_steps,
        "ttft_p50_s": pct(ttft, 50), "ttft_p99_s": pct(ttft, 99),
        "latency_p50_s": pct(latency, 50), "latency_p99_s": pct(latency, 99),
        "decode_tok_s": decode_tokens / max(decode_s, 1e-9),
        "slot_utilization": float(np.mean(occupancy)) if occupancy else 0.0,
    }


def run(n_requests: int = 12, slots: int = 4, seed: int = 0):
    from repro.configs.registry import get_arch
    from repro.serving import EngineConfig, ServingEngine

    cfg = get_arch("llama3.2-1b").reduced()
    max_len = 64

    wave = wave_serve(cfg, make_trace(n_requests, seed), slots, seed)

    engine = ServingEngine(cfg, EngineConfig(
        num_slots=slots, max_len=max_len, temperature=0.0, seed=seed,
        max_prefills_per_step=2, clock="steps"))
    engine.run(make_trace(n_requests, seed))
    cont = engine.summary()

    rows = []
    for sched, m in (("wave", wave), ("continuous", cont)):
        for k in ("decode_steps", "ttft_p50_s", "ttft_p99_s",
                  "latency_p50_s", "latency_p99_s", "decode_tok_s",
                  "slot_utilization"):
            rows.append({"name": f"bench_serving.{sched}.{k}",
                         "value": round(float(m[k]), 4)})
    rows.append({"name": "bench_serving.continuous.sara_cache_hit_rate",
                 "value": round(float(cont["sara_cache_hit_rate"]), 4)})
    rows.append({"name": "bench_serving.step_reduction",
                 "value": round(1.0 - cont["decode_steps"]
                                / max(wave["decode_steps"], 1), 4),
                 "derived": "fewer decode steps vs wave"})
    return emit(rows, "bench_serving",
                config={"n_requests": n_requests, "slots": slots,
                        "seed": seed})


if __name__ == "__main__":
    print("name,value,derived")
    run()
