"""Paper Table II: bypass-link bandwidth requirements per dataflow.

Elements/cycle entering each sub-array edge for OS/WS/IS — the structural
reason every systolic-cell needs a dedicated high-bandwidth bypass link."""
from repro.core.hw import DATAFLOW_NAMES, IS, OS, WS
from benchmarks.common import emit


def run():
    # per R x C sub-array: (horizontal stream, vertical stream) el/cycle
    reqs = {
        OS: ("inputs R/cycle", "weights C/cycle + outputs drain"),
        WS: ("inputs R/cycle", "outputs C/cycle (psums)"),
        IS: ("weights R/cycle", "outputs C/cycle (psums)"),
    }
    rows = []
    for df, (h, v) in reqs.items():
        rows.append({"name": f"tab2.{DATAFLOW_NAMES[df]}.links",
                     "value": 2,
                     "derived": f"hor={h}; ver={v}; both HIGH bandwidth"})
    # SAGAR provisioning: 31 bypass + 1 direct per row/col -> 1024 banks
    rows.append({"name": "tab2.sagar_banks_per_buffer", "value": 1024,
                 "derived": "32 rows x 32 links (Table III)"})
    return emit(rows, "tab2")
