"""Chunked paged prefill vs padded-bucket prefill on a mixed-length trace.

Serves the same ragged request set (short prompts admitted alongside long
ones — the workload the ISSUE's shape-diversity argument is about) through
three engines and reports, per variant:

  * wall-clock TTFT p50 for the short- and long-prompt classes — chunked
    prefill lets a short prompt's first token land after one cheap chunk
    batch instead of waiting behind a long prompt's monolithic padded
    prefill
  * prefill KV rows written into the paged arena vs the padded-bucket
    equivalent (``prefill_kv_write_*`` engine metrics) — the tentpole
    claim that prefill KV traffic scales with real prompt tokens
  * dispatcher shape diversity: distinct (M, K, N) GEMM shapes the SARA
    dispatcher resolved (recommendation-cache size) and distinct executed
    site shapes in the registry, chunking on vs off.  The measurement cuts
    both ways: the bucketed path multiplies shapes (one M per padded
    bucket), while the ragged chunk batch standardizes prefill GEMMs onto
    one M = slots * chunk — the shape diversity moves out of the GEMM
    dimensions (where it costs a compilation each) into the per-row
    lengths the paged kernel masks (where it costs nothing)

``--smoke`` is the CI gate: the chunked engine must generate exactly the
greedy tokens of the dense bucketed engine and its KV-write reduction must
exceed 1x (no bucket padding copies).
"""

import argparse

import numpy as np

ARCH = "llama3.2-1b"
SHORT_MAX = 32                     # prompts <= this count as "short"


def _trace(cfg, rng, n_long, n_short, long_len, short_len):
    """Long prompts first, shorts interleaved behind them — all arrive at
    t=0 so shorts must queue behind longs under FCFS admission."""
    from repro.serving import Request
    reqs = []
    for i in range(n_long):
        p = rng.integers(0, cfg.vocab_size, long_len).astype(np.int32)
        reqs.append(Request(f"long-{i}", p, 8))
    for i in range(n_short):
        n = int(rng.integers(short_len, SHORT_MAX))
        p = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        reqs.append(Request(f"short-{i}", p, 8))
    return reqs


def _shape_diversity(engine):
    """Distinct GEMM shapes seen by the recommendation loop."""
    reg = engine.registry
    executed = {(r.m, r.k, r.n) for sc in reg.scopes()
                for r in reg.sites(sc).values()}
    return {"recommended": engine.dispatcher.cache_info()["size"],
            "executed": len(executed)}


def _serve(cfg, reqs, *, kv_layout, prefill_chunk=None, max_len):
    from repro.serving import EngineConfig, ServingEngine
    engine = ServingEngine(cfg, EngineConfig(
        num_slots=4, max_len=max_len, block_size=16, temperature=0.0,
        max_prefills_per_step=1, clock="wall", kv_layout=kv_layout,
        prefill_chunk=prefill_chunk))
    res = engine.run(reqs)
    engine.pool.check()
    return res, engine


def _ttft_by_class(reqs):
    short = [r.t_first_token - r.arrival_time for r in reqs
             if r.rid.startswith("short")]
    long_ = [r.t_first_token - r.arrival_time for r in reqs
             if r.rid.startswith("long")]
    return (float(np.median(short)) if short else 0.0,
            float(np.median(long_)) if long_ else 0.0)


def run(n_long: int = 2, n_short: int = 6, long_len: int = 384,
        short_len: int = 8, chunk: int = 64):
    from benchmarks.common import emit
    from repro.configs.registry import get_arch

    cfg = get_arch(ARCH).reduced()
    max_len = long_len + 16
    rng = np.random.default_rng(0)
    variants = [
        ("bucketed_dense", dict(kv_layout="dense")),
        ("bucketed_paged", dict(kv_layout="paged")),
        ("chunked_paged", dict(kv_layout="paged", prefill_chunk=chunk)),
    ]
    rows, outputs = [], {}
    for name, kw in variants:
        reqs = _trace(get_arch(ARCH).reduced(), np.random.default_rng(0),
                      n_long, n_short, long_len, short_len)
        res, eng = _serve(cfg, reqs, max_len=max_len, **kw)
        outputs[name] = res
        s = eng.summary()
        ttft_short, ttft_long = _ttft_by_class(reqs)
        div = _shape_diversity(eng)
        rows += [
            {"name": f"bench_chunked_prefill.{name}.ttft_short_p50_s",
             "value": round(ttft_short, 4),
             "derived": f"{n_short} prompts <= {SHORT_MAX} tok"},
            {"name": f"bench_chunked_prefill.{name}.ttft_long_p50_s",
             "value": round(ttft_long, 4),
             "derived": f"{n_long} prompts of {long_len} tok"},
            {"name": f"bench_chunked_prefill.{name}.prefill_tok_s",
             "value": round(s["prefill_tok_s"], 1)},
            {"name": f"bench_chunked_prefill.{name}.prefill_kv_write_rows",
             "value": s["prefill_kv_write_rows"],
             "derived": "rows committed to the paged arena"},
            {"name": f"bench_chunked_prefill.{name}."
                     f"prefill_kv_write_rows_padded",
             "value": s["prefill_kv_write_rows_padded"],
             "derived": "padded-bucket equivalent"},
            {"name": f"bench_chunked_prefill.{name}."
                     f"prefill_kv_write_reduction_x",
             "value": round(s["prefill_kv_write_reduction_x"], 3)},
            {"name": f"bench_chunked_prefill.{name}.gemm_shapes_recommended",
             "value": div["recommended"],
             "derived": "distinct (M,K,N) through the dispatcher"},
            {"name": f"bench_chunked_prefill.{name}.gemm_shapes_executed",
             "value": div["executed"],
             "derived": "distinct (M,K,N) in the site registry"},
            {"name": f"bench_chunked_prefill.{name}.jit_compiles",
             "value": eng.dispatch_stats()["jit_compiles"],
             "derived": "engine-level retraces (JitWatch counter)"},
        ]
    # greedy parity across all three variants rides along with the numbers
    for name in ("bucketed_paged", "chunked_paged"):
        for rid, toks in outputs["bucketed_dense"].items():
            np.testing.assert_array_equal(outputs[name][rid], toks)
    rows.append({"name": "bench_chunked_prefill.greedy_parity", "value": 1,
                 "derived": "all variants emit identical tokens"})
    return emit(rows, "bench_chunked_prefill",
                config={"n_long": n_long, "n_short": n_short,
                        "long_len": long_len, "short_len": short_len,
                        "chunk": chunk, "arch": ARCH})


def smoke():
    """CI gate: chunked == dense greedy on a mixed trace + KV-write rows
    scale with real prompt tokens."""
    from repro.configs.registry import get_arch
    from repro.serving import Request

    cfg = get_arch(ARCH).reduced()
    rng = np.random.default_rng(0)
    plens = [40, 7, 12, 3]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in plens]
    reqs_c = [Request(f"r{i}", p, 5) for i, p in enumerate(prompts)]
    res_c, eng_c = _serve(cfg, reqs_c, kv_layout="paged", prefill_chunk=8,
                          max_len=64)
    reqs_d = [Request(f"r{i}", p, 5) for i, p in enumerate(prompts)]
    res_d, _ = _serve(cfg, reqs_d, kv_layout="dense", max_len=64)
    for rid in res_d:
        np.testing.assert_array_equal(res_c[rid], res_d[rid])
    s = eng_c.summary()
    assert s["prefill_kv_write_rows"] == sum(plens), s
    assert s["prefill_kv_write_reduction_x"] > 1.0, s
    # compile accounting (always-on JitWatch counter): a fresh engine must
    # have traced at least chunk-prefill + paged-decode once, and the count
    # must be bounded — chunking standardizes prefill GEMM shapes, so
    # retraces cannot exceed one per engine entry point per width bucket
    compiles = eng_c.dispatch_stats()["jit_compiles"]
    assert 2 <= compiles <= 16, f"jit_compiles={compiles}"
    print(f"chunked-prefill smoke OK (greedy parity, kv writes "
          f"{s['prefill_kv_write_rows']} rows == real prompt tokens, "
          f"{s['prefill_kv_write_reduction_x']:.2f}x under bucketed, "
          f"{compiles} jit compiles)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--long", type=int, default=2)
    ap.add_argument("--short", type=int, default=6)
    ap.add_argument("--long-len", type=int, default=384)
    ap.add_argument("--short-len", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI parity gate (no sweep)")
    a = ap.parse_args()
    if a.smoke:
        smoke()
        return
    print("name,value,derived")
    run(n_long=a.long, n_short=a.short, long_len=a.long_len,
        short_len=a.short_len, chunk=a.chunk)


if __name__ == "__main__":
    main()
