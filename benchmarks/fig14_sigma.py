"""Paper Fig. 14: SAGAR vs SIGMA (compute- and area-normalized, sparsity)."""
import numpy as np

from repro.core import costmodel as cm
from repro.core import sigma
from repro.core import workloads as W
from repro.core.rsa import SAGAR_INSTANCE
from benchmarks.common import emit


def run():
    rows = []
    for net in ("synthetic", "deepspeech2", "alphagozero"):
        M, K, N = W.layer_dims(W.WORKLOADS[net]())
        sag = cm.oracle_runtime(SAGAR_INSTANCE, M, K, N).sum()
        sc = sigma.sigma_c_runtime(M, K, N).sum()
        sa = sigma.sigma_a_runtime(M, K, N).sum()
        rows.append({"name": f"fig14.{net}.sigma_c_vs_sagar",
                     "value": round(float(sc / sag), 4),
                     "derived": "paper: SIGMA_C wins dense (<1)"})
        rows.append({"name": f"fig14.{net}.sigma_a_vs_sagar",
                     "value": round(float(sa / sag), 4),
                     "derived": "paper: ~an order of magnitude slower (>1)"})
    # sparsity crossover (Fig 14d)
    M, K, N = W.layer_dims(W.alphagozero())
    sag = cm.oracle_runtime(SAGAR_INSTANCE, M, K, N).sum()
    cross = None
    for sparsity in np.arange(0.0, 0.96, 0.05):
        sa = sigma.sigma_a_runtime(M, K, N, density=1 - sparsity).sum()
        if sa < sag:
            cross = sparsity
            break
    rows.append({"name": "fig14d.sigma_a_crossover_sparsity",
                 "value": float(cross) if cross is not None else -1,
                 "derived": "paper: SIGMA_A wins only above ~70% sparsity"})
    return emit(rows, "fig14")
