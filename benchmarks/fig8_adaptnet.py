"""Paper Fig. 8: ADAPTNET test accuracy across RSA sizes (2^12..2^14)."""
import numpy as np

from repro.core import adaptnet as A
from repro.core import dataset as D
from repro.core.rsa import make_instance
from benchmarks.common import emit

N_SAMPLES = 400_000
EPOCHS = 20


def run(shared=None):
    rows = []
    out_shared = {}
    for p in (12, 13, 14):
        inst = make_instance(2 ** p)
        if p == 14 and shared and "dataset" in shared:
            ds = shared["dataset"]
        else:
            ds = D.generate(N_SAMPLES, inst=inst, seed=42)
        tr, te = ds.split()
        res = A.train(tr, te, epochs=EPOCHS, log=False)
        pred = A.predict(res.params, te.features)
        geo = D.geomean_relative(inst, te.features, pred, "edp")
        rows.append({
            "name": f"fig8.adaptnet_{ds.num_classes}cls_2^{p}macs.accuracy",
            "value": round(res.test_accuracy, 4),
            "derived": (f"geomean_rel_edp={geo:.5f} "
                        f"({100/geo:.2f}% of oracle; paper: >90% acc, "
                        f"99.93% of oracle)")})
        if p == 14:
            out_shared = {"dataset": ds, "adaptnet": res,
                          "test": te, "geo": geo}
    emit(rows, "fig8")
    return rows, out_shared
