"""Shared benchmark helpers: timing + row emission."""
import json
import time
from pathlib import Path

RESULTS_DIR = Path(__file__).parent / "results"
RESULTS_DIR.mkdir(exist_ok=True)


def emit(rows, name):
    """Print CSV rows (name,value,derived) and persist JSON."""
    for r in rows:
        print(f"{r['name']},{r['value']},{r.get('derived','')}")
    (RESULTS_DIR / f"{name}.json").write_text(json.dumps(rows, indent=1))
    return rows


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
