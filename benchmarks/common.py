"""Shared benchmark helpers: timing, row emission, and the standardized
result schema.

Every benchmark emits two artifacts under ``benchmarks/results/``:

  * ``<name>.json`` — the legacy CSV-mirror row list (kept for
    EXPERIMENTS.md citations);
  * ``<name>.result.json`` — the standardized schema
    ``{name, schema, config, metrics, suite_rev}`` that
    ``benchmarks/run.py --aggregate`` merges into the perf-trajectory
    file (``results/trajectory.jsonl``), so the repo's performance
    history is reconstructable instead of living in commit messages.

``emit(rows, name, config=...)`` writes both: ``metrics`` is derived
from the rows (``{row name: value}``), ``config`` is whatever knobs the
benchmark ran with, and ``suite_rev`` is the git revision (``unknown``
outside a checkout).
"""
import json
import subprocess
import time
from pathlib import Path

RESULTS_DIR = Path(__file__).parent / "results"
RESULTS_DIR.mkdir(exist_ok=True)

SCHEMA_VERSION = 1


def suite_rev() -> str:
    """Short git revision of the benchmark suite (or 'unknown')."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=Path(__file__).parent, capture_output=True, text=True,
            timeout=10)
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def write_result(name: str, metrics: dict, config: dict = None) -> dict:
    """Persist one standardized benchmark result document."""
    doc = {"name": name, "schema": SCHEMA_VERSION,
           "config": config or {}, "metrics": metrics,
           "suite_rev": suite_rev()}
    (RESULTS_DIR / f"{name}.result.json").write_text(
        json.dumps(doc, indent=1))
    return doc


def validate_result(doc) -> list:
    """Schema check for a standardized result document (tests + the
    aggregator use this); returns a list of problems (empty = valid)."""
    errs = []
    if not isinstance(doc, dict):
        return ["result must be an object"]
    for key, typ in (("name", str), ("config", dict), ("metrics", dict),
                     ("suite_rev", str)):
        if not isinstance(doc.get(key), typ):
            errs.append(f"missing or wrong-type field {key!r}")
    for k, v in (doc.get("metrics") or {}).items():
        if not isinstance(v, (int, float, str, type(None))):
            errs.append(f"metric {k!r} is not a scalar")
    return errs


def emit(rows, name, config: dict = None):
    """Print CSV rows (name,value,derived), persist the legacy row JSON,
    and write the standardized ``<name>.result.json``."""
    for r in rows:
        print(f"{r['name']},{r['value']},{r.get('derived','')}")
    (RESULTS_DIR / f"{name}.json").write_text(json.dumps(rows, indent=1))
    write_result(name, {r["name"]: r["value"] for r in rows}, config)
    return rows


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
