"""Self-adaptive serving: trained ADAPTNET-TPU vs oracle dispatcher on a
live continuous-batching trace — the serving-side repro of the paper's
headline number (ADAPTNET replaces exhaustive config search at 99.93% of
best-achievable performance).

The same Poisson request trace (mixed prompt/gen lengths) is served
twice through the ServingEngine, once per recommendation source:

  oracle    SaraDispatcher(mode="oracle"): argmin over the analytic TPU
            tile cost model at every GEMM site (exhaustive search)
  adaptnet  SaraDispatcher(mode="adaptnet"): a trained ADAPTNET-TPU
            (logbucket encoding) recommends every site's tile config in
            O(1); out-of-trained-range shapes fall back to the oracle

The recommender is trained on the serving shape distribution: the
engine's own executed GEMM shapes (harvested from an oracle probe run's
site registry), the full-vocab sites of the registry architectures
(lm_head N up to 256000 — representable only through the logbucket
encoding), and log-uniform background.  Reported:

  decode tok/s under each dispatcher (identical greedy token streams),
  plan agreement rate (executed tile config identical per site),
  geomean analytic tile-cost ratio adaptnet/oracle (the plan-quality
  number; paper: 99.93%), and recommendation-source counts.

CPU-safe (~1-2 min): engine GEMMs run under XLA, training is the tiny
ADAPTNET MLP; the analytic column carries the TPU-relevant comparison.
"""

import numpy as np

try:
    from benchmarks.common import emit
except ModuleNotFoundError:     # direct: python benchmarks/bench_adaptnet_serving.py
    import pathlib
    import sys
    _root = pathlib.Path(__file__).parent.parent
    sys.path.insert(0, str(_root))
    sys.path.insert(0, str(_root / "src"))
    from benchmarks.common import emit

from benchmarks.bench_serving import make_trace


def _serve(cfg, dispatcher, n_requests, slots, seed):
    from repro.serving import EngineConfig, ServingEngine

    engine = ServingEngine(cfg, EngineConfig(
        num_slots=slots, max_len=64, temperature=0.0, seed=seed,
        max_prefills_per_step=2, clock="steps"), dispatcher=dispatcher)
    outputs = engine.run(make_trace(n_requests, seed))
    return engine, outputs


def _executed_records(engine):
    """{(scope, site): SiteRecord} across every traced scope."""
    return {(scope, name): rec
            for scope in engine.registry.scopes()
            for name, rec in engine.registry.sites(scope).items()}


def run(n_requests: int = 12, slots: int = 4, seed: int = 0,
        samples: int = 150_000, epochs: int = 12):
    from repro.configs.registry import get_arch
    from repro.core import tpu_costmodel as tcm
    from repro.core.sara import SaraDispatcher
    from repro.launch.train_adaptnet import (serving_gemm_shapes,
                                             train_serving_adaptnet)

    cfg = get_arch("llama3.2-1b").reduced()

    # -- oracle pass (also the probe that harvests the executed shapes) -----
    oracle_eng, oracle_out = _serve(cfg, SaraDispatcher(), n_requests,
                                    slots, seed)
    oracle_recs = _executed_records(oracle_eng)
    probe_shapes = {(r.m, r.k, r.n) for r in oracle_recs.values()}

    # -- train ADAPTNET-TPU on the serving shape distribution ---------------
    shapes = sorted(set(serving_gemm_shapes()) | probe_shapes)
    params, info = train_serving_adaptnet(samples, epochs, shapes=shapes,
                                          seed=seed, log=False)

    # -- adaptnet pass on the identical trace -------------------------------
    adapt_disp = SaraDispatcher(mode="adaptnet", adaptnet_params=params)
    adapt_eng, adapt_out = _serve(cfg, adapt_disp, n_requests, slots, seed)
    adapt_recs = _executed_records(adapt_eng)

    # greedy decoding must be bit-identical: the dispatcher only changes
    # HOW each GEMM runs, never WHAT it computes
    assert set(adapt_out) == set(oracle_out)
    for rid in oracle_out:
        np.testing.assert_array_equal(adapt_out[rid], oracle_out[rid])

    # -- plan quality: executed agreement + analytic tile-cost ratio --------
    agree, ratios = 0, []
    for key, arec in adapt_recs.items():
        orec = oracle_recs.get(key)
        if orec is None:
            continue
        agree += arec.executed() == orec.executed()
        cost = tcm.tile_cost_seconds([arec.m], [arec.k], [arec.n])[0]
        ratios.append(float(cost[arec.cfg.class_id]
                            / cost[orec.cfg.class_id]))
    total = len(ratios)
    geo = float(np.exp(np.mean(np.log(ratios)))) if ratios else float("nan")
    o_sum, a_sum = oracle_eng.summary(), adapt_eng.summary()
    src = adapt_disp.source_info()

    # large-dim representability probe: llama3.2-1b lm_head at full vocab
    # (raw [0,10^4] encoding would alias this; logbucket represents it)
    M, K, N = 64, 2048, 128256
    probe_cfg = adapt_disp.recommend(M, K, N)
    probe_cost = tcm.tile_cost_seconds([M], [K], [N])[0]
    probe_ratio = float(probe_cost[probe_cfg.class_id] / probe_cost.min())

    rows = [
        {"name": "adaptnet_serving.adaptnet.accuracy",
         "value": round(info["accuracy"], 4),
         "derived": f"{info['samples']} samples, {info['epochs']} epochs, "
                    f"logbucket max_dim={info['max_dim']}"},
        {"name": "adaptnet_serving.oracle.decode_tok_s",
         "value": round(float(o_sum["decode_tok_s"]), 2)},
        {"name": "adaptnet_serving.adaptnet.decode_tok_s",
         "value": round(float(a_sum["decode_tok_s"]), 2),
         "derived": "identical greedy tokens; XLA backend off-TPU"},
        {"name": "adaptnet_serving.plan_agreement_rate",
         "value": round(agree / max(total, 1), 4),
         "derived": f"{agree}/{total} executed (scope,site) records "
                    "with identical tile config"},
        {"name": "adaptnet_serving.geomean_cost_ratio",
         "value": round(geo, 5),
         "derived": "analytic tile cost, adaptnet choice / oracle choice"},
        {"name": "adaptnet_serving.plan_quality_pct",
         "value": round(100.0 / geo, 2),
         "derived": "paper: 99.93% of best-achievable"},
        {"name": "adaptnet_serving.rec_sources",
         "value": f"adaptnet={src['adaptnet']}"
                  f"/fallback={src['oracle_fallback']}",
         "derived": "distinct shapes decided by the net vs oracle fallback"},
        {"name": "adaptnet_serving.lm_head_full_vocab.cost_ratio",
         "value": round(probe_ratio, 5),
         "derived": f"{M}x{K}x{N} (N>10^4: unrepresentable pre-logbucket)"},
    ]
    return emit(rows, "bench_adaptnet_serving",
                config={"n_requests": n_requests, "slots": slots,
                        "seed": seed, "samples": samples, "epochs": epochs})


if __name__ == "__main__":
    print("name,value,derived")
    run()
