"""Paper Fig. 3: runtime + SRAM reads, 256x64 @ 64x256, mono vs distributed
vs RSA — the motivating trade-off."""
import numpy as np

from repro.core import costmodel as cm
from repro.core.hw import OS
from repro.core.rsa import SAGAR_INSTANCE
from benchmarks.common import emit

M, K, N = 256, 64, 256


def run():
    rows = []
    mono = cm.monolithic_cost(M, K, N, 128, 128, OS)
    t0, r0 = float(mono.runtime), float(mono.sram_reads)
    rows.append({"name": "fig3.monolithic_128x128.runtime", "value": t0,
                 "derived": f"reads={r0:.0f}"})
    for units, dim in [(4, 64), (16, 32), (64, 16), (256, 8), (1024, 4)]:
        d = cm.distributed_cost(M, K, N, dim, dim, units, OS)
        rows.append({
            "name": f"fig3.distributed_{units}x{dim}x{dim}.runtime",
            "value": float(d.runtime),
            "derived": (f"speedup_vs_mono={t0/float(d.runtime):.2f}x "
                        f"reads_vs_mono={float(d.sram_reads)/r0:.1f}x")})
    best = cm.oracle_runtime(SAGAR_INSTANCE, [M], [K], [N])[0]
    lbl = cm.best_config(SAGAR_INSTANCE, [M], [K], [N],
                         objective="edp")[0]
    sc = cm.sweep_configs(SAGAR_INSTANCE, [M], [K], [N])
    rows.append({"name": "fig3.rsa_best.runtime", "value": float(best),
                 "derived": f"speedup_vs_mono={t0/best:.2f}x"})
    rows.append({"name": "fig3.rsa_edp_choice.reads",
                 "value": float(sc.sram_reads[0, lbl]),
                 "derived": f"reads_vs_mono={float(sc.sram_reads[0,lbl])/r0:.2f}x"})
    return emit(rows, "fig3")
