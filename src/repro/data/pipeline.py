"""Deterministic synthetic LM data pipeline.

Generates a learnable token stream (noisy affine bigram process) so e2e
training shows a real loss drop below the uniform-entropy floor.  The
stream is a pure function of (seed, shard, step): restart-safe (a resumed
run sees exactly the data it would have seen), and host-shardable (each
data-parallel host generates only its rows; no data service needed at
1000-node scale).

A background thread prefetches `prefetch` batches ahead.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    noise: float = 0.05       # fraction of tokens resampled uniformly
    # host sharding: this host generates rows [row_start, row_start+rows)
    row_start: int = 0
    rows: Optional[int] = None

    @property
    def local_rows(self) -> int:
        return self.rows if self.rows is not None else self.global_batch


def _batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Tokens (local_rows, seq_len + 1) — pure function of (cfg, step)."""
    V = cfg.vocab_size
    out = np.empty((cfg.local_rows, cfg.seq_len + 1), np.int32)
    for i in range(cfg.local_rows):
        row = cfg.row_start + i
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, row]))
        a = 31 % V or 1
        c = 17 % V
        t = np.empty(cfg.seq_len + 1, np.int64)
        t[0] = rng.integers(0, V)
        noise = rng.random(cfg.seq_len) < cfg.noise
        rand = rng.integers(0, V, cfg.seq_len)
        for j in range(cfg.seq_len):
            t[j + 1] = rand[j] if noise[j] else (a * t[j] + c) % V
        out[i] = t.astype(np.int32)
    return {"tokens": out}


class Loader:
    """Iterator over batches with background prefetch + seekable step."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self._q.put((s, _batch(self.cfg, s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        s, b = self._q.get()
        self.step = s + 1
        return b

    def close(self):
        self._stop.set()


def make_loader(vocab_size: int, seq_len: int, global_batch: int,
                seed: int = 1234, start_step: int = 0, **kw) -> Loader:
    return Loader(DataConfig(vocab_size=vocab_size, seq_len=seq_len,
                             global_batch=global_batch, seed=seed, **kw),
                  start_step=start_step)
