"""ADAPTNET — the paper's recommendation network (Fig. 7f), in pure JAX.

Architecture (faithful): one trainable embedding table per input feature
(M, K, N), concatenated, one 128-unit hidden layer, softmax over config
classes.  The embedding tables dominate the on-chip footprint (paper
footnote 1): 3 x 10001 x 16 at one byte/weight ~ 480 KB of the 512 KB
ADAPTNETX SRAM.

Two feature encodings (``AdaptNetConfig.encoding``):

  "raw"        the paper's direct per-dim embedding lookup over
               [0, 10^4].  Dims beyond the table silently clip, so every
               dim > 10^4 aliases to one row — real serving sites like
               lm_head (N = 128256..256000) are NOT representable.
  "logbucket"  log-spaced bucket embedding over [1, max_dim] (default
               2^18, covering every registry arch's vocab), concatenated
               with per-dim continuous features (log2 magnitude + the
               fractional position within 128/512/2048 alignment
               periods, which is what the tile cost model's ceil()
               quantization actually depends on).  This is the encoding
               ADAPTNET-TPU serves with; params carry their
               ``bucket_edges``/``dim_max`` so a loaded checkpoint is
               self-describing.

Trained with this repo's own substrate (optim.AdamW), not an external
framework — the framework trains its own controller.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataset import Dataset, MAX_DIM
from repro.optim.adamw import AdamW, apply_updates, cosine_schedule

EMBED_DIM = 16
HIDDEN = 128
VOCAB = MAX_DIM + 1

# logbucket encoding: covers every registry arch's GEMM dims (gemma-2b
# lm_head N = 256000 < 2^18); alignment periods mirror the tile space's
# block granularities (BLOCK_MN up to 512, BLOCK_K up to 2048).
MAX_DIM_SERVING = 1 << 18
ALIGN_PERIODS = (128.0, 512.0, 2048.0)
N_CONT = 1 + len(ALIGN_PERIODS)          # log2 magnitude + one per period


@dataclass
class AdaptNetConfig:
    num_classes: int
    embed_dim: int = EMBED_DIM
    hidden: int = HIDDEN
    vocab: int = VOCAB
    encoding: str = "raw"                # "raw" | "logbucket"
    num_buckets: int = 256               # logbucket table rows per feature
    max_dim: int = MAX_DIM_SERVING       # logbucket coverage [1, max_dim]


def init_params(key, cfg: AdaptNetConfig) -> Dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e = cfg.embed_dim
    if cfg.encoding == "logbucket":
        vocab = cfg.num_buckets
        in_dim = 3 * e + 3 * N_CONT
    elif cfg.encoding == "raw":
        vocab = cfg.vocab
        in_dim = 3 * e
    else:
        raise ValueError(f"unknown encoding {cfg.encoding!r}")
    params = {
        "emb_m": jax.random.normal(k1, (vocab, e)) * 0.02,
        "emb_k": jax.random.normal(k2, (vocab, e)) * 0.02,
        "emb_n": jax.random.normal(k3, (vocab, e)) * 0.02,
        "w1": jax.random.normal(k4, (in_dim, cfg.hidden)) *
              (1.0 / np.sqrt(in_dim)),
        "b1": jnp.zeros((cfg.hidden,)),
        "w2": jax.random.normal(k5, (cfg.hidden, cfg.num_classes)) *
              (1.0 / np.sqrt(cfg.hidden)),
        "b2": jnp.zeros((cfg.num_classes,)),
    }
    if cfg.encoding == "logbucket":
        # interior bucket boundaries + coverage bound ride inside the param
        # pytree (zero gradient, zero weight decay) so a saved checkpoint
        # is self-describing and the dispatcher can detect out-of-range
        # shapes without side-channel config.
        edges = np.geomspace(1.0, cfg.max_dim, cfg.num_buckets + 1)[1:-1]
        params["bucket_edges"] = jnp.asarray(edges, jnp.float32)
        params["dim_max"] = jnp.float32(cfg.max_dim)
    return params


def trained_max_dim(params: Dict) -> int:
    """Largest dim the params' encoding can represent: the recorded
    coverage bound for logbucket params, the embedding-table extent for
    legacy raw params (beyond which lookups would alias)."""
    if "dim_max" in params:
        return int(np.asarray(params["dim_max"]))
    return MAX_DIM


def _encode_logbucket(params: Dict, feats: jnp.ndarray) -> jnp.ndarray:
    f = feats.astype(jnp.float32)
    idx = jnp.searchsorted(params["bucket_edges"], f, side="right")
    m = params["emb_m"][idx[:, 0]]
    k = params["emb_k"][idx[:, 1]]
    n = params["emb_n"][idx[:, 2]]
    logd = jnp.log2(jnp.maximum(f, 1.0)) / np.log2(float(MAX_DIM_SERVING))
    cont = [logd] + [jnp.mod(f, p) / p for p in ALIGN_PERIODS]
    return jnp.concatenate([m, k, n] + cont, axis=-1)


def logits_fn(params: Dict, feats: jnp.ndarray) -> jnp.ndarray:
    """feats: (B, 3) int32 (M, K, N) -> (B, num_classes)."""
    if "bucket_edges" in params:
        h = _encode_logbucket(params, feats)
    else:
        m = params["emb_m"][jnp.clip(feats[:, 0], 0, VOCAB - 1)]
        k = params["emb_k"][jnp.clip(feats[:, 1], 0, VOCAB - 1)]
        n = params["emb_n"][jnp.clip(feats[:, 2], 0, VOCAB - 1)]
        h = jnp.concatenate([m, k, n], axis=-1)
    # saralint: ok[dispatch-escape] ADAPTNET's own recommender MLP — routing it through dispatch.gemm would recurse into the dispatcher it implements
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    # saralint: ok[dispatch-escape] ADAPTNET's own recommender MLP — routing it through dispatch.gemm would recurse into the dispatcher it implements
    return h @ params["w2"] + params["b2"]


def logits_np(params: Dict, feats: np.ndarray) -> np.ndarray:
    """Pure-NumPy twin of ``logits_fn`` for trace-time callers: the SARA
    dispatcher resolves recommendations while an ambient jit/vmap trace
    is active (the engine's prefill/decode), where jnp ops would either
    stage into the executable or trip the transform machinery.  Same
    math, host-side — like the oracle's cost-model sweep."""
    p = {k: np.asarray(v) for k, v in params.items()}
    f = np.asarray(feats)
    if "bucket_edges" in p:
        ff = f.astype(np.float32)
        idx = np.searchsorted(p["bucket_edges"], ff, side="right")
        emb = [p["emb_m"][idx[:, 0]], p["emb_k"][idx[:, 1]],
               p["emb_n"][idx[:, 2]]]
        logd = np.log2(np.maximum(ff, 1.0)) / np.log2(float(MAX_DIM_SERVING))
        cont = [logd] + [np.mod(ff, per) / per for per in ALIGN_PERIODS]
        h = np.concatenate(emb + cont, axis=-1, dtype=np.float32)
    else:
        h = np.concatenate([p["emb_m"][np.clip(f[:, 0], 0, VOCAB - 1)],
                            p["emb_k"][np.clip(f[:, 1], 0, VOCAB - 1)],
                            p["emb_n"][np.clip(f[:, 2], 0, VOCAB - 1)]],
                           axis=-1)
    # saralint: ok[dispatch-escape] host-side NumPy twin of the recommender MLP; runs under an ambient trace where dispatch cannot
    h = np.maximum(h @ p["w1"] + p["b1"], 0.0)
    # saralint: ok[dispatch-escape] host-side NumPy twin of the recommender MLP; runs under an ambient trace where dispatch cannot
    return h @ p["w2"] + p["b2"]


def predict(params: Dict, feats: np.ndarray, batch: int = 8192) -> np.ndarray:
    f = jax.jit(lambda p, x: jnp.argmax(logits_fn(p, x), -1))
    out = []
    for lo in range(0, len(feats), batch):
        out.append(np.asarray(f(params, feats[lo:lo + batch])))
    return np.concatenate(out)


@dataclass
class TrainResult:
    params: Dict
    history: list          # (epoch, train_acc, val_acc)
    test_accuracy: float
    train_seconds: float


def train(train_ds: Dataset, test_ds: Dataset, *, epochs: int = 20,
          batch: int = 1024, lr: float = 3e-3, seed: int = 0,
          log: bool = True, cfg: AdaptNetConfig = None) -> TrainResult:
    if cfg is None:
        cfg = AdaptNetConfig(num_classes=train_ds.num_classes)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    n = len(train_ds.labels)
    steps_per_epoch = n // batch
    total_steps = epochs * steps_per_epoch
    opt = AdamW(lr=cosine_schedule(lr, warmup=min(200, total_steps // 10),
                                   total=total_steps),
                weight_decay=0.0, clip_norm=1.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, xb, yb):
        def loss_fn(p):
            lg = logits_fn(p, xb)
            lse = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, yb[:, None], -1)[:, 0]
            loss = jnp.mean(lse - gold)
            acc = jnp.mean((jnp.argmax(lg, -1) == yb).astype(jnp.float32))
            return loss, acc
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        updates, opt_state, _ = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss, acc

    rng = np.random.default_rng(seed)
    feats = train_ds.features
    labels = train_ds.labels
    hist = []
    t0 = time.time()
    for ep in range(epochs):
        order = rng.permutation(n)
        accs = []
        for s in range(steps_per_epoch):
            idx = order[s * batch:(s + 1) * batch]
            params, opt_state, loss, acc = step(
                params, opt_state, feats[idx], labels[idx])
            accs.append(float(acc))
        val_acc = accuracy(params, test_ds)
        hist.append((ep, float(np.mean(accs)), val_acc))
        if log:
            print(f"  adaptnet epoch {ep}: train_acc={np.mean(accs):.4f} "
                  f"val_acc={val_acc:.4f}")
    return TrainResult(params=params, history=hist,
                       test_accuracy=accuracy(params, test_ds),
                       train_seconds=time.time() - t0)


def accuracy(params: Dict, ds: Dataset) -> float:
    pred = predict(params, ds.features)
    return float(np.mean(pred == ds.labels))
