"""ADAPTNET — the paper's recommendation network (Fig. 7f), in pure JAX.

Architecture (faithful): one trainable embedding table per input feature
(M, K, N), concatenated, one 128-unit hidden layer, softmax over config
classes.  The embedding tables dominate the on-chip footprint (paper
footnote 1): 3 x 10001 x 16 at one byte/weight ~ 480 KB of the 512 KB
ADAPTNETX SRAM.

Trained with this repo's own substrate (optim.AdamW), not an external
framework — the framework trains its own controller.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataset import Dataset, MAX_DIM
from repro.optim.adamw import AdamW, apply_updates, cosine_schedule

EMBED_DIM = 16
HIDDEN = 128
VOCAB = MAX_DIM + 1


@dataclass
class AdaptNetConfig:
    num_classes: int
    embed_dim: int = EMBED_DIM
    hidden: int = HIDDEN
    vocab: int = VOCAB


def init_params(key, cfg: AdaptNetConfig) -> Dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e = cfg.embed_dim
    return {
        "emb_m": jax.random.normal(k1, (cfg.vocab, e)) * 0.02,
        "emb_k": jax.random.normal(k2, (cfg.vocab, e)) * 0.02,
        "emb_n": jax.random.normal(k3, (cfg.vocab, e)) * 0.02,
        "w1": jax.random.normal(k4, (3 * e, cfg.hidden)) *
              (1.0 / np.sqrt(3 * e)),
        "b1": jnp.zeros((cfg.hidden,)),
        "w2": jax.random.normal(k5, (cfg.hidden, cfg.num_classes)) *
              (1.0 / np.sqrt(cfg.hidden)),
        "b2": jnp.zeros((cfg.num_classes,)),
    }


def logits_fn(params: Dict, feats: jnp.ndarray) -> jnp.ndarray:
    """feats: (B, 3) int32 (M, K, N) -> (B, num_classes)."""
    m = params["emb_m"][jnp.clip(feats[:, 0], 0, VOCAB - 1)]
    k = params["emb_k"][jnp.clip(feats[:, 1], 0, VOCAB - 1)]
    n = params["emb_n"][jnp.clip(feats[:, 2], 0, VOCAB - 1)]
    h = jnp.concatenate([m, k, n], axis=-1)
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def predict(params: Dict, feats: np.ndarray, batch: int = 8192) -> np.ndarray:
    f = jax.jit(lambda p, x: jnp.argmax(logits_fn(p, x), -1))
    out = []
    for lo in range(0, len(feats), batch):
        out.append(np.asarray(f(params, feats[lo:lo + batch])))
    return np.concatenate(out)


@dataclass
class TrainResult:
    params: Dict
    history: list          # (epoch, train_acc, val_acc)
    test_accuracy: float
    train_seconds: float


def train(train_ds: Dataset, test_ds: Dataset, *, epochs: int = 20,
          batch: int = 1024, lr: float = 3e-3, seed: int = 0,
          log: bool = True) -> TrainResult:
    cfg = AdaptNetConfig(num_classes=train_ds.num_classes)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    n = len(train_ds.labels)
    steps_per_epoch = n // batch
    total_steps = epochs * steps_per_epoch
    opt = AdamW(lr=cosine_schedule(lr, warmup=min(200, total_steps // 10),
                                   total=total_steps),
                weight_decay=0.0, clip_norm=1.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, xb, yb):
        def loss_fn(p):
            lg = logits_fn(p, xb)
            lse = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, yb[:, None], -1)[:, 0]
            loss = jnp.mean(lse - gold)
            acc = jnp.mean((jnp.argmax(lg, -1) == yb).astype(jnp.float32))
            return loss, acc
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        updates, opt_state, _ = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss, acc

    rng = np.random.default_rng(seed)
    feats = train_ds.features
    labels = train_ds.labels
    hist = []
    t0 = time.time()
    for ep in range(epochs):
        order = rng.permutation(n)
        accs = []
        for s in range(steps_per_epoch):
            idx = order[s * batch:(s + 1) * batch]
            params, opt_state, loss, acc = step(
                params, opt_state, feats[idx], labels[idx])
            accs.append(float(acc))
        val_acc = accuracy(params, test_ds)
        hist.append((ep, float(np.mean(accs)), val_acc))
        if log:
            print(f"  adaptnet epoch {ep}: train_acc={np.mean(accs):.4f} "
                  f"val_acc={val_acc:.4f}")
    return TrainResult(params=params, history=hist,
                       test_accuracy=accuracy(params, test_ds),
                       train_seconds=time.time() - t0)


def accuracy(params: Dict, ds: Dataset) -> float:
    pred = predict(params, ds.features)
    return float(np.mean(pred == ds.labels))
