"""Post-layout PPA arithmetic (paper §V-B, Fig. 13) — published constants.

RTL/PnR cannot run in software; what CAN be reproduced is the paper's PPA
*arithmetic*: given the published component numbers, recompute the headline
ratios (3.2x compute density, 3.5x power efficiency, <10% area / ~50% power
over monolithic, ADAPTNETX at 8.65% area / 1.36% power) and validate them in
tests/benchmarks.  Component breakdowns follow Fig. 13c-d.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.hw import TECH_28NM


@dataclass(frozen=True)
class PPA:
    name: str
    area_mm2: float
    power_w: float
    tops: float

    @property
    def compute_density(self) -> float:        # TOPS / mm^2
        return self.tops / self.area_mm2

    @property
    def power_efficiency(self) -> float:       # TOPS / W
        return self.tops / self.power_w


# paper Fig. 13b-d (28 nm, 1 GHz, 2^14 MACs => 32.768 TOPS)
SAGAR = PPA("SAGAR", area_mm2=81.90, power_w=13.01, tops=32.768)

# monolithic 128x128: SAGAR is ~8% larger and ~50% more power (paper §V-B)
MONOLITHIC = PPA("monolithic-128x128", area_mm2=81.90 / 1.08,
                 power_w=13.01 / 1.50, tops=32.768)

# distributed 1024x 4x4 with mesh NoC: 3.2x SAGAR area, 3.5x SAGAR power
DISTRIBUTED_4x4 = PPA("distributed-1024x4x4", area_mm2=81.90 * 3.2,
                      power_w=13.01 * 3.5, tops=32.768)

# SIGMA comparison points (paper §V-C): SAGAR fits 45% more compute at equal
# area; compute-equivalent SIGMA takes ~43% more power and ~30% more area.
SIGMA_COMPUTE_EQ = PPA("SIGMA-compute-eq", area_mm2=81.90 / 0.70,
                       power_w=13.01 / 0.57, tops=32.768)

ADAPTNETX_AREA_MM2 = SAGAR.area_mm2 * TECH_28NM.adaptnetx_area_frac
ADAPTNETX_POWER_W = SAGAR.power_w * TECH_28NM.adaptnetx_power_frac


def headline_ratios() -> dict:
    return {
        "density_vs_distributed":
            SAGAR.compute_density / DISTRIBUTED_4x4.compute_density,
        "power_eff_vs_distributed":
            SAGAR.power_efficiency / DISTRIBUTED_4x4.power_efficiency,
        "area_overhead_vs_monolithic":
            SAGAR.area_mm2 / MONOLITHIC.area_mm2 - 1.0,
        "power_overhead_vs_monolithic":
            SAGAR.power_w / MONOLITHIC.power_w - 1.0,
        "adaptnetx_area_frac": TECH_28NM.adaptnetx_area_frac,
        "adaptnetx_power_frac": TECH_28NM.adaptnetx_power_frac,
        "sigma_compute_eq_power_saving":
            1.0 - SAGAR.power_w / SIGMA_COMPUTE_EQ.power_w,
        "sigma_compute_eq_area_saving":
            1.0 - SAGAR.area_mm2 / SIGMA_COMPUTE_EQ.area_mm2,
    }
