"""GEMM-workload dataset generation for ADAPTNET training.

Paper §III-B: ~2M workloads, M/N/K sampled from positive integers <= 10^4,
labels = exhaustive-search optimum over the RSA config space via (modified)
SCALE-Sim — about a week on ~200 Xeon cores.  Here the closed-form cost
model labels 2M workloads in seconds on one core.

Deviations (DESIGN.md §2.1):
- sampling is LOG-uniform over [1, 10^4] by default.  Under a contention-
  free analytic model, uniform sampling concentrates all mass at dims where
  quantization effects vanish and the label collapses to a near-constant;
  log-uniform matches real layer-dim distributions and restores the
  boundary structure.  `--dist uniform` reproduces the paper's sampler.
- the default objective is EDP (energy-delay product).  The paper labels by
  min-runtime under a simulator whose contention creates interior optima;
  our contention-free model's runtime-optimum degenerates, while EDP
  (occupancy-aware energy x delay) recovers the interior-optimum structure
  of paper Fig. 7c.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from repro.core import costmodel as cm
from repro.core.rsa import RSAInstance, SAGAR_INSTANCE, enumerate_configs

MAX_DIM = 10_000


@dataclass
class Dataset:
    features: np.ndarray      # (n, 3) int32: M, K, N
    labels: np.ndarray        # (n,) int32 class ids
    num_classes: int

    def split(self, train_frac: float = 0.9, seed: int = 0
              ) -> Tuple["Dataset", "Dataset"]:
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(self.labels))
        k = int(len(idx) * train_frac)
        tr, te = idx[:k], idx[k:]
        return (Dataset(self.features[tr], self.labels[tr], self.num_classes),
                Dataset(self.features[te], self.labels[te], self.num_classes))


def sample_workloads(n: int, *, dist: str = "loguniform", seed: int = 0,
                     max_dim: int = MAX_DIM) -> np.ndarray:
    """``max_dim`` widens the sampled range beyond the paper's 10^4 (the
    serving-realistic ADAPTNET-TPU trainer covers lm_head-scale dims up
    to 2^18 — see launch/train_adaptnet.py)."""
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        dims = rng.integers(1, max_dim + 1, size=(n, 3))
    elif dist == "loguniform":
        dims = np.exp(rng.uniform(0.0, np.log(max_dim), size=(n, 3)))
        dims = np.clip(dims.astype(np.int64) + 1, 1, max_dim)
    else:
        raise ValueError(dist)
    return dims.astype(np.int32)


def generate(n: int = 400_000, *, inst: RSAInstance = SAGAR_INSTANCE,
             dist: str = "loguniform", objective: str = "edp",
             seed: int = 0, chunk: int = 100_000) -> Dataset:
    """Label n workloads with the exhaustive-search oracle (vectorized)."""
    feats = sample_workloads(n, dist=dist, seed=seed)
    labels = np.empty(n, np.int32)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        labels[lo:hi] = cm.best_config(
            inst, feats[lo:hi, 0], feats[lo:hi, 1], feats[lo:hi, 2],
            objective=objective)
    return Dataset(feats, labels, num_classes=len(enumerate_configs(inst)))


def relative_performance(inst: RSAInstance, feats: np.ndarray,
                         pred: np.ndarray, metric: str = "edp") -> np.ndarray:
    """per-sample predicted-config cost / oracle cost (>= 1)."""
    cost = cm.sweep_configs(inst, feats[:, 0], feats[:, 1], feats[:, 2])
    table = cost.edp if metric == "edp" else cost.runtime
    chosen = np.take_along_axis(table, pred[:, None].astype(int), -1)[:, 0]
    return chosen / table.min(axis=-1)


def geomean_relative(inst: RSAInstance, feats: np.ndarray, pred: np.ndarray,
                     metric: str = "edp") -> float:
    rel = relative_performance(inst, feats, pred, metric)
    return float(np.exp(np.mean(np.log(rel))))
