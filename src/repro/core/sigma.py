"""SIGMA analytical performance model (paper §V-C, Fig. 14).

SIGMA [30] streams operands over a Benes network directly to a flexible
multiplier substrate and reduces partial sums through a forest of adder
trees (FAN).  The paper's comparison uses SIGMA's own analytical model:
time to (a) stream operands, (b) multiply, (c) reduce — sparsity-aware.

Closed form used here (per GEMM M x K x N, `flex` multipliers, density d):
  useful_macs   = M*K*N * d
  rounds        = ceil(useful_macs / flex)     (1 round/cycle, pipelined)
  fill          = K*d / bw + log2(K)           (first-operand distribution +
                                                adder-tree latency; streaming
                                                overlaps with compute after
                                                the pipeline fills)
This reproduces the paper's Fig.-14 ordering with no store-and-forward
penalty: SIGMA_C (compute-normalized, 16384 MACs) slightly beats SAGAR on
dense workloads; SIGMA_A (area-normalized, 2734 MACs) is ~6x slower and only
overtakes SAGAR beyond ~70-85% operand sparsity.
"""

from __future__ import annotations

import numpy as np

SIGMA_C_MACS = 16384
SIGMA_A_MACS = 2734
BW_FACTOR = 16.0            # Benes delivers a K-slice in K/16 cycles


def sigma_runtime(M, K, N, *, num_macs: int = SIGMA_C_MACS,
                  density: float = 1.0) -> np.ndarray:
    M = np.asarray(M, np.float64)
    K = np.asarray(K, np.float64)
    N = np.asarray(N, np.float64)
    useful = M * K * N * density
    rounds = np.ceil(useful / num_macs)
    fill = np.maximum(K * density / BW_FACTOR, 1.0) + \
        np.log2(np.maximum(K, 2.0))
    return rounds + fill


def sigma_c_runtime(M, K, N, density: float = 1.0):
    return sigma_runtime(M, K, N, num_macs=SIGMA_C_MACS, density=density)


def sigma_a_runtime(M, K, N, density: float = 1.0):
    return sigma_runtime(M, K, N, num_macs=SIGMA_A_MACS, density=density)
