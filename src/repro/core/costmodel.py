"""SCALE-Sim-equivalent analytical cost model for systolic GEMM.

Closed forms (validated by hand + property tests) replace cycle-accurate
simulation so that labeling ~10^6 workloads takes seconds on one core
instead of the paper's week on ~200 Xeons (DESIGN.md §2).

Per-pass runtime on an R x C MAC array (SCALE-Sim §III conventions):
  OS: map M->R, N->C, stream K:     T = 2R + C + K - 2
  WS: preload KxN tile, stream M:   T = R + C + M - 1
  IS: preload KxM tile, stream N:   T = R + C + N - 1
Folds (serialization steps over the partition grid p x q):
  OS: ceil(ceil(M/R)/p) * ceil(ceil(N/C)/q)
  WS: ceil(ceil(K/R)/p) * ceil(ceil(N/C)/q)
  IS: ceil(ceil(K/R)/p) * ceil(ceil(M/C)/q)

System kinds:
  MONOLITHIC  — p=q=1, no extra latency.
  RSA (SAGAR) — partitions fed by pipelined bypass links: +ceil(cells/8)
                pipeline fill per pass (paper Fig. 13h), UNIFIED scratchpad:
                reads are multicast-collated, so reads match an equivalent
                monolithic array (the paper's headline reuse property).
  DISTRIBUTED — independent units behind a mesh NoC: per-pass operand
                distribution latency of HOP_CYCLES * 2*sqrt(P) cycles
                (round-trip across the mesh diameter), and per-unit SRAM
                streams with NO collation: reads scale with the number of
                active units.  HOP_CYCLES=8 is the single calibrated
                constant, chosen so the Fig.-3 motivating GEMM reproduces
                the paper's reported optimum (32x32, ~2x over monolithic);
                the 4x SRAM-read excess of the 32x32 distributed config is
                reproduced with no calibration (it is structural).

Energy (paper Fig. 11d narrative): fine-grained gating is impractical, so
every MAC burns every cycle => E_compute = num_macs * T * e_mac; SRAM reads
dominate the rest; distributed adds NoC hop energy; EDP = E * T.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.core.hw import IS, OS, TECH_28NM, WS
from repro.core.rsa import CELL, RSAInstance, config_table

HOP_CYCLES = 8.0          # mesh-NoC hop (calibrated, see module docstring)
# RSA bypass links are SMART-style pipelined wires (paper §II-C), not a
# packet-switched NoC: staging operands into P concurrent partitions costs
# ~2*sqrt(P) cycles per pass at 1 cycle/stage — 8x cheaper than the mesh.
# This is the term that makes the optimal partitioning workload-dependent
# (interior optima, paper Fig. 7c) instead of degenerating to finest-grid.
RSA_STAGE_CYCLES = 1.0
BYTES_PER_ELEM = 1        # int8 operands (32.768 TOPS at 2^14 MACs @ 1 GHz)

MONOLITHIC = "monolithic"
RSA = "rsa"
DISTRIBUTED = "distributed"


def _ceil_div(a, b):
    return -(-a // b)


@dataclass
class GEMMCost:
    runtime: np.ndarray           # cycles
    sram_reads: np.ndarray        # element reads
    sram_writes: np.ndarray       # element writes
    energy_pj: np.ndarray
    edp: np.ndarray               # pJ * cycles
    theoretical_min_cycles: np.ndarray
    theoretical_min_reads: np.ndarray


def gemm_cost(M, K, N, R, C, p, q, df, *, system: str = RSA,
              num_macs_total: int | None = None) -> GEMMCost:
    """Vectorized cost.  All of (M,K,N) and (R,C,p,q,df) broadcast together.

    (M,K,N): workload dims;  (R,C): sub-array MAC dims;  (p,q): partition
    grid;  df: dataflow id;  system: MONOLITHIC | RSA | DISTRIBUTED.
    """
    M = np.asarray(M, np.float64)
    K = np.asarray(K, np.float64)
    N = np.asarray(N, np.float64)
    R = np.asarray(R, np.float64)
    C = np.asarray(C, np.float64)
    p = np.asarray(p, np.float64)
    q = np.asarray(q, np.float64)
    df = np.asarray(df)

    P = p * q
    macs = R * C * P if num_macs_total is None else float(num_macs_total)

    # ---- folds per partition ---------------------------------------------
    fM_R = _ceil_div(M, R)
    fN_C = _ceil_div(N, C)
    fK_R = _ceil_div(K, R)
    fM_C = _ceil_div(M, C)
    folds_os = _ceil_div(fM_R, p) * _ceil_div(fN_C, q)
    folds_ws = _ceil_div(fK_R, p) * _ceil_div(fN_C, q)
    folds_is = _ceil_div(fK_R, p) * _ceil_div(fM_C, q)
    folds = np.where(df == OS, folds_os,
                     np.where(df == WS, folds_ws, folds_is))

    # ---- per-pass latency ---------------------------------------------------
    t_os = 2 * R + C + K - 2
    t_ws = R + C + M - 1
    t_is = R + C + N - 1
    t_pass = np.where(df == OS, t_os, np.where(df == WS, t_ws, t_is))

    if system == DISTRIBUTED:
        t_pass = t_pass + HOP_CYCLES * 2.0 * np.sqrt(P)
    elif system == RSA:
        # pipelined bypass staging (see RSA_STAGE_CYCLES) + relay fill of
        # ceil(cells spanned / 8) (paper Fig. 13h)
        cells_span = np.maximum(p * R, q * C) / CELL
        t_pass = (t_pass + RSA_STAGE_CYCLES * 2.0 * np.sqrt(P) +
                  _ceil_div(cells_span, TECH_28NM.bypass_cells_per_stage))
    runtime = folds * t_pass

    # ---- SRAM traffic -------------------------------------------------------
    # streams per pass on one unit (operands entering the array edges):
    stream_os = (R + C) * K
    stream_ws = R * C + M * R            # preload W + stream inputs
    stream_is = R * C + N * R
    stream = np.where(df == OS, stream_os,
                      np.where(df == WS, stream_ws, stream_is))
    if system == DISTRIBUTED:
        reads = folds * P * stream       # every unit streams privately
    elif system == RSA:
        # unified SRAM, multicast by read collation (paper §II-D): per global
        # step the array reads p*R rows + q*C cols ONCE each.
        coll_os = (p * R + q * C) * K
        coll_ws = p * R * q * C + M * p * R
        coll_is = p * R * q * C + N * p * R
        reads = folds * np.where(df == OS, coll_os,
                                 np.where(df == WS, coll_ws, coll_is))
    else:
        reads = folds * stream

    # psum read-modify-write when K is folded (WS/IS)
    k_folds = np.where(df == OS, 1.0, fK_R)
    writes = M * N + (k_folds - 1) * M * N        # final + partial writes
    reads = reads + (k_folds - 1) * M * N         # partial re-reads

    # ---- energy -------------------------------------------------------------
    # Fine-grained (per-MAC) gating is impractical (paper §V-A), but whole
    # idle PARTITIONS gate at the bypass-mux boundary: active fraction =
    # tiles_mapped / (folds * P).  This is what makes the energy-optimal
    # geometry workload-dependent (Fig. 7c).
    tiles_os = fM_R * fN_C
    tiles_ws = fK_R * fN_C
    tiles_is = fK_R * fM_C
    tiles = np.where(df == OS, tiles_os,
                     np.where(df == WS, tiles_ws, tiles_is))
    occupancy = np.minimum(1.0, tiles / np.maximum(folds * P, 1.0))
    t = TECH_28NM
    e_compute = macs * occupancy * runtime * t.e_mac_pj
    e_sram = (reads * t.e_sram_read_pj_per_byte +
              writes * t.e_sram_write_pj_per_byte) * BYTES_PER_ELEM
    e_noc = np.zeros_like(e_sram)
    if system == DISTRIBUTED:
        hops = np.sqrt(P)
        e_noc = reads * hops * t.e_noc_hop_pj_per_byte * BYTES_PER_ELEM
    e_dram = (M * K + K * N + M * N) * t.e_dram_pj_per_byte * BYTES_PER_ELEM
    energy = e_compute + e_sram + e_noc + e_dram
    edp = energy * runtime

    return GEMMCost(
        runtime=runtime,
        sram_reads=reads,
        sram_writes=writes,
        energy_pj=energy,
        edp=edp,
        theoretical_min_cycles=np.maximum(M * N * K / macs, 1.0),
        theoretical_min_reads=M * K + K * N,
    )


# ---------------------------------------------------------------------------
# RSA-wide sweep: cost of every configuration for a batch of workloads
# ---------------------------------------------------------------------------

def sweep_configs(inst: RSAInstance, M, K, N, *, system: str = RSA
                  ) -> GEMMCost:
    """Cost of all configs (axis -1) for workloads (leading axes)."""
    tab = config_table(inst)
    M = np.asarray(M, np.float64)[..., None]
    K = np.asarray(K, np.float64)[..., None]
    N = np.asarray(N, np.float64)[..., None]
    return gemm_cost(M, K, N, tab["R"], tab["C"], tab["p"], tab["q"],
                     tab["df"], system=system,
                     num_macs_total=inst.num_macs)


def best_config(inst: RSAInstance, M, K, N, *, system: str = RSA,
                objective: str = "runtime") -> np.ndarray:
    """Oracle labels: argmin config id per workload (ties -> fewer reads,
    then lower id, deterministically)."""
    cost = sweep_configs(inst, M, K, N, system=system)
    key1 = cost.runtime if objective == "runtime" else cost.edp
    # lexicographic argmin via epsilon tie-breaking on reads
    key = key1 * (1.0 + 1e-12) + cost.sram_reads * 1e-9 / (
        1.0 + cost.sram_reads.max(axis=-1, keepdims=True))
    return np.argmin(key, axis=-1)


def oracle_runtime(inst: RSAInstance, M, K, N, *, system: str = RSA
                   ) -> np.ndarray:
    cost = sweep_configs(inst, M, K, N, system=system)
    return cost.runtime.min(axis=-1)


def runtime_of_class(inst: RSAInstance, M, K, N, class_ids) -> np.ndarray:
    cost = sweep_configs(inst, M, K, N, system=RSA)
    return np.take_along_axis(cost.runtime,
                              np.asarray(class_ids)[..., None],
                              axis=-1)[..., 0]


# fixed-configuration systems (paper baselines, Table III)
def monolithic_cost(M, K, N, rows: int, cols: int, df) -> GEMMCost:
    return gemm_cost(M, K, N, rows, cols, 1, 1, df, system=MONOLITHIC)


def distributed_cost(M, K, N, unit_rows: int, unit_cols: int,
                     num_units: int, df) -> GEMMCost:
    import math
    pr = int(math.isqrt(num_units))
    qc = num_units // pr
    return gemm_cost(M, K, N, unit_rows, unit_cols, pr, qc, df,
                     system=DISTRIBUTED)


def best_dataflow_cost(cost_fn, M, K, N, *args) -> Dict[str, np.ndarray]:
    """min over the three dataflows for fixed-geometry systems."""
    runs = []
    for df in (OS, WS, IS):
        c = cost_fn(M, K, N, *args, df)
        runs.append(c)
    runtime = np.stack([c.runtime for c in runs])
    reads = np.stack([c.sram_reads for c in runs])
    energy = np.stack([c.energy_pj for c in runs])
    edp = np.stack([c.edp for c in runs])
    idx = np.argmin(runtime, axis=0)
    take = lambda a: np.take_along_axis(a, idx[None], axis=0)[0]
    return {"runtime": take(runtime), "sram_reads": take(reads),
            "energy_pj": take(energy), "edp": take(edp), "dataflow": idx}
