"""Hardware constants.

Two hardware models live here:

1. TPU v5e-class chip (the roofline TARGET for the dry-run analysis).
2. The paper's 28nm accelerator technology constants (for the SCALE-Sim-
   equivalent cost model, energy/EDP reproduction, and PPA arithmetic).
"""

from __future__ import annotations

from dataclasses import dataclass


# ---------------------------------------------------------------------------
# TPU roofline target (per chip)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TPUChip:
    peak_bf16_flops: float = 197e12     # FLOP/s
    hbm_bw: float = 819e9               # B/s
    ici_link_bw: float = 50e9           # B/s per link
    hbm_bytes: float = 16e9             # HBM capacity
    vmem_bytes: float = 16 * 2 ** 20    # ~16 MiB VMEM
    mxu_dim: int = 128                  # systolic MXU tile


TPU_V5E = TPUChip()


# ---------------------------------------------------------------------------
# Paper-side constants (28nm-class; sources noted)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AcceleratorTech:
    freq_hz: float = 1e9                # SAGAR runs at 1 GHz (paper §V-B)
    # per-op energies (28nm-class, pJ) — Dally et al. CACM'20 + Horowitz
    # ISSCC'14 scaling; the paper cites 100 fJ/bit-mm wire energy.
    e_mac_pj: float = 1.0               # one 8-bit-ish MAC
    e_sram_read_pj_per_byte: float = 6.0
    e_sram_write_pj_per_byte: float = 8.0
    e_dram_pj_per_byte: float = 160.0
    e_wire_fj_per_bit_mm: float = 100.0
    e_noc_hop_pj_per_byte: float = 2.0  # mesh NoC hop (router+link)
    # NoC latency per hop (cycles) for the distributed baseline (OpenSMART)
    noc_hop_cycles: float = 1.0
    # SAGAR bypass pipelining: 8 systolic-cells per pipeline stage (Fig 13h)
    bypass_cells_per_stage: int = 8

    # published PnR numbers (paper Fig. 13b) used by core/ppa.py
    sagar_area_mm2: float = 81.90
    sagar_power_w: float = 13.01
    sagar_tops: float = 32.768
    adaptnetx_area_frac: float = 0.0865
    adaptnetx_power_frac: float = 0.0136


TECH_28NM = AcceleratorTech()


# Dataflow ids (paper: output/weight/input stationary)
OS, WS, IS = 0, 1, 2
DATAFLOW_NAMES = {OS: "OS", WS: "WS", IS: "IS"}
