"""SARA — the Self-Adaptive layer that couples ADAPTNET to the execution
substrate (paper §IV, adapted to TPU per DESIGN.md §2).

``SaraDispatcher`` is the framework-level realization of Fig. 2: every GEMM
site can ask it for a configuration.  Two recommendation paths:

  - "oracle": argmin over the analytic TPU tile cost model (exhaustive
    search — what the paper's software stack would do at compile time);
  - "adaptnet": O(1) lookup through a trained ADAPTNET-TPU (what SARA does
    in hardware at runtime).  The paper's claim — the learned model replaces
    search at equal quality — is validated in tests/benchmarks by comparing
    the two paths.

Execution lives in the dispatch layer (``repro.dispatch``): every model
GEMM site calls ``dispatch.gemm(x, w, site=...)``, which resolves the
configuration through the *active* dispatcher (installed with
``dispatch.use(dispatcher, execute="pallas"|"xla"|"auto")``) and runs the
Pallas RSA kernel or XLA accordingly.  ``SaraDispatcher.gemm`` is a
convenience wrapper over that layer; the old module-level ``_GLOBAL``
singleton is gone — policy is explicit, scoped context.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tpu_costmodel as tcm
from repro.core.adaptnet import AdaptNetConfig, init_params, logits_fn


@dataclass
class SaraDispatcher:
    mode: str = "oracle"                   # "oracle" | "adaptnet"
    adaptnet_params: Optional[Dict] = None
    use_pallas: bool = False
    _cache: Dict = field(default_factory=dict)
    _hits: int = 0
    _misses: int = 0

    # -- recommendation ------------------------------------------------------
    def recommend(self, M: int, K: int, N: int) -> tcm.TPUTileConfig:
        key = (M, K, N)
        if key in self._cache:
            self._hits += 1
            return self._cache[key]
        self._misses += 1
        if self.mode == "adaptnet" and self.adaptnet_params is not None:
            feats = jnp.array([[M, K, N]], jnp.int32)
            cid = int(jnp.argmax(logits_fn(self.adaptnet_params, feats), -1)[0])
        else:
            cid = int(tcm.best_tile_config(M, K, N))
        cfg = tcm.TILE_CONFIGS[cid]
        self._cache[key] = cfg
        return cfg

    def cache_info(self) -> Dict[str, int]:
        """Recommendation-cache statistics (the serving engine reports the
        hit rate: a high rate means shape diversity stayed inside the O(1)
        lookup path)."""
        return {"hits": self._hits, "misses": self._misses,
                "size": len(self._cache)}

    def cache_clear(self) -> None:
        self._cache.clear()
        self._hits = self._misses = 0

    def recommend_sharding(self, M: int, K: int, N: int,
                           data: int = 16, model: int = 16) -> tcm.ShardPlan:
        return tcm.plan_gemm_sharding(M, K, N, data=data, model=model)

    # -- execution -----------------------------------------------------------
    def gemm(self, x: jnp.ndarray, w: jnp.ndarray, *,
             site: str = "sara.gemm") -> jnp.ndarray:
        """Self-adaptive GEMM: (..., M, K) @ (K, N), through the dispatch
        layer with this dispatcher active (``use_pallas`` selects the RSA
        Pallas kernel; otherwise XLA)."""
        from repro import dispatch
        with dispatch.use(self,
                          execute="pallas" if self.use_pallas else "xla"):
            return dispatch.gemm(x, w, site=site)


def train_adaptnet_tpu(n_samples: int = 150_000, epochs: int = 10,
                       seed: int = 0, log: bool = False):
    """Train ADAPTNET-TPU on the TPU tile-config space; returns
    (params, test_accuracy, geomean_rel_time)."""
    from repro.core import adaptnet as A
    from repro.core.dataset import Dataset, sample_workloads

    feats = sample_workloads(n_samples, dist="loguniform", seed=seed)
    labels = tcm.best_tile_config(feats[:, 0], feats[:, 1],
                                  feats[:, 2]).astype(np.int32)
    ds = Dataset(feats, labels, num_classes=tcm.NUM_TILE_CLASSES)
    tr, te = ds.split()
    res = A.train(tr, te, epochs=epochs, log=log)
    pred = A.predict(res.params, te.features)
    cost = tcm.tile_cost_seconds(te.features[:, 0], te.features[:, 1],
                                 te.features[:, 2])
    chosen = np.take_along_axis(cost, pred[:, None].astype(int), -1)[:, 0]
    rel = chosen / cost.min(-1)
    geomean = float(np.exp(np.mean(np.log(np.clip(rel, 1.0, None)))))
    return res.params, res.test_accuracy, geomean
