"""SARA — the Self-Adaptive layer that couples ADAPTNET to the execution
substrate (paper §IV, adapted to TPU per DESIGN.md §2).

``SaraDispatcher`` is the framework-level realization of Fig. 2: every GEMM
site can ask it for a configuration.  Two recommendation paths:

  - "oracle": argmin over the analytic TPU tile cost model (exhaustive
    search — what the paper's software stack would do at compile time);
  - "adaptnet": O(1) lookup through a trained ADAPTNET-TPU (what SARA does
    in hardware at runtime).  The paper's claim — the learned model replaces
    search at equal quality — is validated in tests/benchmarks by comparing
    the two paths.

Execution lives in the dispatch layer (``repro.dispatch``): every model
GEMM site calls ``dispatch.gemm(x, w, site=...)``, which resolves the
configuration through the *active* dispatcher (installed with
``dispatch.use(dispatcher, execute="pallas"|"xla"|"auto")``) and runs the
Pallas RSA kernel or XLA accordingly.  ``SaraDispatcher.gemm`` is a
convenience wrapper over that layer; the old module-level ``_GLOBAL``
singleton is gone — policy is explicit, scoped context.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tpu_costmodel as tcm
from repro.core.adaptnet import logits_np, trained_max_dim


def load_adaptnet(directory: str) -> Tuple[Dict, dict]:
    """Load a trained ADAPTNET-TPU artifact saved by
    ``launch/train_adaptnet.py`` (checkpoint/manager.py layout); returns
    (params, metadata).  The params dict is flat, so the checkpoint's
    flat keys restore it directly."""
    from repro.checkpoint.manager import CheckpointManager
    _, flat, meta = CheckpointManager(directory).restore_flat()
    # keep leaves host-side: the only consumers are the dispatcher's
    # NumPy forward (logits_np) and trained_max_dim, so a cache miss
    # stays a table lookup instead of a full-pytree device transfer
    return {k: np.asarray(v) for k, v in flat.items()}, meta


@dataclass
class SaraDispatcher:
    """Per-shape tile-configuration recommender (the paper's SARA runtime).

    ``recommend(M, K, N) -> TPUTileConfig`` resolves a GEMM shape to the
    tile blocks + residency mode the RSA kernel should run with, through
    either the analytic oracle (exhaustive cost-model argmin) or a trained
    ADAPTNET-TPU (``mode="adaptnet"``; shapes outside the trained range
    fall back to the oracle).  Recommendations are memoized per shape —
    ``cache_info()`` / ``cache_clear()`` expose the cache, and
    ``source_of`` / ``source_info`` report which path produced each one.
    Build adaptnet-mode instances with ``from_checkpoint(dir)``; install
    as the active policy with ``dispatch.use(dispatcher, ...)``."""

    mode: str = "oracle"                   # "oracle" | "adaptnet"
    adaptnet_params: Optional[Dict] = None
    use_pallas: bool = False
    _cache: Dict = field(default_factory=dict)
    _sources: Dict = field(default_factory=dict)
    _hits: int = 0
    _misses: int = 0
    _n_adaptnet: int = 0
    _n_oracle: int = 0
    _n_fallback: int = 0

    def __setattr__(self, name, value):
        # flipping the recommendation source on a live dispatcher must not
        # keep serving stale cached recommendations (or stale per-source
        # counters — the telemetry restarts with the new source)
        if (name in ("mode", "adaptnet_params")
                and self.__dict__.get(name) is not value
                and self.__dict__.get("_cache")):
            self.cache_clear()
        object.__setattr__(self, name, value)

    @classmethod
    def from_checkpoint(cls, directory: str, **kw) -> "SaraDispatcher":
        """An adaptnet-mode dispatcher driven by a saved ADAPTNET-TPU."""
        params, _ = load_adaptnet(directory)
        return cls(mode="adaptnet", adaptnet_params=params, **kw)

    # -- recommendation ------------------------------------------------------
    def _adaptnet_active(self) -> bool:
        return self.mode == "adaptnet" and self.adaptnet_params is not None

    def in_trained_range(self, M: int, K: int, N: int) -> bool:
        """Whether the installed ADAPTNET can represent this shape.  Raw
        legacy params clip (alias) every dim > 10^4, so those shapes must
        go to the oracle; logbucket params record their coverage bound."""
        if not self._adaptnet_active():
            return False
        return max(int(M), int(K), int(N)) <= trained_max_dim(
            self.adaptnet_params)

    def _oracle_cfg(self, M, K, N) -> tcm.TPUTileConfig:
        return tcm.TILE_CONFIGS[int(tcm.best_tile_config(M, K, N))]

    def recommend(self, M: int, K: int, N: int) -> tcm.TPUTileConfig:
        key = (int(M), int(K), int(N))
        if key in self._cache:
            self._hits += 1
            return self._cache[key]
        self._misses += 1
        if self._adaptnet_active():
            if self.in_trained_range(M, K, N):
                # recommendations resolve at trace time, often inside an
                # ambient jit/vmap trace (the engine's prefill/decode):
                # the NumPy forward keeps the lookup host-side instead of
                # staging it into the traced executable
                cid = int(np.argmax(logits_np(
                    self.adaptnet_params, np.array([key], np.int64)), -1)[0])
                cfg, src = tcm.TILE_CONFIGS[cid], "adaptnet"
            else:
                # guaranteed fallback: shapes the net was never trained to
                # represent get the exhaustive-search answer, not an
                # arbitrary aliased embedding row
                cfg, src = self._oracle_cfg(M, K, N), "oracle_fallback"
        else:
            cfg, src = self._oracle_cfg(M, K, N), "oracle"
        self._commit(key, cfg, src)
        return cfg

    def recommend_batch(self, shapes: Sequence[Tuple[int, int, int]]
                        ) -> List[tcm.TPUTileConfig]:
        """Batch recommendation: one ADAPTNET forward for every uncached
        in-range shape, one vectorized oracle sweep for the rest — the O(1)
        runtime path the paper's hardware ADAPTNETX provides."""
        keys = [(int(M), int(K), int(N)) for M, K, N in shapes]
        out: List[Optional[tcm.TPUTileConfig]] = [None] * len(keys)
        net_idx, orc_idx = [], []
        seen = set()
        for i, key in enumerate(keys):
            if key in self._cache:
                self._hits += 1
                out[i] = self._cache[key]
                continue
            if key in seen:            # in-batch duplicate: the first
                self._hits += 1        # occurrence decides it (same
                continue               # bookkeeping as the scalar path)
            self._misses += 1
            seen.add(key)
            (net_idx if self.in_trained_range(*key) else orc_idx).append(i)
        if net_idx:
            feats = np.asarray([keys[i] for i in net_idx], np.int64)
            cids = np.argmax(logits_np(self.adaptnet_params, feats), -1)
            for i, cid in zip(net_idx, cids):
                self._commit(keys[i], tcm.TILE_CONFIGS[int(cid)], "adaptnet")
        if orc_idx:
            ms, ks, ns = zip(*(keys[i] for i in orc_idx))
            src = ("oracle_fallback" if self._adaptnet_active() else "oracle")
            cids = np.atleast_1d(tcm.best_tile_config(
                np.asarray(ms), np.asarray(ks), np.asarray(ns)))
            for i, cid in zip(orc_idx, cids):
                self._commit(keys[i], tcm.TILE_CONFIGS[int(cid)], src)
        return [out[i] if out[i] is not None else self._cache[keys[i]]
                for i in range(len(keys))]

    def _commit(self, key, cfg: tcm.TPUTileConfig, src: str) -> None:
        self._cache[key] = cfg
        self._sources[key] = src
        if src == "adaptnet":
            self._n_adaptnet += 1
        elif src == "oracle_fallback":
            self._n_fallback += 1
        else:
            self._n_oracle += 1

    def source_of(self, M: int, K: int, N: int) -> str:
        """Provenance of a cached recommendation: "adaptnet", "oracle", or
        "oracle_fallback" (adaptnet mode, shape outside the trained range)."""
        return self._sources.get((int(M), int(K), int(N)), "oracle")

    def cache_info(self) -> Dict[str, int]:
        """Recommendation-cache statistics (the serving engine reports the
        hit rate: a high rate means shape diversity stayed inside the O(1)
        lookup path)."""
        return {"hits": self._hits, "misses": self._misses,
                "size": len(self._cache)}

    def source_info(self) -> Dict[str, int]:
        """How many distinct shapes each recommendation source decided."""
        return {"adaptnet": self._n_adaptnet, "oracle": self._n_oracle,
                "oracle_fallback": self._n_fallback}

    def cache_clear(self) -> None:
        self._cache.clear()
        self._sources.clear()
        self._hits = self._misses = 0
        self._n_adaptnet = self._n_oracle = self._n_fallback = 0

    def recommend_sharding(self, M: int, K: int, N: int,
                           data: int = 16, model: int = 16) -> tcm.ShardPlan:
        return tcm.plan_gemm_sharding(M, K, N, data=data, model=model)

    # -- execution -----------------------------------------------------------
    def gemm(self, x: jnp.ndarray, w: jnp.ndarray, *,
             site: str = "sara.gemm") -> jnp.ndarray:
        """Self-adaptive GEMM: (..., M, K) @ (K, N), through the dispatch
        layer with this dispatcher active (``use_pallas`` selects the RSA
        Pallas kernel; otherwise XLA)."""
        from repro import dispatch
        with dispatch.use(self,
                          execute="pallas" if self.use_pallas else "xla"):
            return dispatch.gemm(x, w, site=site)


def train_adaptnet_tpu(n_samples: int = 150_000, epochs: int = 10,
                       seed: int = 0, log: bool = False):
    """Train ADAPTNET-TPU on the TPU tile-config space; returns
    (params, test_accuracy, geomean_rel_time)."""
    from repro.core import adaptnet as A
    from repro.core.dataset import Dataset, sample_workloads

    feats = sample_workloads(n_samples, dist="loguniform", seed=seed)
    labels = tcm.best_tile_config(feats[:, 0], feats[:, 1],
                                  feats[:, 2]).astype(np.int32)
    ds = Dataset(feats, labels, num_classes=tcm.NUM_TILE_CLASSES)
    tr, te = ds.split()
    res = A.train(tr, te, epochs=epochs, log=log)
    pred = A.predict(res.params, te.features)
    cost = tcm.tile_cost_seconds(te.features[:, 0], te.features[:, 1],
                                 te.features[:, 2])
    chosen = np.take_along_axis(cost, pred[:, None].astype(int), -1)[:, 0]
    rel = chosen / cost.min(-1)
    geomean = float(np.exp(np.mean(np.log(np.clip(rel, 1.0, None)))))
    return res.params, res.test_accuracy, geomean
