"""RECONFIGURABLE SYSTOLIC ARRAY (RSA) configuration space.

An RSA instance is a grid of `systolic-cells` (4x4 MAC sub-grids in SAGAR,
paper §II-B) with muxed bypass links.  A *configuration* is:

  (sub-array rows a, sub-array cols b, dataflow in {OS, WS, IS})

where (a, b) are measured in cells and must tile the cell grid evenly
(a | grid_rows, b | grid_cols) — the partition grid is then
(grid_rows/a) x (grid_cols/b) identical sub-arrays, every one of them
simultaneously active on a slice of the GEMM (paper Fig. 5d).

The paper reports 858 raw configurations for 2^14 MACs but never states the
enumeration rule; we use the clean even-tiling space (DESIGN.md §2.1):
108 classes at 2^14 MACs (6 x 6 x 3), 90 at 2^13, 75 at 2^12.  The learning
problem is isomorphic: one categorical class per (shape, dims, dataflow).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.core.hw import DATAFLOW_NAMES, IS, OS, WS

CELL = 4                                  # MACs per systolic-cell edge


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


@dataclass(frozen=True)
class RSAInstance:
    """A physical RSA: cell grid of (grid_rows x grid_cols) systolic-cells."""
    grid_rows: int
    grid_cols: int

    @property
    def num_macs(self) -> int:
        return self.grid_rows * self.grid_cols * CELL * CELL

    @property
    def rows(self) -> int:
        return self.grid_rows * CELL

    @property
    def cols(self) -> int:
        return self.grid_cols * CELL


@dataclass(frozen=True)
class RSAConfig:
    """One runtime configuration (= one ADAPTNET output class)."""
    class_id: int
    sub_rows: int          # sub-array height in MACs
    sub_cols: int          # sub-array width in MACs
    part_rows: int         # partition grid height
    part_cols: int         # partition grid width
    dataflow: int          # OS | WS | IS

    @property
    def num_partitions(self) -> int:
        return self.part_rows * self.part_cols

    def describe(self) -> str:
        return (f"{self.part_rows}x{self.part_cols} grid of "
                f"{self.sub_rows}x{self.sub_cols} arrays, "
                f"{DATAFLOW_NAMES[self.dataflow]}")


def make_instance(num_macs: int) -> RSAInstance:
    """Cell grid for a power-of-two MAC budget (squarish, SAGAR layout)."""
    cells = num_macs // (CELL * CELL)
    import math
    r = 2 ** (int(math.log2(cells)) // 2)
    c = cells // r
    if c < r:
        r, c = c, r
    return RSAInstance(r, c)


SAGAR_INSTANCE = RSAInstance(32, 32)      # 2^14 MACs, paper §IV-B


def enumerate_configs(inst: RSAInstance) -> List[RSAConfig]:
    cfgs: List[RSAConfig] = []
    cid = 0
    for a in _divisors(inst.grid_rows):
        for b in _divisors(inst.grid_cols):
            for df in (OS, WS, IS):
                cfgs.append(RSAConfig(
                    class_id=cid,
                    sub_rows=a * CELL, sub_cols=b * CELL,
                    part_rows=inst.grid_rows // a,
                    part_cols=inst.grid_cols // b,
                    dataflow=df))
                cid += 1
    return cfgs


def config_table(inst: RSAInstance) -> dict:
    """Vectorized columns for the cost model: arrays of shape (n_configs,)."""
    cfgs = enumerate_configs(inst)
    return {
        "R": np.array([c.sub_rows for c in cfgs]),
        "C": np.array([c.sub_cols for c in cfgs]),
        "p": np.array([c.part_rows for c in cfgs]),
        "q": np.array([c.part_cols for c in cfgs]),
        "df": np.array([c.dataflow for c in cfgs]),
        "configs": cfgs,
    }
