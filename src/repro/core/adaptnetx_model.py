"""ADAPTNETX cycle/cost models (paper §IV-A, Fig. 9a).

Two ways to run ADAPTNET inference in hardware:

1. On `systolic-cells` borrowed from the main array: each dense layer is a
   GEMV on an r x c systolic sub-array (WS folds + skew fill).  The paper's
   best point: 1134 cycles at 1024 multipliers (64 cells).

2. On ADAPTNETX — a dedicated 1-D multiplier row + binary-tree reduction,
   input-stationary: the input vector is pinned at the multipliers, weight
   matrix rows stream through at 1 row/cycle/unit.  The paper's best point:
   576 cycles at 512 multipliers (2 units).

These closed forms land on the paper's numbers with no tuning beyond the
lookup-overhead constant (embedding + argmax + control ~ a few tens of
cycles).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple

from repro.core.adaptnet import EMBED_DIM, HIDDEN

LOOKUP_CYCLES = 12          # 3 embedding row fetches (SRAM) + concat control
ARGMAX_CYCLES_PER_8 = 1     # comparator tree on the output vector


def adaptnet_layer_dims(num_classes: int) -> List[Tuple[int, int]]:
    return [(3 * EMBED_DIM, HIDDEN), (HIDDEN, num_classes)]


def cycles_on_systolic_cells(num_multipliers: int, num_classes: int,
                             cell: int = 4) -> int:
    """GEMV on a square-ish array of 4x4 systolic cells, WS dataflow."""
    cells = max(1, num_multipliers // (cell * cell))
    r_cells = 2 ** (int(math.log2(cells)) // 2)
    c_cells = cells // r_cells
    R, C = r_cells * cell, c_cells * cell
    total = LOOKUP_CYCLES
    for din, dout in adaptnet_layer_dims(num_classes):
        folds = math.ceil(din / R) * math.ceil(dout / C)
        # per fold: preload R rows of weights, stream 1 input row + skew
        total += folds * (R + C + 1 - 1)
    total += math.ceil(num_classes / 8) * ARGMAX_CYCLES_PER_8
    return total


def cycles_on_adaptnetx(num_multipliers: int, num_classes: int,
                        units: int = 2) -> int:
    """1-D IS units with binary-tree reduction (paper Fig. 9b)."""
    m_per_unit = max(1, num_multipliers // units)
    total = LOOKUP_CYCLES
    for din, dout in adaptnet_layer_dims(num_classes):
        chunks = math.ceil(din / m_per_unit)      # passes over the input vec
        tree = math.ceil(math.log2(min(din, m_per_unit))) + 1
        # one output/cycle/unit sustained; fill = tree depth
        total += math.ceil(dout / units) * chunks + tree
    total += math.ceil(num_classes / 8) * ARGMAX_CYCLES_PER_8
    return total


@dataclass
class AdaptNetXDesign:
    num_multipliers: int = 512
    units: int = 2
    sram_kb: int = 512           # embedding tables + weights (paper §IV-B)

    def cycles(self, num_classes: int) -> int:
        return cycles_on_adaptnetx(self.num_multipliers, num_classes,
                                   self.units)

    def model_bytes(self, num_classes: int) -> int:
        """1 byte/weight (int8): tables dominate (paper footnote 1)."""
        from repro.core.adaptnet import VOCAB
        table = 3 * VOCAB * EMBED_DIM
        dense = (3 * EMBED_DIM) * HIDDEN + HIDDEN * num_classes
        return table + dense


def sweep_multipliers(num_classes: int, points=(64, 128, 256, 512, 1024, 2048)):
    return {
        "systolic_cells": {m: cycles_on_systolic_cells(m, num_classes)
                           for m in points},
        "adaptnetx": {m: cycles_on_adaptnetx(m, num_classes)
                      for m in points},
    }
