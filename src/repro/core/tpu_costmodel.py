"""TPU-native analogue of the RSA configuration space (DESIGN.md §2).

The MXU is a 128x128 systolic array; the runtime-reconfigurable knobs on a
TPU GEMM are the Pallas BlockSpec tiling (block_m, block_n, block_k) and the
residency mode (which operand's tile stays pinned in VMEM while the others
stream — the dataflow analogue):

  OS: C tile resident, K streamed     traffic = MK*Nt + KN*Mt + MN
  WS: B tile resident, M streamed     traffic = KN + MK*Nt + MN*(2Kt-1)
  IS: A tile resident, N streamed     traffic = MK + KN*Mt + MN*(2Kt-1)

(Xt = number of tiles along X.)  Cost = max(compute, memory) under MXU
alignment padding; configs whose working set exceeds VMEM are infeasible.
The best config is workload-dependent in exactly the way the paper's Fig. 7c
shows for the RSA — ADAPTNET-TPU learns this space (core/sara.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.core.hw import IS, OS, TPU_V5E, WS

BLOCK_MN = (128, 256, 512)
BLOCK_K = (128, 256, 512, 1024, 2048)
DTYPE_BYTES = 2            # bf16


@dataclass(frozen=True)
class TPUTileConfig:
    class_id: int
    block_m: int
    block_n: int
    block_k: int
    mode: int              # OS | WS | IS

    def describe(self) -> str:
        from repro.core.hw import DATAFLOW_NAMES
        return (f"bm={self.block_m} bn={self.block_n} bk={self.block_k} "
                f"{DATAFLOW_NAMES[self.mode]}")


def enumerate_tile_configs() -> List[TPUTileConfig]:
    out = []
    cid = 0
    for bm in BLOCK_MN:
        for bn in BLOCK_MN:
            for bk in BLOCK_K:
                for mode in (OS, WS, IS):
                    out.append(TPUTileConfig(cid, bm, bn, bk, mode))
                    cid += 1
    return out


TILE_CONFIGS = enumerate_tile_configs()
NUM_TILE_CLASSES = len(TILE_CONFIGS)


def _cols():
    return (np.array([c.block_m for c in TILE_CONFIGS]),
            np.array([c.block_n for c in TILE_CONFIGS]),
            np.array([c.block_k for c in TILE_CONFIGS]),
            np.array([c.mode for c in TILE_CONFIGS]))


def tile_cost_seconds(M, K, N) -> np.ndarray:
    """(workloads..., n_configs) estimated per-chip GEMM time."""
    bm, bn, bk, mode = _cols()
    M = np.asarray(M, np.float64)[..., None]
    K = np.asarray(K, np.float64)[..., None]
    N = np.asarray(N, np.float64)[..., None]

    Mt = np.ceil(M / bm)
    Nt = np.ceil(N / bn)
    Kt = np.ceil(K / bk)
    # compute with padding to full tiles (MXU runs whole blocks)
    flops = 2.0 * (Mt * bm) * (Nt * bn) * (Kt * bk)
    t_compute = flops / TPU_V5E.peak_bf16_flops

    traffic_os = M * K * Nt + K * N * Mt + M * N
    traffic_ws = K * N + M * K * Nt + M * N * (2 * Kt - 1)
    traffic_is = M * K + K * N * Mt + M * N * (2 * Kt - 1)
    traffic = np.where(mode == OS, traffic_os,
                       np.where(mode == WS, traffic_ws, traffic_is))
    t_mem = traffic * DTYPE_BYTES / TPU_V5E.hbm_bw

    # VMEM feasibility: resident + streaming double-buffers
    vmem = (bm * bk + bk * bn + bm * bn) * 2 * DTYPE_BYTES
    feasible = vmem <= TPU_V5E.vmem_bytes
    t = np.maximum(t_compute, t_mem)
    return np.where(feasible, t, np.inf)


def best_tile_config(M, K, N) -> np.ndarray:
    """Argmin with a deterministic physical tie-break: the max(compute, mem)
    roofline plateaus across many tilings for small GEMMs, so near-ties
    (within 1%) prefer fewer grid launches, then larger K blocks (less
    accumulator churn) — the same rule a human kernel engineer applies."""
    bm, bn, bk, _ = _cols()
    t = tile_cost_seconds(M, K, N)
    Mb = np.asarray(M, np.float64)[..., None]
    Nb = np.asarray(N, np.float64)[..., None]
    grid = np.ceil(Mb / bm) * np.ceil(Nb / bn)
    grid = grid / grid.max()
    key = t * (1.0 + 0.01 * grid + 1e-4 * (1.0 - bk / max(BLOCK_K)))
    return np.argmin(key, axis=-1)


# ---------------------------------------------------------------------------
# distributed GEMM sharding planner (mesh-level "configuration")
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardPlan:
    name: str
    x_spec: tuple              # PartitionSpec entries for x (M, K)
    w_spec: tuple              # for w (K, N)
    out_spec: tuple            # for out (M, N)
    comm_bytes: float
    time_s: float


def plan_gemm_sharding(M: int, K: int, N: int, *, data: int = 16,
                       model: int = 16) -> ShardPlan:
    """Pick the lowest-latency sharding for out = x @ w on a (data, model)
    mesh: {replicated, row(M/data), col(N/model), 2D, k-sharded+AR}."""
    chips = data * model
    peak = TPU_V5E.peak_bf16_flops
    link = TPU_V5E.ici_link_bw
    b = DTYPE_BYTES
    flops = 2.0 * M * N * K
    # ICI collective latency floor (~1 us/hop) + SPMD dispatch overhead:
    # this is what makes tiny GEMMs prefer replication over sharding.
    LAT = 2e-6

    cands = []
    # replicated: no comm, no parallelism
    cands.append(ShardPlan("replicated", (None, None), (None, None),
                           (None, None), 0.0, flops / peak))
    # row-parallel: M over data (and pod): w replicated
    cands.append(ShardPlan("row_dp", ("data", None), (None, None),
                           ("data", None), 0.0,
                           flops / (peak * data) + LAT))
    # col-parallel: N over model; out gathered (all-gather over model)
    ag = M * N * b
    cands.append(ShardPlan("col_tp", (None, None), (None, "model"),
                           (None, "model"), ag,
                           flops / (peak * model) + ag / (chips * link)
                           + 2 * LAT))
    # 2D: M over data, N over model
    cands.append(ShardPlan("2d", ("data", None), (None, "model"),
                           ("data", "model"), 0.0,
                           flops / (peak * chips) + 2 * LAT))
    # k-sharded over model + all-reduce of out
    ar = 2 * M * N * b
    cands.append(ShardPlan("k_model_ar", (None, "model"), ("model", None),
                           (None, None), ar,
                           flops / (peak * model) + ar / (chips * link)
                           + 2 * LAT))
    # fully sharded: M/data, K/model + all-reduce over model
    cands.append(ShardPlan("m_data_k_model_ar", ("data", "model"),
                           ("model", None), ("data", None), ar / data,
                           flops / (peak * chips) +
                           ar / data / (chips * link) + 2 * LAT))

    def feasible(p: ShardPlan) -> bool:
        if "data" in (p.x_spec[0], ) and M % data:
            return False
        if "model" in (p.x_spec[1], p.w_spec[0]) and K % model:
            return False
        if "model" in (p.w_spec[1],) and N % model:
            return False
        return True

    cands = [p for p in cands if feasible(p)] or cands[:1]
    return min(cands, key=lambda p: p.time_s)
