"""Classical classifier baselines for Fig. 7e (ADAPTNET vs. the field).

The paper compares SVCs, XGBoost and MLPs (scikit-learn / xgboost / keras).
Those packages are unavailable offline, so the comparison set is implemented
here in NumPy/JAX (DESIGN.md §2.1): kNN, multinomial logistic regression, a
plain MLP on log-features (no embeddings — isolates ADAPTNET's embedding
contribution), and a random-forest (the tree-ensemble stand-in for XGBoost).

All baselines receive log-scaled features — the representation most
favorable to them; ADAPTNET's advantage comes from per-integer embeddings
that can express the ceil-quantization cliffs of the config space.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataset import Dataset


def _logfeat(x: np.ndarray) -> np.ndarray:
    return np.log1p(x.astype(np.float64))


@dataclass
class BaselineResult:
    name: str
    accuracy: float
    train_seconds: float
    predict: Callable[[np.ndarray], np.ndarray]


# ---------------------------------------------------------------------------
# kNN
# ---------------------------------------------------------------------------

def knn(train: Dataset, test: Dataset, k: int = 5,
        max_train: int = 60_000) -> BaselineResult:
    t0 = time.time()
    rng = np.random.default_rng(0)
    idx = rng.permutation(len(train.labels))[:max_train]
    X = _logfeat(train.features[idx])
    y = train.labels[idx]
    mu, sd = X.mean(0), X.std(0) + 1e-9
    Xn = (X - mu) / sd

    Xj = jnp.asarray(Xn, jnp.float32)
    yj = jnp.asarray(y)

    @jax.jit
    def _pred(q):
        d = jnp.sum((Xj[None] - q[:, None]) ** 2, -1)
        _, nb = jax.lax.top_k(-d, k)
        votes = yj[nb]                                     # (B, k)
        onehot = jax.nn.one_hot(votes, train.num_classes).sum(1)
        return jnp.argmax(onehot, -1)

    def predict(feats: np.ndarray) -> np.ndarray:
        q = (_logfeat(feats) - mu) / sd
        out = []
        for lo in range(0, len(q), 512):
            out.append(np.asarray(_pred(jnp.asarray(q[lo:lo + 512],
                                                    jnp.float32))))
        return np.concatenate(out)

    acc = float(np.mean(predict(test.features) == test.labels))
    return BaselineResult("kNN-5", acc, time.time() - t0, predict)


# ---------------------------------------------------------------------------
# multinomial logistic regression (a linear SVC-class stand-in)
# ---------------------------------------------------------------------------

def logistic_regression(train: Dataset, test: Dataset, epochs: int = 30,
                        lr: float = 0.5) -> BaselineResult:
    t0 = time.time()
    X = _logfeat(train.features)
    mu, sd = X.mean(0), X.std(0) + 1e-9
    Xn = jnp.asarray((X - mu) / sd, jnp.float32)
    y = jnp.asarray(train.labels)
    C = train.num_classes
    W = jnp.zeros((X.shape[1], C))
    b = jnp.zeros((C,))

    @jax.jit
    def step(W, b):
        def loss(Wb):
            W_, b_ = Wb
            lg = Xn @ W_ + b_
            lse = jax.nn.logsumexp(lg, -1)
            gold = jnp.take_along_axis(lg, y[:, None], -1)[:, 0]
            return jnp.mean(lse - gold)
        g = jax.grad(loss)((W, b))
        return W - lr * g[0], b - lr * g[1]

    for _ in range(epochs):
        W, b = step(W, b)

    Wn, bn = np.asarray(W), np.asarray(b)

    def predict(feats: np.ndarray) -> np.ndarray:
        q = (_logfeat(feats) - mu) / sd
        return np.argmax(q @ Wn + bn, -1)

    acc = float(np.mean(predict(test.features) == test.labels))
    return BaselineResult("LogReg", acc, time.time() - t0, predict)


# ---------------------------------------------------------------------------
# plain MLP on log features (no embeddings)
# ---------------------------------------------------------------------------

def plain_mlp(train: Dataset, test: Dataset, hidden: Tuple[int, ...] = (128, 128),
              epochs: int = 20, batch: int = 1024,
              lr: float = 3e-3) -> BaselineResult:
    from repro.optim.adamw import AdamW, apply_updates
    t0 = time.time()
    X = _logfeat(train.features)
    mu, sd = X.mean(0), X.std(0) + 1e-9
    Xn = (X - mu) / sd
    y = train.labels
    C = train.num_classes
    key = jax.random.PRNGKey(0)
    sizes = (X.shape[1],) + hidden + (C,)
    params = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        params.append({
            "w": jax.random.normal(k, (sizes[i], sizes[i + 1])) /
                 np.sqrt(sizes[i]),
            "b": jnp.zeros((sizes[i + 1],))})

    def fwd(params, x):
        for i, layer in enumerate(params):
            x = x @ layer["w"] + layer["b"]
            if i < len(params) - 1:
                x = jax.nn.relu(x)
        return x

    opt = AdamW(lr=lr, weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, xb, yb):
        def loss(p):
            lg = fwd(p, xb)
            lse = jax.nn.logsumexp(lg, -1)
            gold = jnp.take_along_axis(lg, yb[:, None], -1)[:, 0]
            return jnp.mean(lse - gold)
        grads = jax.grad(loss)(params)
        updates, opt_state2, _ = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state2

    rng = np.random.default_rng(0)
    n = len(y)
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(n // batch):
            idx = order[s * batch:(s + 1) * batch]
            params, opt_state = step(params, opt_state,
                                     jnp.asarray(Xn[idx], jnp.float32),
                                     jnp.asarray(y[idx]))

    def predict(feats: np.ndarray) -> np.ndarray:
        q = jnp.asarray((_logfeat(feats) - mu) / sd, jnp.float32)
        return np.asarray(jnp.argmax(fwd(params, q), -1))

    acc = float(np.mean(predict(test.features) == test.labels))
    return BaselineResult("MLP(128,128)", acc, time.time() - t0, predict)


# ---------------------------------------------------------------------------
# random forest (axis-aligned CART, histogram splits) — XGBoost stand-in
# ---------------------------------------------------------------------------

class _Tree:
    __slots__ = ("feat", "thr", "left", "right", "leaf")

    def __init__(self):
        self.leaf = None


def _grow(X, y, C, depth, max_depth, min_leaf, rng) -> _Tree:
    node = _Tree()
    if depth >= max_depth or len(y) < 2 * min_leaf or \
            np.all(y == y[0]):
        node.leaf = np.bincount(y, minlength=C)
        return node
    best = (None, None, np.inf)
    counts = np.bincount(y, minlength=C).astype(np.float64)
    total_gini = 1.0 - np.sum((counts / len(y)) ** 2)
    feats = rng.choice(X.shape[1], size=X.shape[1], replace=False)
    for f in feats:
        xs = X[:, f]
        qs = np.quantile(xs, np.linspace(0.05, 0.95, 16))
        for thr in np.unique(qs):
            mask = xs <= thr
            nl = int(mask.sum())
            if nl < min_leaf or len(y) - nl < min_leaf:
                continue
            cl = np.bincount(y[mask], minlength=C).astype(np.float64)
            cr = counts - cl
            gl = 1.0 - np.sum((cl / max(nl, 1)) ** 2)
            gr = 1.0 - np.sum((cr / max(len(y) - nl, 1)) ** 2)
            g = (nl * gl + (len(y) - nl) * gr) / len(y)
            if g < best[2]:
                best = (f, thr, g)
    if best[0] is None or best[2] >= total_gini:
        node.leaf = np.bincount(y, minlength=C)
        return node
    f, thr, _ = best
    mask = X[:, f] <= thr
    node.feat, node.thr = f, thr
    node.left = _grow(X[mask], y[mask], C, depth + 1, max_depth, min_leaf, rng)
    node.right = _grow(X[~mask], y[~mask], C, depth + 1, max_depth, min_leaf,
                       rng)
    return node


def _tree_predict_counts(node: _Tree, X: np.ndarray, out: np.ndarray,
                         idx: np.ndarray):
    if node.leaf is not None:
        out[idx] += node.leaf / max(node.leaf.sum(), 1)
        return
    mask = X[idx, node.feat] <= node.thr
    _tree_predict_counts(node.left, X, out, idx[mask])
    _tree_predict_counts(node.right, X, out, idx[~mask])


def random_forest(train: Dataset, test: Dataset, n_trees: int = 12,
                  max_depth: int = 12, max_train: int = 40_000
                  ) -> BaselineResult:
    t0 = time.time()
    rng = np.random.default_rng(0)
    sel = rng.permutation(len(train.labels))[:max_train]
    X = _logfeat(train.features[sel])
    y = train.labels[sel].astype(np.int64)
    C = train.num_classes
    trees = []
    for t in range(n_trees):
        bs = rng.integers(0, len(y), len(y))
        trees.append(_grow(X[bs], y[bs], C, 0, max_depth, 8,
                           np.random.default_rng(t)))

    def predict(feats: np.ndarray) -> np.ndarray:
        Xq = _logfeat(feats)
        probs = np.zeros((len(Xq), C))
        for tree in trees:
            _tree_predict_counts(tree, Xq, probs, np.arange(len(Xq)))
        return np.argmax(probs, -1)

    acc = float(np.mean(predict(test.features) == test.labels))
    return BaselineResult(f"RandomForest-{n_trees}", acc,
                          time.time() - t0, predict)


def run_all(train: Dataset, test: Dataset) -> Dict[str, BaselineResult]:
    out = {}
    for fn in (logistic_regression, knn, plain_mlp, random_forest):
        r = fn(train, test)
        out[r.name] = r
    return out
