"""Evaluation workloads (paper §V-A): FasterRCNN, DeepSpeech2, AlphaGoZero
layer GEMMs + the synthetic G1..G20 set (Table IV).

Conv layers are given as im2col GEMMs: M = out_h*out_w, N = filters,
K = kh*kw*c_in (batch 1, SCALE-Sim convention).  The layer lists are
reconstructed from the public network topologies (SCALE-Sim topology-file
style); the paper does not publish its exact CSVs, so dims are documented
approximations of the same networks (DESIGN.md §2.1).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class Layer:
    name: str
    M: int
    K: int
    N: int


def _conv(name, oh, ow, kh, kw, cin, cout) -> Layer:
    return Layer(name, oh * ow, kh * kw * cin, cout)


# ---- AlphaGoZero: 19x19 board, 256-filter residual tower [36] -------------
def alphagozero() -> List[Layer]:
    layers = [_conv("conv_in", 19, 19, 3, 3, 17, 256)]
    for i in range(19):
        layers.append(_conv(f"res{i}", 19, 19, 3, 3, 256, 256))
    layers.append(_conv("policy", 19, 19, 1, 1, 256, 2))
    layers.append(_conv("value", 19, 19, 1, 1, 256, 1))
    return layers


# ---- DeepSpeech2: conv frontend + bidirectional GRU stack [2] -------------
def deepspeech2(T: int = 341) -> List[Layer]:
    # conv1 41x11x1 -> 32, conv2 21x11x32 -> 32 on (time x freq) = (341 x 40)
    layers = [
        _conv("conv1", T, 40, 41, 11, 1, 32),
        _conv("conv2", T, 20, 21, 11, 32, 32),
    ]
    d = 1312      # flattened conv features entering the RNN stack
    h = 1760      # DS2 hidden size
    for i in range(5):
        din = d if i == 0 else h
        # GRU as GEMMs: input proj (3h) + recurrent proj (3h)
        layers.append(Layer(f"gru{i}_x", T, din, 3 * h))
        layers.append(Layer(f"gru{i}_h", T, h, 3 * h))
    layers.append(Layer("fc", T, h, 29))
    return layers


# ---- FasterRCNN: VGG-16 backbone + RPN + heads [31] ------------------------
def fasterrcnn() -> List[Layer]:
    L: List[Layer] = []
    cfg = [  # (out_hw, cin, cout, repeat)
        (224, 3, 64, 1), (224, 64, 64, 1),
        (112, 64, 128, 1), (112, 128, 128, 1),
        (56, 128, 256, 1), (56, 256, 256, 2),
        (28, 256, 512, 1), (28, 512, 512, 2),
        (14, 512, 512, 3),
    ]
    i = 0
    for hw, cin, cout, rep in cfg:
        for _ in range(rep):
            i += 1
            L.append(_conv(f"conv{i}", hw, hw, 3, 3, cin, cout))
    L.append(_conv("rpn_conv", 14, 14, 3, 3, 512, 512))       # layer 14
    L.append(_conv("rpn_cls", 14, 14, 1, 1, 512, 18))
    L.append(_conv("rpn_box", 14, 14, 1, 1, 512, 36))
    L.append(Layer("fc6", 300, 25088, 4096))                  # 300 RoIs
    L.append(Layer("fc7", 300, 4096, 4096))                   # "layer 19"
    L.append(Layer("cls_score", 300, 4096, 21))
    L.append(Layer("bbox_pred", 300, 4096, 84))
    return L


# ---- sensitivity-analysis networks (Fig. 11f-g) ----------------------------
def resnet50() -> List[Layer]:
    L = [_conv("conv1", 112, 112, 7, 7, 3, 64)]
    spec = [(56, 64, 64, 256, 3), (28, 256, 128, 512, 4),
            (14, 512, 256, 1024, 6), (7, 1024, 512, 2048, 3)]
    i = 1
    for hw, cin, mid, cout, rep in spec:
        for r in range(rep):
            i += 1
            L.append(_conv(f"b{i}a", hw, hw, 1, 1, cin if r == 0 else cout, mid))
            L.append(_conv(f"b{i}b", hw, hw, 3, 3, mid, mid))
            L.append(_conv(f"b{i}c", hw, hw, 1, 1, mid, cout))
    L.append(Layer("fc", 1, 2048, 1000))
    return L


def bert_base(S: int = 512) -> List[Layer]:
    d, h, ff = 768, 12, 3072
    L = []
    for i in range(12):
        L.append(Layer(f"l{i}_qkv", S, d, 3 * d))
        L.append(Layer(f"l{i}_attn_qk", S, d // h, S))   # per-head scores
        L.append(Layer(f"l{i}_attn_v", S, S, d // h))
        L.append(Layer(f"l{i}_o", S, d, d))
        L.append(Layer(f"l{i}_ff1", S, d, ff))
        L.append(Layer(f"l{i}_ff2", S, ff, d))
    return L


# ---- synthetic GEMMs, Table IV ---------------------------------------------
def synthetic_g() -> List[Layer]:
    dims: List[Tuple[int, int, int]] = [
        (128, 128, 128), (256, 256, 256), (512, 512, 512),
        (1024, 1024, 1024), (2048, 2048, 2048),
        (128, 64, 64), (256, 64, 64), (512, 64, 64),
        (1024, 64, 64), (2048, 64, 64),
        (64, 64, 128), (64, 64, 256), (64, 64, 512),
        (64, 64, 1024), (64, 64, 2048),
        (64, 128, 64), (64, 256, 64), (64, 512, 64),
        (64, 1024, 64), (64, 2048, 64),
    ]
    return [Layer(f"G{i+1}", m, k, n) for i, (m, k, n) in enumerate(dims)]


WORKLOADS = {
    "alphagozero": alphagozero,
    "deepspeech2": deepspeech2,
    "fasterrcnn": fasterrcnn,
    "resnet50": resnet50,
    "bert_base": bert_base,
    "synthetic": synthetic_g,
}


def layer_dims(layers: List[Layer]) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    M = np.array([l.M for l in layers])
    K = np.array([l.K for l in layers])
    N = np.array([l.N for l in layers])
    return M, K, N
