"""AdamW + schedules + global-norm clipping (no optax offline).

Optimizer state is a pytree shaped like params (moments inherit the
parameter sharding => ZeRO-3 with FSDP param specs).  Moment dtype comes from
ArchConfig.opt_state_dtype (bf16 for the 671B config — DESIGN.md §2.1).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        t = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


class AdamW:
    def __init__(self, lr: Callable | float = 3e-4, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0,
                 state_dtype: str = "float32"):
        self.lr = lr if callable(lr) else (lambda _: jnp.float32(lr))
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm
        self.state_dtype = jnp.dtype(state_dtype)

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
        step = state.step + 1
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        b1, b2 = self.b1, self.b2
        sd = self.state_dtype

        m_new = jax.tree_util.tree_map(
            lambda g, m: (b1 * m.astype(jnp.float32) +
                          (1 - b1) * g.astype(jnp.float32)).astype(sd),
            grads, state.m)
        v_new = jax.tree_util.tree_map(
            lambda g, v: (b2 * v.astype(jnp.float32) +
                          (1 - b2) * jnp.square(g.astype(jnp.float32))
                          ).astype(sd),
            grads, state.v)

        lr = self.lr(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def delta(p, m, v):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v.astype(jnp.float32) / bc2
            d = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:      # decoupled weight decay on matrices only
                d = d + self.weight_decay * p.astype(jnp.float32)
            return (-lr * d).astype(p.dtype)

        updates = jax.tree_util.tree_map(delta, params, m_new, v_new)
        new_state = AdamWState(step=step, m=m_new, v=v_new)
        return updates, new_state, {"grad_norm": gnorm, "lr": lr}


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)


def sgd_momentum(lr: float = 0.1, momentum: float = 0.9):
    """Tiny SGD for the ADAPTNET trainers/tests."""

    class SGD:
        def init(self, params):
            return AdamWState(
                step=jnp.zeros((), jnp.int32),
                m=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params),
                v=None)

        def update(self, grads, state, params):
            m = jax.tree_util.tree_map(
                lambda g, m_: momentum * m_ + g, grads, state.m)
            updates = jax.tree_util.tree_map(lambda m_: -lr * m_, m)
            return updates, AdamWState(state.step + 1, m, None), {}

    return SGD()
