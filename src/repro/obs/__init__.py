"""Serving observability: request-lifecycle spans, engine-step timeline,
dispatch / compile / KV-arena event tracing, Perfetto export.

  trace    TraceRecorder (ring-buffered events + always-on counters and
           gauges), JitWatch (compile/retrace detection on jitted calls)
  spans    RequestTracker (per-request lifecycle state machine with
           close-exactly-once invariants), StepTimeline (per-step phase
           breakdown)
  export   Chrome/Perfetto trace-event JSON + structured JSONL writers
           and the trace schema validator the CI smoke runs

Everything funnels into one :class:`TraceRecorder` owned by the
``ServingEngine`` (``EngineConfig.trace`` / ``serve.py --trace-out``);
see docs/OBSERVABILITY.md for the event taxonomy and how to open a
trace in Perfetto.
"""

from repro.obs.export import (to_chrome_trace, validate_trace,
                              write_chrome_trace, write_jsonl)
from repro.obs.spans import RequestTracker, StepTimeline
from repro.obs.trace import (CATEGORIES, REQUIRED_CATEGORIES, JitWatch,
                             TraceError, TraceEvent, TraceRecorder)

__all__ = ["CATEGORIES", "REQUIRED_CATEGORIES", "JitWatch", "TraceError",
           "TraceEvent", "TraceRecorder", "RequestTracker", "StepTimeline",
           "to_chrome_trace", "validate_trace", "write_chrome_trace",
           "write_jsonl"]
