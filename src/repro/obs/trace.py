"""Low-overhead event tracing for the serving stack.

The :class:`TraceRecorder` is the single sink every layer reports into:

  * **counters** (monotonic) and **gauges** (last-value) are always on —
    a dict update per call, cheap enough for the hot loop regardless of
    whether span recording is enabled;
  * **events** (Perfetto-style slices and instants) land in a bounded
    ring buffer only when ``spans`` is enabled (``EngineConfig.trace`` /
    ``--trace-out``), so a production engine with tracing off pays one
    branch per would-be event.

Every event carries a *category* from :data:`CATEGORIES`:

  ``request``   per-request lifecycle spans (queue / active / prefill
                chunks / first token) — see ``obs/spans.py``
  ``step``      engine-step timeline with phase breakdown (schedule /
                prefill / decode / sample / sync)
  ``dispatch``  GEMM-site resolution at trace time (site, (M,K,N), chosen
                tile, recommendation source, analytic cost) plus per-call
                wall time of each traced scope
  ``compile``   a jit cache gained an entry (a retrace) — the raw signal
                behind width-bucket / shape-diversity retrace storms
  ``arena``     KV block pool traffic (reserve / grow / free / defrag)
  ``fault``     chaos-harness injections and step-level containment
                (``serving/faults.py``) — absent from healthy runs, so
                trace validation requires only :data:`REQUIRED_CATEGORIES`

Timestamps are wall seconds relative to recorder construction
(``time.perf_counter`` — monotonic, so step-phase slices never overlap or
run backwards even if the system clock steps).  ``obs/export.py`` turns
the buffer into Chrome/Perfetto trace-event JSON and structured JSONL.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

CATEGORIES = ("request", "step", "dispatch", "compile", "arena", "fault")

# The categories every healthy serve trace must contain.  "fault" events
# only exist when chaos injection or step-level containment actually
# fired, so the CI trace gate (scripts/check_trace.py) and tests require
# this subset, not CATEGORIES.
REQUIRED_CATEGORIES = ("request", "step", "dispatch", "compile", "arena")

# The closed taxonomy of step-timeline phases and metric series.  Export
# validation (obs/export.py) enforces CATEGORIES at runtime; saralint's
# obs-taxonomy pass enforces all four tuples statically at every
# recorder call site, so a typo'd literal fails CI instead of silently
# creating an orphan series.
STEP_PHASES = ("schedule", "prefill", "prefill_chunk", "decode",
               "paged_decode", "spec_draft", "spec_verify", "sample",
               "sync")

COUNTERS = ("jit_compiles", "dispatch_records", "kv_defrag_auto",
            "shared_prefix_steps", "prefix_cache_inserted_pages",
            "prefix_cache_evicted_pages", "kv_sanitize_checks",
            "kv_poison_hits", "kv_generation_faults",
            # fault tolerance (serving/faults.py + the engine's step
            # error boundary): injections, containments, engine-level
            # step retries, terminal request outcomes, snapshots
            "faults_injected", "faults_contained", "engine_step_retries",
            "preempt_budget_exhausted", "prefix_cache_fallbacks",
            "requests_failed", "requests_expired", "requests_shed",
            "requests_cancelled", "requests_rejected",
            "engine_snapshots", "engine_restores",
            # speculative decoding (serving/spec_decode.py): verify
            # steps taken, draft tokens proposed/accepted, bonus tokens
            # committed from the verify argmax, draft-pool preemptions
            "spec_steps", "spec_drafted_tokens", "spec_accepted_tokens",
            "spec_bonus_tokens", "spec_draft_preempts")

GAUGES = ("kv_pages_in_use", "kv_fragmentation", "slot_occupancy",
          "decode_table_width", "shared_prefix_lanes",
          "spec_accepted_per_step")

# Perfetto phase codes used by the export ("X" complete slice with a
# duration, "i" instant, "C" counter sample)
PH_SLICE, PH_INSTANT, PH_COUNTER = "X", "i", "C"


class TraceError(RuntimeError):
    """A lifecycle invariant was violated (e.g. a span closed twice)."""


@dataclass
class TraceEvent:
    """One trace event.  ``ts``/``dur`` are seconds on the recorder's
    monotonic clock; ``track`` names the Perfetto row the event renders
    on (the export maps tracks to tids)."""

    cat: str
    name: str
    ph: str = PH_INSTANT
    ts: float = 0.0
    dur: float = 0.0
    track: str = "engine"
    args: Dict[str, Any] = field(default_factory=dict)


class _Span:
    """Context manager measuring one slice; created by ``TraceRecorder.span``."""

    __slots__ = ("_rec", "_ev")

    def __init__(self, rec: "TraceRecorder", ev: Optional[TraceEvent]):
        self._rec = rec
        self._ev = ev

    def __enter__(self) -> "_Span":
        if self._ev is not None:
            self._ev.ts = self._rec.now()
        return self

    def __exit__(self, *exc) -> None:
        if self._ev is not None:
            self._ev.dur = self._rec.now() - self._ev.ts
            self._rec._append(self._ev)


class TraceRecorder:
    """Ring-buffered event sink + always-on counters/gauges.

    ``capacity`` bounds the event buffer (oldest events drop first;
    ``dropped`` counts them so an export can say it is a suffix).  With
    ``spans=False`` (the default in production) ``emit``/``span``/
    ``instant`` are no-ops and only counters/gauges accrue.
    """

    def __init__(self, capacity: int = 65536, spans: bool = False):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.spans = spans
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        # per-scope wall-clock accumulation for the dispatch layer: the
        # measured-runtime side of profile-calibrated dispatch
        self.scope_wall: Dict[str, List[float]] = {}   # scope -> [calls, s]
        self.dropped = 0
        self._events: deque = deque(maxlen=capacity)
        self._t0 = time.perf_counter()

    # -- clock ---------------------------------------------------------------
    def now(self) -> float:
        return time.perf_counter() - self._t0

    # -- always-on telemetry -------------------------------------------------
    def count(self, name: str, delta: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + delta

    def gauge(self, name: str, value: float, track: str = "gauges") -> None:
        """Record a sampled value; also emits a Perfetto counter event when
        span recording is on (one counter row per gauge name)."""
        self.gauges[name] = value
        if self.spans:
            self._append(TraceEvent("step", name, PH_COUNTER, self.now(),
                                    0.0, track, {"value": value}))

    def add_scope_wall(self, scope: str, seconds: float) -> None:
        """Attribute one traced-scope call's wall time (always on — this is
        the per-site measured timing profile-calibrated dispatch needs)."""
        cell = self.scope_wall.setdefault(scope, [0, 0.0])
        cell[0] += 1
        cell[1] += seconds

    # -- events (span recording) ---------------------------------------------
    def _append(self, ev: TraceEvent) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    def emit(self, cat: str, name: str, ph: str = PH_INSTANT,
             ts: Optional[float] = None, dur: float = 0.0,
             track: str = "engine", **args) -> None:
        if not self.spans:
            return
        self._append(TraceEvent(cat, name, ph,
                                self.now() if ts is None else ts,
                                dur, track, args))

    def instant(self, cat: str, name: str, track: str = "engine",
                **args) -> None:
        self.emit(cat, name, PH_INSTANT, track=track, **args)

    def slice(self, cat: str, name: str, ts: float, dur: float,
              track: str = "engine", **args) -> None:
        """A completed slice whose endpoints were measured by the caller."""
        self.emit(cat, name, PH_SLICE, ts=ts, dur=dur, track=track, **args)

    def span(self, cat: str, name: str, track: str = "engine",
             **args) -> _Span:
        """``with rec.span("step", "decode"): ...`` — measures the block's
        wall time and emits one slice (no-op when spans are off)."""
        if not self.spans:
            return _Span(self, None)
        return _Span(self, TraceEvent(cat, name, PH_SLICE, 0.0, 0.0,
                                      track, args))

    # -- read-back -----------------------------------------------------------
    def events(self, cat: Optional[str] = None) -> List[TraceEvent]:
        if cat is None:
            return list(self._events)
        return [e for e in self._events if e.cat == cat]

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self) -> Iterator[TraceEvent]:
        return iter(self._events)

    def clear(self) -> None:
        self._events.clear()
        self.counters.clear()
        self.gauges.clear()
        self.scope_wall.clear()
        self.dropped = 0


class JitWatch:
    """Wrap a jitted callable and emit a ``compile`` event whenever a call
    creates a new executable (a retrace) — the per-step visibility that
    makes width-bucket / shape-diversity retrace storms diagnosable the
    step they fire instead of via wall-time archaeology.

    Uses the jit cache size when the wrapped function exposes it
    (``_cache_size``); otherwise falls back to tracking distinct abstract
    argument signatures.  The ``jit_compiles`` counter is always on; the
    event (with the call's array shapes) lands in the buffer only when
    span recording is enabled.
    """

    def __init__(self, fn, name: str, rec: TraceRecorder):
        self.fn = fn
        self.name = name
        self.rec = rec
        self._sigs: set = set()
        self._probe = getattr(fn, "_cache_size", None)

    @staticmethod
    def _shapes(args: Tuple[Any, ...], limit: int = 8) -> List[str]:
        """Compact shape summary of the call's array leaves (first
        ``limit`` distinct shapes — enough to identify the retrace)."""
        import jax
        out: List[str] = []
        for leaf in jax.tree_util.tree_leaves(args):
            s = getattr(leaf, "shape", None)
            if s is None:
                continue
            d = "x".join(str(int(x)) for x in s) or "scalar"
            if d not in out:
                out.append(d)
                if len(out) >= limit:
                    break
        return out

    def _entries(self) -> int:
        return int(self._probe()) if self._probe is not None else len(self._sigs)

    def __call__(self, *args):
        if self._probe is None:
            import jax
            self._sigs.add(tuple(
                (getattr(a, "shape", None), str(getattr(a, "dtype", type(a))))
                for a in jax.tree_util.tree_leaves(args)))
        before = self._entries()
        t0 = time.perf_counter()
        out = self.fn(*args)
        if self._entries() > before:
            self.rec.count("jit_compiles")
            self.rec.count(f"jit_compiles.{self.name}")
            self.rec.emit("compile", f"compile:{self.name}", PH_SLICE,
                          ts=self.rec.now() - (time.perf_counter() - t0),
                          dur=time.perf_counter() - t0, track="compile",
                          fn=self.name, shapes=self._shapes(args),
                          cache_entries=self._entries())
        return out
