"""Request-lifecycle span tracking with close-exactly-once invariants.

A request's life is a tree of spans on its own trace track
(``req:<rid>``):

    request ─┬─ queue      submit -> admit           (re-opens on preempt)
             ├─ active     admit -> retire | preempt
             │    ├─ prefill_chunk  (one slice per streamed chunk)
             │    └─ first_token    (instant)
             └─ ... (queue/active repeat per preempt -> readmit cycle)

The tracker is a small state machine (``queued`` -> ``active`` ->
``done``, with ``active`` -> ``queued`` on preemption) that makes the
ISSUE's invariant structural: the root span closes exactly once, at
retirement, no matter how many preempt/readmit cycles happened in
between; closing twice or transitioning illegally raises
:class:`~repro.obs.trace.TraceError` instead of silently corrupting the
trace.  State bookkeeping is always on (it is a dict update per
transition); the emitted slices obey the recorder's ``spans`` toggle.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.obs.trace import PH_SLICE, TraceError, TraceRecorder
from repro.obs.trace import TraceEvent

QUEUED, ACTIVE, DONE = "queued", "active", "done"


@dataclass
class _ReqState:
    state: str
    t_root: float          # root span open (submit)
    t_phase: float         # current queue/active span open
    preempts: int = 0
    chunks: int = 0


class RequestTracker:
    """Per-request lifecycle spans recorded by the engine/scheduler."""

    def __init__(self, rec: TraceRecorder):
        self.rec = rec
        self._live: Dict[str, _ReqState] = {}
        self.closed = 0                     # root spans closed (== retires)

    # -- helpers -------------------------------------------------------------
    def _track(self, rid: str) -> str:
        return f"req:{rid}"

    def _need(self, rid: str, *states: str) -> _ReqState:
        st = self._live.get(rid)
        if st is None:
            raise TraceError(f"request {rid}: no open span "
                             "(submit was never tracked, or already retired)")
        if st.state not in states:
            raise TraceError(f"request {rid}: invalid transition from "
                             f"{st.state!r} (expected one of {states})")
        return st

    def open_requests(self) -> Dict[str, str]:
        """rid -> state for every request whose root span is still open."""
        return {rid: st.state for rid, st in self._live.items()}

    # -- transitions ---------------------------------------------------------
    def on_submit(self, rid: str, **args) -> None:
        if rid in self._live:
            raise TraceError(f"request {rid}: submitted twice")
        now = self.rec.now()
        self._live[rid] = _ReqState(QUEUED, now, now)
        self.rec.instant("request", "submit", self._track(rid), rid=rid,
                         **args)

    def on_admit(self, rid: str, slot: int = -1, **args) -> None:
        st = self._need(rid, QUEUED)
        now = self.rec.now()
        self.rec.slice("request", "queue", st.t_phase, now - st.t_phase,
                       self._track(rid), rid=rid, readmit=st.preempts > 0)
        st.state, st.t_phase = ACTIVE, now
        self.rec.instant("request", "admit", self._track(rid), rid=rid,
                         slot=slot, **args)

    def on_prefill_chunk(self, rid: str, tokens: int, dur: float,
                         **args) -> None:
        st = self._need(rid, ACTIVE)
        st.chunks += 1
        self.rec.slice("request", "prefill_chunk", self.rec.now() - dur,
                       dur, self._track(rid), rid=rid, tokens=tokens, **args)

    def on_cache_hit(self, rid: str, **args) -> None:
        """Prefix-cache hit at admission: the request's first ``tokens``
        context tokens were mapped from cached pages instead of
        prefilled."""
        self._need(rid, ACTIVE)
        self.rec.instant("request", "cache_hit", self._track(rid),
                         rid=rid, **args)

    def on_first_token(self, rid: str, **args) -> None:
        self._need(rid, ACTIVE)
        self.rec.instant("request", "first_token", self._track(rid),
                         rid=rid, **args)

    def on_preempt(self, rid: str, **args) -> None:
        """Active -> queued: close the active span (outcome=preempt) and
        re-open the queue span — the root stays open across the cycle."""
        st = self._need(rid, ACTIVE)
        now = self.rec.now()
        st.preempts += 1
        self.rec.slice("request", "active", st.t_phase, now - st.t_phase,
                       self._track(rid), rid=rid, outcome="preempt", **args)
        st.state, st.t_phase = QUEUED, now

    def on_retire(self, rid: str, **args) -> None:
        """Close the active span and the root — exactly once per request."""
        st = self._need(rid, ACTIVE)
        now = self.rec.now()
        self.rec.slice("request", "active", st.t_phase, now - st.t_phase,
                       self._track(rid), rid=rid, outcome="retire")
        self.rec.slice("request", "request", st.t_root, now - st.t_root,
                       self._track(rid), rid=rid, preempts=st.preempts,
                       chunks=st.chunks, **args)
        del self._live[rid]
        self.closed += 1

    def on_finish(self, rid: str, outcome: str, reason: str = "",
                  **args) -> None:
        """Terminal-failure closure (failed / expired / shed / cancelled):
        close whatever phase span is open — ``queue`` for a request that
        never got a slot, ``active`` for one that did — then the root,
        exactly once, mirroring ``on_retire``'s invariant for the failure
        outcomes the fault boundary and deadline sweep produce."""
        st = self._need(rid, QUEUED, ACTIVE)
        now = self.rec.now()
        phase = "active" if st.state == ACTIVE else "queue"
        self.rec.slice("request", phase, st.t_phase, now - st.t_phase,
                       self._track(rid), rid=rid, outcome=outcome)
        self.rec.instant("request", outcome, self._track(rid), rid=rid,
                         reason=reason)
        self.rec.slice("request", "request", st.t_root, now - st.t_root,
                       self._track(rid), rid=rid, outcome=outcome,
                       preempts=st.preempts, chunks=st.chunks, **args)
        del self._live[rid]
        self.closed += 1


class StepTimeline:
    """Engine-step timeline: one root slice per step on the ``engine``
    track with sequential child phases (schedule / prefill / decode /
    sample / sync).  Phases are measured with the recorder's monotonic
    clock inside a single thread, so per-step phase slices are
    monotonic and non-overlapping by construction."""

    def __init__(self, rec: TraceRecorder):
        self.rec = rec
        self.steps = 0
        self._open: Optional[float] = None

    def begin(self) -> int:
        if self._open is not None:
            raise TraceError("step span already open")
        self._open = self.rec.now()
        return self.steps

    def phase(self, name: str, **args):
        """``with tl.phase("decode"): ...`` — one child slice."""
        if self._open is None:
            raise TraceError("phase() outside an open step")
        return self.rec.span("step", name, track="engine", step=self.steps,
                             **args)

    def end(self, **args) -> None:
        if self._open is None:
            raise TraceError("step span not open")
        now = self.rec.now()
        if self.rec.spans:
            # root emitted after its children; the export sorts by ts so
            # Perfetto still nests the phases underneath it
            self.rec._append(TraceEvent(
                "step", "engine_step", PH_SLICE, self._open,
                now - self._open, "engine", {"step": self.steps, **args}))
        self._open = None
        self.steps += 1
