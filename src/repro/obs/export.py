"""Trace export: Chrome/Perfetto trace-event JSON + structured JSONL.

``to_chrome_trace`` renders a :class:`~repro.obs.trace.TraceRecorder`
buffer in the Chrome trace-event format (the JSON flavour Perfetto and
``chrome://tracing`` both load — see docs/OBSERVABILITY.md for how to
open one).  Conventions:

  * one process (pid 1, named "serving"); each distinct event ``track``
    becomes a tid with a ``thread_name`` metadata record, so request
    tracks (``req:<rid>``), the engine-step timeline, dispatch, compile
    and arena rows render as separate labelled rows;
  * slice events are complete ("X") with microsecond ``ts``/``dur``;
    gauges are counter ("C") events and render as value tracks;
  * the recorder's always-on counters ride along in a trailing metadata
    event so a trace file is self-describing even without the JSONL.

``write_jsonl`` emits the same events one JSON object per line — the
grep/pandas-friendly form — with a leading ``meta`` line carrying
counters, gauges and per-scope wall times.

``validate_trace`` is the schema gate used by ``scripts/check_trace.py``
and the tests: structural checks plus the cross-event invariants the
ISSUE names (per-step phase slices monotonic and non-overlapping).
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

from repro.obs.trace import (CATEGORIES, PH_COUNTER, PH_INSTANT, PH_SLICE,
                             TraceEvent, TraceRecorder)

PID = 1


def _tid_map(events: Iterable[TraceEvent]) -> Dict[str, int]:
    tids: Dict[str, int] = {}
    for ev in events:
        if ev.track not in tids:
            tids[ev.track] = len(tids)
    return tids


def to_chrome_trace(rec: TraceRecorder,
                    meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Render the recorder as a Chrome trace-event JSON object."""
    events = sorted(rec.events(), key=lambda e: e.ts)
    tids = _tid_map(events)
    out: List[Dict[str, Any]] = [
        {"ph": "M", "pid": PID, "tid": 0, "name": "process_name",
         "args": {"name": "serving"}}]
    for track, tid in tids.items():
        out.append({"ph": "M", "pid": PID, "tid": tid,
                    "name": "thread_name", "args": {"name": track}})
    for ev in events:
        rec_json: Dict[str, Any] = {
            "ph": ev.ph, "pid": PID, "tid": tids[ev.track],
            "cat": ev.cat, "name": ev.name,
            "ts": round(ev.ts * 1e6, 3), "args": dict(ev.args)}
        if ev.ph == PH_SLICE:
            rec_json["dur"] = round(ev.dur * 1e6, 3)
        elif ev.ph == PH_INSTANT:
            rec_json["s"] = "t"            # thread-scoped instant
        out.append(rec_json)
    return {"traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"counters": dict(rec.counters),
                          "gauges": dict(rec.gauges),
                          "scope_wall_s": {k: {"calls": v[0],
                                               "seconds": v[1]}
                                           for k, v in rec.scope_wall.items()},
                          "dropped_events": rec.dropped,
                          **(meta or {})}}


def write_chrome_trace(path: str, rec: TraceRecorder,
                       meta: Optional[Dict[str, Any]] = None) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(rec, meta), f)


def write_jsonl(path: str, rec: TraceRecorder,
                meta: Optional[Dict[str, Any]] = None) -> None:
    """One JSON object per line: a ``meta`` record (counters / gauges /
    per-scope wall time), then every buffered event in ts order."""
    with open(path, "w") as f:
        head = {"record": "meta", "counters": dict(rec.counters),
                "gauges": dict(rec.gauges),
                "scope_wall_s": {k: {"calls": v[0], "seconds": v[1]}
                                 for k, v in rec.scope_wall.items()},
                "dropped_events": rec.dropped, **(meta or {})}
        f.write(json.dumps(head) + "\n")
        for ev in sorted(rec.events(), key=lambda e: e.ts):
            f.write(json.dumps({
                "record": "event", "cat": ev.cat, "name": ev.name,
                "ph": ev.ph, "ts": ev.ts, "dur": ev.dur,
                "track": ev.track, "args": ev.args}) + "\n")


# ---------------------------------------------------------------------------
# schema validation (scripts/check_trace.py + tests)
# ---------------------------------------------------------------------------

_VALID_PH = {PH_SLICE, PH_INSTANT, PH_COUNTER, "M"}


def validate_trace(doc: Any,
                   require_categories: Iterable[str] = ()) -> List[str]:
    """Validate a loaded Chrome trace-event document against the event
    schema.  Returns a list of problems (empty = valid).  Checks:

      * top-level shape (``traceEvents`` list of dicts);
      * every event has ph/pid/tid/name, a known phase code, a known
        category (for non-metadata events), numeric non-negative ts, and
        a ``dur`` on complete slices;
      * per (tid, step) the ``step``-category phase slices are monotonic
        and non-overlapping (each phase starts at-or-after the previous
        phase's end) and sit inside their ``engine_step`` root;
      * each category in ``require_categories`` appears at least once.
    """
    errs: List[str] = []
    if not isinstance(doc, dict) or \
            not isinstance(doc.get("traceEvents"), list):
        return ["top level must be {'traceEvents': [...]}"]
    seen_cats: set = set()
    # (tid, step) -> list of (ts, dur, name) child phases + root extent
    phases: Dict[Any, List] = {}
    roots: Dict[Any, Any] = {}
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            errs.append(f"event {i}: unknown phase code {ph!r}")
            continue
        if ph == "M":
            continue
        for k in ("pid", "tid", "name"):
            if k not in ev:
                errs.append(f"event {i}: missing {k!r}")
        cat = ev.get("cat")
        if cat not in CATEGORIES:
            errs.append(f"event {i}: unknown category {cat!r}")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"event {i}: bad ts {ts!r}")
            continue
        if ph == PH_SLICE and not isinstance(ev.get("dur"), (int, float)):
            errs.append(f"event {i}: slice without dur")
            continue
        seen_cats.add(cat)
        if cat == "step" and ph == PH_SLICE:
            step = (ev.get("args") or {}).get("step")
            key = (ev.get("tid"), step)
            if ev["name"] == "engine_step":
                roots[key] = (ts, ev["dur"])
            else:
                phases.setdefault(key, []).append((ts, ev["dur"],
                                                   ev["name"]))
    for key, ps in phases.items():
        ps.sort()
        end = None
        for ts, dur, name in ps:
            if end is not None and ts < end - 1e-6:
                errs.append(f"step {key[1]}: phase {name!r} overlaps the "
                            f"previous phase (starts {ts} < end {end})")
            end = ts + dur
        root = roots.get(key)
        if root is not None:
            r0, rd = root
            if ps[0][0] < r0 - 1e-6 or end > r0 + rd + 1e-6:
                errs.append(f"step {key[1]}: phases escape the engine_step "
                            "root slice")
    for cat in require_categories:
        if cat not in seen_cats:
            errs.append(f"no {cat!r} events in trace")
    return errs
