"""qwen2-moe-a2.7b — 24L d2048 16H (kv=16) expert-ff=1408 v=151936,
MoE: 60 routed top-4 + 4 shared experts.  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Routed experts are padded 60 -> 64 for even 16-way EP (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=5632, vocab_size=151936,
    mlp_activation="silu", use_bias=True, rope_theta=1000000.0,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=60, num_shared_experts=4, experts_per_token=4,
                  d_ff_expert=1408, capacity_factor=1.25),
    param_dtype="bfloat16", compute_dtype="bfloat16",
    skip_shapes=("long_500k",),
)
