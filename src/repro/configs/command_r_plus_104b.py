"""command-r-plus-104b — 64L d12288 96H (GQA kv=8) hd=128 ff=33792 v=256000.

[hf:CohereForAI/c4ai-command-r-v01; unverified]  GQA, no biases.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000,
    mlp_activation="silu", use_bias=False, rope_theta=75000000.0,
    tie_embeddings=True,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    skip_shapes=("long_500k",),
)
