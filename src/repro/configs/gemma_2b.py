"""gemma-2b — 18L d2048 8H (MQA kv=1) hd=256 ff=16384 GeGLU v=256000.

[arXiv:2403.08295; hf]  Full-attention -> long_500k is N/A (see DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    mlp_activation="gelu",            # GeGLU
    rope_theta=10000.0, tie_embeddings=True,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    skip_shapes=("long_500k",),
)
