"""Assigned input-shape set and ShapeDtypeStruct input specs.

Every (architecture × shape) cell is defined by one of these shapes:

  train_4k     seq_len=4096    global_batch=256   -> lowers train_step
  prefill_32k  seq_len=32768   global_batch=32    -> lowers prefill
  decode_32k   seq_len=32768   global_batch=128   -> lowers serve_step
                                                     (1 new token, 32K cache)
  long_500k    seq_len=524288  global_batch=1     -> serve_step; only for
                                                     sub-quadratic archs

``input_specs`` returns ShapeDtypeStructs (no allocation) for the model
inputs of a given arch+shape, matching the batch dicts the model consumes.
Modality frontends are stubs: the spec provides precomputed frame/patch
embeddings, per the assignment.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# sub-quadratic (state-space) archs that can run long_500k
LONG_CONTEXT_OK = ("rwkv6-1.6b", "zamba2-7b")


def shape_applicable(cfg: ArchConfig, shape_name: str) -> bool:
    if shape_name in cfg.skip_shapes:
        return False
    if shape_name == "long_500k":
        return cfg.name in LONG_CONTEXT_OK or cfg.family in ("ssm", "hybrid")
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the model inputs of this cell.

    train  -> {"tokens": (B, S+1)} (+frontend features)
    prefill-> {"tokens": (B, S)}   (+frontend features)
    decode -> {"tokens": (B, 1)}   (cache spec comes from cache_specs())
    """
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = _sds((B, S + 1), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = _sds((B, S), jnp.int32)
    else:
        specs["tokens"] = _sds((B, 1), jnp.int32)

    if cfg.family == "vlm" and shape.kind != "decode":
        n_img = cfg.frontend.num_tokens
        specs["patch_embeds"] = _sds((B, n_img, cfg.frontend.feature_dim),
                                     jnp.dtype(cfg.compute_dtype))
        # image tokens count against the sequence budget
        specs["tokens"] = _sds(
            (B, specs["tokens"].shape[1] - n_img), jnp.int32)
    if cfg.family == "encdec" and shape.kind != "decode":
        specs["src_features"] = _sds((B, S, cfg.frontend.feature_dim),
                                     jnp.dtype(cfg.compute_dtype))
    return specs


def cache_specs(cfg: ArchConfig, shape: ShapeSpec) -> Any:
    """Abstract cache pytree for decode cells (KV cache of seq_len)."""
    from repro.models import serving
    B, S = shape.global_batch, shape.seq_len
    src = S if cfg.family == "encdec" else 0
    return jax.eval_shape(lambda: serving.init_cache(cfg, B, S, src))
