"""Architecture configuration schema.

One ``ArchConfig`` fully describes a model in this framework: the decoder (or
encoder-decoder) backbone, attention flavour (GQA / MQA / MLA / none), MLP or
MoE feed-forward, SSM blocks (RWKV6 / Mamba2-SSD) and hybrid interleaving, and
the modality frontend stub for audio / vision architectures.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact published shape) built from this schema.  ``reduced()``
derives a tiny same-family config for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0           # routed experts
    num_shared_experts: int = 0    # always-on experts
    experts_per_token: int = 0     # top-k
    d_ff_expert: int = 0           # hidden dim of each expert
    capacity_factor: float = 1.25
    # Experts are padded up to a multiple of the model axis for even EP
    # sharding; the router never selects padding experts.
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"      # "mamba2" | "rwkv6"
    state_dim: int = 64       # N (mamba2) or per-head key dim (rwkv6)
    head_dim: int = 64        # P (mamba2 value dim per head) / rwkv6 value dim
    num_heads: int = 0        # derived if 0: d_inner // head_dim
    expand: int = 2           # d_inner = expand * d_model (mamba2)
    conv_width: int = 4       # local conv width (mamba2)
    chunk: int = 64           # chunked-scan block length


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() provides precomputed embeddings."""

    kind: str = "none"        # "none" | "audio_frames" | "vision_patches"
    feature_dim: int = 0      # dim of the precomputed frame/patch features
    num_tokens: int = 0       # tokens contributed per example (vision)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | encdec | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    attention_type: str = "gqa"     # gqa | mla | none
    rope_theta: float = 10000.0
    mla: Optional[MLAConfig] = None
    attn_logit_softcap: float = 0.0
    attn_chunk: int = 512           # flash/blockwise query/kv-chunk length
    # §Perf lever: iterate only the lower-triangular (q-chunk, kv-chunk)
    # pairs in causal flash attention (halves attention FLOPs/bytes).
    # False = paper-faithful baseline recorded in the roofline table.
    flash_causal_skip: bool = False
    # §Perf lever: "pallas" routes full-sequence attention through the
    # flash-attention Pallas kernel (kernels/flash_attn.py) — score tiles
    # stay in VMEM, never crossing HBM.  "xla" = blockwise-scan baseline.
    attn_impl: str = "xla"

    # feed-forward
    mlp_activation: str = "silu"    # silu (SwiGLU) | gelu (GeGLU)
    use_bias: bool = False
    moe: Optional[MoEConfig] = None
    moe_every: int = 1              # MoE layer frequency (1 = every layer)
    first_dense_layers: int = 0     # leading dense layers before MoE starts

    # SSM / hybrid
    ssm: Optional[SSMConfig] = None
    # §Perf lever: "pallas" routes the chunked WKV/SSD scan through the
    # linear-attention Pallas kernel (VMEM-resident decay block + carried
    # state).  "xla" = pure-jnp chunked scan baseline.
    ssm_impl: str = "xla"
    # hybrid: one weight-SHARED attention block every `shared_attn_every`
    # layer slots (zamba2-style); 0 disables.
    shared_attn_every: int = 0

    # encoder-decoder
    encoder_layers: int = 0         # >0 => enc-dec; num_layers = decoder layers
    frontend: FrontendConfig = FrontendConfig()

    # embeddings / norm / dtypes
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # optimizer state dtype; the largest archs use bf16 accumulators so the
    # per-device footprint stays within HBM at 256-512 chips (documented).
    opt_state_dtype: str = "float32"

    # memory policy
    remat: bool = True              # checkpoint each block in train_step
    loss_chunk: int = 512           # seq-chunked vocab-parallel CE

    # distribution
    pipeline_stages: int = 1        # >1: GPipe-style PP over the 'pod' axis
    # "tp": Megatron TP over `model` + FSDP over `data` (baseline rules).
    # "dp": no tensor parallelism — batch+FSDP over every mesh axis (small
    #       models whose TP collectives dominate; MoE keeps EP over `model`).
    tp_strategy: str = "tp"

    # Shapes that are architecturally impossible (recorded as N/A in the
    # roofline table).  e.g. full-attention archs skip long_500k.
    skip_shapes: Tuple[str, ...] = ()

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
        kw = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            attn_chunk=32,
            loss_chunk=32,
            param_dtype="float32",
            compute_dtype="float32",
            opt_state_dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=8,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                experts_per_token=2,
                d_ff_expert=32,
                # E/k = 4 guarantees zero capacity drops -> smoke tests can
                # assert exact prefill/decode vs forward equivalence.
                capacity_factor=4.0,
            )
            kw["first_dense_layers"] = min(self.first_dense_layers, 1)
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                q_lora_rank=32, kv_lora_rank=16,
                qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
            )
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(
                kind=self.ssm.kind, state_dim=16, head_dim=16,
                expand=2, conv_width=4, chunk=16,
            )
        if self.encoder_layers:
            kw["encoder_layers"] = 2
        if self.frontend.kind != "none":
            kw["frontend"] = FrontendConfig(
                kind=self.frontend.kind, feature_dim=24,
                num_tokens=min(self.frontend.num_tokens or 8, 8),
            )
        if self.shared_attn_every:
            kw["shared_attn_every"] = 3
            kw["num_layers"] = 7   # exercises groups + remainder
        return self.replace(**kw)
