"""zamba2-7b — 81 layer slots: Mamba2 blocks + one weight-SHARED attention
block every 6th slot.  d3584, shared-attn 32H hd=112, ff=14336, v=32000,
ssm_state=64.  [arXiv:2411.15242; unverified]

Simplifications (DESIGN.md §2.1): the shared block is a standard pre-norm
attn+MLP block (zamba2's per-invocation LoRA adapters and concat-input are
omitted); Mamba2 d_inner=2*d (7168), P=64 => 112 ssm heads.
Mamba2 state decode => long_500k runs.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    mlp_activation="silu", rope_theta=10000.0, tie_embeddings=True,
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2,
                  conv_width=4, chunk=64),
    shared_attn_every=6,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
