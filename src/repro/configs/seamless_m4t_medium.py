"""seamless-m4t-medium — 12L enc + 12L dec, d1024 16H ff=4096 v=256206.

[arXiv:2308.11596; hf]  Enc-dec; audio frontend is a STUB: input_specs()
provides precomputed frame features (80-d fbank), projected into d_model.
Full attention -> long_500k N/A.
"""
from repro.configs.base import ArchConfig, FrontendConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, encoder_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    mlp_activation="silu", rope_theta=10000.0, tie_embeddings=True,
    frontend=FrontendConfig(kind="audio_frames", feature_dim=80),
    param_dtype="bfloat16", compute_dtype="bfloat16",
    skip_shapes=("long_500k",),
)
