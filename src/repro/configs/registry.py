"""Architecture registry: --arch <id> -> ArchConfig."""
from typing import Dict

from repro.configs.base import ArchConfig


def _load() -> Dict[str, ArchConfig]:
    from repro.configs import (command_r_plus_104b, deepseek_coder_33b,
                               deepseek_v3_671b, gemma_2b, internvl2_76b,
                               llama3_2_1b, qwen2_moe_a27b, rwkv6_1_6b,
                               seamless_m4t_medium, zamba2_7b)
    mods = [gemma_2b, deepseek_coder_33b, llama3_2_1b, command_r_plus_104b,
            qwen2_moe_a27b, deepseek_v3_671b, rwkv6_1_6b,
            seamless_m4t_medium, internvl2_76b, zamba2_7b]
    return {m.CONFIG.name: m.CONFIG for m in mods}


REGISTRY: Dict[str, ArchConfig] = _load()
ARCH_IDS = tuple(REGISTRY)

# Beyond-paper optimized profile per architecture (EXPERIMENTS.md §Perf):
# the config the SARA-TPU recommender converges to for the training shapes.
#  - small dense / MoE models: ZeRO-3 DP beats Megatron TP (activation
#    collectives dominate at d_model ~2K); flash-attention Pallas kernel.
#  - large dense models: keep TP (weights dominate), add the flash kernel.
#  - SSM/hybrid: Pallas WKV kernel (rwkv); hybrid keeps TP + flash kernel
#    on its shared-attention blocks.
OPTIMIZED_OVERRIDES: Dict[str, dict] = {
    "gemma-2b":            {"attn_impl": "pallas", "tp_strategy": "dp_all"},
    "llama3.2-1b":         {"attn_impl": "pallas", "tp_strategy": "dp_all"},
    "qwen2-moe-a2.7b":     {"attn_impl": "pallas",
                            "tp_strategy": "dp_all_noep"},
    "deepseek-coder-33b":  {"attn_impl": "pallas"},
    "command-r-plus-104b": {"attn_impl": "pallas"},
    "internvl2-76b":       {"attn_impl": "pallas"},
    "deepseek-v3-671b":    {"attn_impl": "pallas"},
    "seamless-m4t-medium": {"attn_impl": "pallas"},
    "rwkv6-1.6b":          {"ssm_impl": "pallas"},
    "zamba2-7b":           {"attn_impl": "pallas"},
}


def get_arch(name: str, optimized: bool = False,
             global_batch: int = 0, devices: int = 256) -> ArchConfig:
    """optimized=True applies OPTIMIZED_OVERRIDES — SHAPE-AWARE, which is
    the paper's whole point (the best config is workload-dependent): the
    ZeRO-3 `dp_all*` layouts only apply when the global batch divides the
    device count; otherwise the profile keeps TP and the kernel levers.
    (Measured: blindly applying dp_all to prefill_32k (B=32, 256 chips)
    replicates the batch 8x and regresses 30-80x — EXPERIMENTS.md §Perf.)"""
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    cfg = REGISTRY[name]
    if optimized:
        ov = dict(OPTIMIZED_OVERRIDES.get(name, {}))
        if "tp_strategy" in ov and global_batch % max(devices, 1) != 0:
            ov.pop("tp_strategy")
        cfg = cfg.replace(**ov)
    return cfg
