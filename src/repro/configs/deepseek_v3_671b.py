"""deepseek-v3-671b — 61L d7168 128H MLA ff(expert)=2048 v=129280,
MoE: 256 routed top-8 + 1 shared; first 3 layers dense (ff=18432).
[arXiv:2412.19437; hf]  MTP head not modeled (optional in paper; documented).

opt_state_dtype=bf16: fp32 Adam moments would need ~21 GB/chip at 256 chips —
bf16 moments keep the cell within a 16 GB HBM budget (DESIGN.md §2.1).
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128, head_dim=128,
    d_ff=18432, vocab_size=129280,
    attention_type="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    mlp_activation="silu", rope_theta=10000.0, tie_embeddings=False,
    moe=MoEConfig(num_experts=256, num_shared_experts=1, experts_per_token=8,
                  d_ff_expert=2048, capacity_factor=1.25),
    first_dense_layers=3,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    opt_state_dtype="bfloat16",
    skip_shapes=("long_500k",),
)
