"""llama3.2-1b — 16L d2048 32H (GQA kv=8) hd=64 ff=8192 v=128256.

[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256,
    mlp_activation="silu", rope_theta=500000.0, tie_embeddings=True,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    skip_shapes=("long_500k",),
)
