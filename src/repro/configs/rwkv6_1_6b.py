"""rwkv6-1.6b (Finch) — 24L d2048 attn-free ff=7168 v=65536.

[arXiv:2404.05892; unverified]  Data-dependent decay linear attention;
O(1)-state decode => long_500k runs.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    attention_type="none",
    ssm=SSMConfig(kind="rwkv6", state_dim=64, head_dim=64, chunk=64),
    tie_embeddings=False,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)
