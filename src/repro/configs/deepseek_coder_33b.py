"""deepseek-coder-33b — 62L d7168 56H (GQA kv=8) hd=128 ff=19200 v=32256.

[arXiv:2401.14196; hf]  llama-arch (SwiGLU, untied embeddings).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=19200, vocab_size=32256,
    mlp_activation="silu", rope_theta=100000.0, tie_embeddings=False,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    skip_shapes=("long_500k",),
)
