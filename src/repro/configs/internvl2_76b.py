"""internvl2-76b — 80L d8192 64H (GQA kv=8) hd=128 ff=28672 v=128256.

[arXiv:2404.16821; unverified]  InternViT frontend is a STUB: input_specs()
provides 256 precomputed patch embeddings (3200-d), MLP-projected, prepended
to the text sequence.  LM backbone (llama3-70b-class) modeled exactly.
"""
from repro.configs.base import ArchConfig, FrontendConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    mlp_activation="silu", rope_theta=500000.0, tie_embeddings=False,
    frontend=FrontendConfig(kind="vision_patches", feature_dim=3200,
                            num_tokens=256),
    param_dtype="bfloat16", compute_dtype="bfloat16",
    skip_shapes=("long_500k",),
)
