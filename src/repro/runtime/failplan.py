"""Seeded fail-injection scheduling shared by the training and serving
fault harnesses.

Both fault-tolerance loops in this repo need the same primitive: "does a
simulated fault fire at step N?", answered deterministically from a seed
so a failing run can be replayed bit-for-bit.  ``TrainDriver``'s
``fail_injector`` used to hand-roll this per test (a ``fail_steps`` set
plus a ``fired`` set so a restored step does not re-fire); the serving
chaos harness (``serving/faults.py``) needs the probability-scheduled
variant.  One utility keeps the two harnesses from drifting.

:class:`FaultSchedule` supports both trigger styles:

  * explicit steps (``steps={5, 11}``) — the restart tests' style;
  * per-step probability (``probability=0.05``) — the chaos harness's
    style, drawn from a counter-based RNG keyed on ``(seed, salt,
    step)`` so the outcome for a given step is independent of how many
    other draws happened before it (retries and replays see the same
    schedule).

``fires`` marks each firing step so a step replayed after a restore does
not fail forever (``once=True``, the default); ``peek`` answers without
consuming.  ``pick`` derives a deterministic victim index for the same
step, for harnesses that must also choose *what* to break.
"""

from __future__ import annotations

from typing import Callable, Iterable, Optional, Set, Type

import numpy as np


class FaultSchedule:
    """Deterministic fail-injection trigger: explicit steps and/or a
    per-step probability, seeded and replay-stable."""

    def __init__(self, seed: int = 0, probability: float = 0.0,
                 steps: Iterable[int] = (), salt: int = 0,
                 once: bool = True):
        if not 0.0 <= probability <= 1.0:
            raise ValueError(f"probability must be in [0, 1], got "
                             f"{probability}")
        self.seed = int(seed)
        self.probability = float(probability)
        self.steps: Set[int] = set(int(s) for s in steps)
        self.salt = int(salt)
        self.once = once
        self.fired: Set[int] = set()

    def _draw(self, step: int, stream: int) -> np.random.Generator:
        # counter-based: one generator per (seed, salt, step, stream), so
        # the answer for a step never depends on draw order or retries
        return np.random.default_rng(
            (self.seed, self.salt, int(step), stream))

    def peek(self, step: int) -> bool:
        """Would a fault fire at ``step``?  Does not consume the firing."""
        if step in self.steps:
            return True
        if self.probability <= 0.0:
            return False
        return bool(self._draw(step, 0).random() < self.probability)

    def fires(self, step: int) -> bool:
        """True when a fault fires at ``step``.  With ``once`` (default)
        each step fires at most one fault, so a step replayed after a
        restart/restore makes progress instead of failing forever."""
        if self.once and step in self.fired:
            return False
        if not self.peek(step):
            return False
        self.fired.add(step)
        return True

    def pick(self, step: int, n: int) -> int:
        """Deterministic victim index in ``[0, n)`` for ``step`` — the
        'what breaks' companion draw to ``fires``'s 'when'."""
        if n <= 0:
            raise ValueError("pick needs n >= 1")
        return int(self._draw(step, 1).integers(n))


def make_fail_injector(schedule: FaultSchedule,
                       exc_type: Type[BaseException] = RuntimeError,
                       message: str = "injected fault"
                       ) -> Callable[[int], None]:
    """Adapt a :class:`FaultSchedule` to ``TrainDriver``'s
    ``fail_injector`` interface: a callable of the step index that raises
    when the schedule fires."""

    def injector(step: int) -> None:
        if schedule.fires(step):
            raise exc_type(f"{message} at step {step}")

    return injector
