"""Fault-tolerant training driver.

Production posture for 1000+ nodes (DESIGN.md §5):
  - periodic async sharded checkpoints (atomic; crash-safe),
  - restart-from-latest on ANY step failure (restore params/opt/loader
    position and continue — the e2e test injects failures and asserts the
    loss trajectory is unaffected),
  - straggler monitor: per-step wall time vs. an EWMA; a step slower than
    `straggler_factor` x EWMA fires the mitigation callback (on real fleets:
    re-slice the job / evict the node; here: recorded + surfaced),
  - elastic re-mesh: checkpoint -> rebuild mesh at a new DP width ->
    resharded restore (checkpoint/manager.restore_resharded).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclass
class DriverConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    max_restarts: int = 10


@dataclass
class StepEvent:
    step: int
    seconds: float
    is_straggler: bool
    metrics: Dict[str, float]


class TrainDriver:
    def __init__(self, cfg: DriverConfig, *, train_step: Callable,
                 make_batch: Callable[[int], Any],
                 fail_injector: Optional[Callable[[int], None]] = None,
                 straggler_callback: Optional[Callable] = None):
        self.cfg = cfg
        self.train_step = train_step
        self.make_batch = make_batch
        self.fail_injector = fail_injector
        self.straggler_callback = straggler_callback
        self.ckpt = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep)
        self.events: List[StepEvent] = []
        self.restarts = 0
        self._ewma: Optional[float] = None

    # ------------------------------------------------------------------
    def _maybe_checkpoint(self, step: int, params, opt_state, force=False):
        if force or (step > 0 and step % self.cfg.checkpoint_every == 0):
            self.ckpt.save(step, {"params": params, "opt": opt_state},
                           metadata={"step": step}, blocking=False)

    def _restore(self, params, opt_state):
        step, tree, _ = self.ckpt.restore(
            {"params": params, "opt": opt_state})
        return step, tree["params"], tree["opt"]

    # ------------------------------------------------------------------
    def run(self, params, opt_state, *, start_step: int, num_steps: int):
        """Run the loop; returns (params, opt_state, metrics_history)."""
        step = start_step
        history: List[Dict[str, float]] = []
        # initial checkpoint so step-0 failures can restore
        self.ckpt.save(step, {"params": params, "opt": opt_state},
                       metadata={"step": step}, blocking=True)
        while step < start_step + num_steps:
            try:
                if self.fail_injector is not None:
                    self.fail_injector(step)     # may raise (simulated crash)
                batch = self.make_batch(step)
                t0 = time.time()
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.time() - t0
                straggler = (self._ewma is not None and
                             dt > self.cfg.straggler_factor * self._ewma)
                if straggler and self.straggler_callback is not None:
                    self.straggler_callback(step, dt, self._ewma)
                a = self.cfg.ewma_alpha
                self._ewma = dt if self._ewma is None else \
                    (1 - a) * self._ewma + a * dt
                self.events.append(StepEvent(step, dt, straggler, metrics))
                history.append({"step": step, **metrics})
                step += 1
                self._maybe_checkpoint(step, params, opt_state)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — node failure path
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.cfg.max_restarts}"
                    ) from e
                self.ckpt.wait()
                step, params, opt_state = self._restore(params, opt_state)
        self.ckpt.wait()
        self._maybe_checkpoint(step, params, opt_state, force=True)
        self.ckpt.wait()
        return params, opt_state, history

    # ------------------------------------------------------------------
    def straggler_report(self) -> Dict[str, float]:
        ss = [e for e in self.events if e.is_straggler]
        return {"steps": len(self.events), "stragglers": len(ss),
                "restarts": self.restarts,
                "mean_step_s": float(np.mean([e.seconds for e in self.events]))
                if self.events else 0.0}
