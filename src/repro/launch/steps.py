"""Step builders: sharded train_step / prefill / serve(decode) closures.

Each builder returns the pure step function plus the in/out sharding trees,
ready for ``jax.jit(...).lower(...)`` in the dry-run, ``train.py`` and
``serve.py``.

Lowering happens under a SARA dispatch context (``_dispatch_ctx``): every
GEMM site resolves its tile configuration at trace time, so the lowered
HLO embodies the executed plan (RSA Pallas kernels under
``execute="pallas"``/on-TPU ``"auto"``; XLA dots otherwise) and the sites
are recorded in the given registry for dry-run inspection.
"""

from __future__ import annotations

import contextlib
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import dispatch
from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec, cache_specs, input_specs
from repro.models.api import Model, build_model
from repro.models.moe import padded_num_experts
from repro.optim.adamw import AdamW, AdamWState, apply_updates
from repro.parallel.hints import use_mesh
from repro.parallel.sharding import (batch_specs, cache_specs_tree,
                                     param_specs, to_named)


@contextlib.contextmanager
def _dispatch_ctx(scope: str, execute: str = "xla",
                  registry: Optional[dispatch.SiteRegistry] = None):
    reg = registry if registry is not None else dispatch.default_registry()
    with dispatch.use(execute=execute, registry=reg), reg.scope(scope):
        yield reg


# ---------------------------------------------------------------------------
# MODEL_FLOPS (the "useful work" denominator for §Roofline)
# ---------------------------------------------------------------------------

def _matmul_param_count(cfg: ArchConfig, params_aval) -> Tuple[float, float]:
    """(N_total_matmul, N_active_matmul): params participating in matmuls.

    Token-embedding gathers are excluded (untied); a tied table is counted
    once (it runs as the unembed matmul).  MoE expert banks are scaled by
    top-k/E for the active count.
    """
    import jax.tree_util as jtu
    total = 0.0
    routed = 0.0
    for path, leaf in jtu.tree_flatten_with_path(params_aval)[0]:
        ps = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                      for p in path)
        n = float(leaf.size)
        if ps.endswith("embed") and not cfg.tie_embeddings:
            continue
        if "moe/w_" in ps or ("moe" in ps and ps.split("/")[-1].startswith("w_")
                              and "shared" not in ps):
            routed += n
        total += n
    active = total
    if cfg.moe is not None and routed > 0:
        e_pad = padded_num_experts(cfg)
        frac = cfg.moe.experts_per_token / e_pad
        active = total - routed + routed * frac
    return total, active


def _attention_flops(cfg: ArchConfig, B: int, S: int, kind: str) -> float:
    """Score+value matmul FLOPs (not covered by 6ND)."""
    H, hd = cfg.num_heads, cfg.head_dim
    if cfg.attention_type == "none":
        return 0.0
    if cfg.attention_type == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        per_pair = 2 * H * (qk + m.v_head_dim)
    else:
        per_pair = 4 * H * hd
    n_attn_layers = cfg.num_layers
    if cfg.shared_attn_every:
        n_attn_layers = cfg.num_layers // cfg.shared_attn_every
    if kind == "decode":
        # decoder self-attn: 1 query x S cached keys; encoder is NOT re-run,
        # cross-attn reads the cached encoder output: 1 query x S_enc keys.
        fwd = per_pair * B * S * n_attn_layers
        if cfg.encoder_layers:
            fwd += per_pair * B * S * cfg.num_layers        # cross-attn
        return fwd
    pairs = B * S * S / 2                                   # causal self
    fwd = per_pair * pairs * n_attn_layers
    if cfg.encoder_layers:
        fwd += per_pair * B * S * S * cfg.encoder_layers    # bidirectional enc
        fwd += per_pair * B * S * S * cfg.num_layers        # cross: S_dec x S_enc
    return 3 * fwd if kind == "train" else fwd


def _ssm_flops(cfg: ArchConfig, B: int, S: int, kind: str) -> float:
    """Chunked-scan FLOPs of SSM blocks (not covered by 6ND): the intra-chunk
    masked einsum + inter-chunk state update/readout of _ssd_chunked /
    _wkv_chunked (models/ssm.py)."""
    if cfg.ssm is None:
        return 0.0
    s = cfg.ssm
    n_ssm = cfg.num_layers
    if cfg.shared_attn_every:                 # hybrid: attn slots replace SSM
        n_ssm -= cfg.num_layers // cfg.shared_attn_every
    if s.kind == "mamba2":
        d_inner = s.expand * cfg.d_model
        H, P, N = (s.num_heads or d_inner // s.head_dim), s.head_dim, s.state_dim
        lc = s.chunk
        if kind == "decode":
            per_tok = 4.0 * N * H * P                  # state rank-1 + readout
        else:
            #   G=C.B^T (2*lc*N) + intra apply (2*lc*H*P) + state in/out (4*N*H*P)
            per_tok = 2.0 * lc * N + 2.0 * lc * H * P + 4.0 * N * H * P
    else:                                              # rwkv6
        d = cfg.d_model
        hd = s.head_dim
        lc = s.chunk
        if kind == "decode":
            per_tok = 4.0 * hd * d                     # S += k v^T; o = r^T S
        else:
            per_tok = 4.0 * hd * d + 2.0 * lc * d      # + intra-chunk matmul
    tokens = B * (1 if kind == "decode" else S)
    fwd = per_tok * tokens * n_ssm
    return 3.0 * fwd if kind == "train" else fwd


def model_flops_estimate(cfg: ArchConfig, params_aval, shape: ShapeSpec
                         ) -> float:
    """6*N_active*D (train) / 2*N_active*D (inference) + attention/SSM FLOPs."""
    _, n_active = _matmul_param_count(cfg, params_aval)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        base = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = B * S
        base = 2.0 * n_active * tokens
    else:
        tokens = B * 1
        base = 2.0 * n_active * tokens
    return (base + _attention_flops(cfg, B, S, shape.kind)
            + _ssm_flops(cfg, B, S, shape.kind))


def model_min_bytes_estimate(cfg: ArchConfig, params_aval, shape: ShapeSpec
                             ) -> float:
    """Compulsory GLOBAL HBM traffic per step, in bytes — the floor for the
    §Roofline memory term (memory_attainment = floor / achieved).

    train   : params fwd-read + bwd-read + update-write (param dtype)
              + grads write+read + AdamW m,v read+write (opt dtype)
              + one residual checkpoint per layer write (fwd) + read (bwd)
    prefill : params read once + KV-cache write + embeddings/logits touch
    decode  : params read once + KV-cache read (+1-token write, negligible)
    """
    import jax.tree_util as jtu
    leaves = jtu.tree_leaves(params_aval)
    p_bytes = float(sum(l.size * jnp.dtype(l.dtype).itemsize for l in leaves))
    p_count = float(sum(l.size for l in leaves))
    B, S = shape.global_batch, shape.seq_len
    act_b = jnp.dtype(cfg.compute_dtype).itemsize
    L = cfg.num_layers + cfg.encoder_layers
    d = cfg.d_model

    if shape.kind == "train":
        ob = jnp.dtype(cfg.opt_state_dtype).itemsize
        traffic = p_bytes * 3.0            # fwd read, bwd read, update write
        traffic += p_bytes * 2.0           # grads: write (bwd) + read (opt)
        traffic += p_count * ob * 4.0      # m, v: read + write each
        traffic += 2.0 * B * S * d * L * act_b   # residual ckpt: write + read
        return traffic

    cache_bytes = 0.0
    try:
        cache = cache_specs(cfg, shape)
        cache_bytes = float(sum(l.size * jnp.dtype(l.dtype).itemsize
                                for l in jtu.tree_leaves(cache)))
    except Exception:
        pass
    if shape.kind == "prefill":
        return p_bytes + cache_bytes + 2.0 * B * S * d * act_b
    # decode: whole cache is read once per emitted token
    return p_bytes + cache_bytes


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_optimizer(cfg: ArchConfig, lr: float = 3e-4) -> AdamW:
    return AdamW(lr=lr, state_dtype=cfg.opt_state_dtype)


def build_train_step(cfg: ArchConfig, mesh, lr: float = 3e-4):
    """Returns (step_fn, (params_sh, opt_sh, batch_sh), out_sh, abstract_args)."""
    model = build_model(cfg)
    opt = make_optimizer(cfg, lr)
    params_aval = model.init_abstract()
    opt_aval = jax.eval_shape(opt.init, params_aval)

    p_specs = param_specs(params_aval, cfg, mesh)
    o_specs = AdamWState(step=P(), m=p_specs, v=p_specs)
    p_sh = to_named(p_specs, mesh)
    o_sh = AdamWState(step=NamedSharding(mesh, P()),
                      m=to_named(p_specs, mesh), v=to_named(p_specs, mesh))

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        updates, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, metrics

    return model, train_step, (params_aval, opt_aval), (p_sh, o_sh)


def lower_train_step(cfg: ArchConfig, mesh, shape: ShapeSpec,
                     execute: str = "xla",
                     registry: Optional[dispatch.SiteRegistry] = None):
    model, step, (params_aval, opt_aval), (p_sh, o_sh) = \
        build_train_step(cfg, mesh)
    specs = input_specs(cfg, shape)
    b_sh = to_named(batch_specs(specs, mesh, cfg), mesh)
    jitted = jax.jit(step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
    with mesh:
        with use_mesh(mesh, cfg.tp_strategy), \
                _dispatch_ctx(f"train:{shape.name}", execute, registry):
            lowered = jitted.lower(params_aval, opt_aval, specs)
    return lowered, model, params_aval


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def build_serve_parts(cfg: ArchConfig, mesh, shape: ShapeSpec):
    model = build_model(cfg)
    params_aval = model.init_abstract()
    p_sh = to_named(param_specs(params_aval, cfg, mesh), mesh)
    cache_aval = cache_specs(cfg, shape)
    c_sh = to_named(cache_specs_tree(cache_aval, cfg, mesh), mesh)
    return model, params_aval, p_sh, cache_aval, c_sh


def lower_decode_step(cfg: ArchConfig, mesh, shape: ShapeSpec,
                      execute: str = "xla",
                      registry: Optional[dispatch.SiteRegistry] = None):
    """serve_step: one new token against a seq_len KV cache."""
    model, params_aval, p_sh, cache_aval, c_sh = \
        build_serve_parts(cfg, mesh, shape)
    specs = input_specs(cfg, shape)
    b_sh = to_named(batch_specs(specs, mesh), mesh)

    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    jitted = jax.jit(serve_step,
                     in_shardings=(p_sh, b_sh["tokens"], c_sh),
                     out_shardings=(None, c_sh),
                     donate_argnums=(2,))
    with mesh, use_mesh(mesh, cfg.tp_strategy), \
            _dispatch_ctx(f"decode:{shape.name}", execute, registry):
        # decode against a FULL cache: pos = seq_len - 1 abstractly (the cache
        # aval already has capacity seq_len; occupancy is a runtime value)
        lowered = jitted.lower(params_aval, specs["tokens"], cache_aval)
    return lowered, model, params_aval


def lower_prefill_step(cfg: ArchConfig, mesh, shape: ShapeSpec,
                       execute: str = "xla",
                       registry: Optional[dispatch.SiteRegistry] = None):
    model, params_aval, p_sh, cache_aval, c_sh = \
        build_serve_parts(cfg, mesh, shape)
    specs = input_specs(cfg, shape)
    b_sh = to_named(batch_specs(specs, mesh), mesh)

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    jitted = jax.jit(prefill_step,
                     in_shardings=(p_sh, b_sh, c_sh),
                     out_shardings=(None, c_sh),
                     donate_argnums=(2,))
    with mesh, use_mesh(mesh, cfg.tp_strategy), \
            _dispatch_ctx(f"prefill:{shape.name}", execute, registry):
        lowered = jitted.lower(params_aval, specs, cache_aval)
    return lowered, model, params_aval


def lower_for_cell(cfg: ArchConfig, mesh, shape: ShapeSpec,
                   execute: str = "xla",
                   registry: Optional[dispatch.SiteRegistry] = None):
    if shape.kind == "train":
        return lower_train_step(cfg, mesh, shape, execute, registry)
    if shape.kind == "prefill":
        return lower_prefill_step(cfg, mesh, shape, execute, registry)
    return lower_decode_step(cfg, mesh, shape, execute, registry)
