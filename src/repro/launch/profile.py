import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run profiler: lower+compile one (arch x shape x mesh) cell — with
optional config overrides — and print the roofline terms plus the top HBM /
FLOP contributors from the optimized HLO.  This is the 'profile' step of the
§Perf hypothesis loop.

Usage:
  PYTHONPATH=src python -m repro.launch.profile --arch gemma-2b \
      --shape train_4k [--multi-pod] [--top 30] \
      [--set flash_causal_skip=True --set attn_chunk=256 ...]
"""

import argparse


def parse_override(kv: str):
    key, _, val = kv.partition("=")
    try:
        import ast
        pval = ast.literal_eval(val)
    except (ValueError, SyntaxError):
        pval = val
    return key, pval


def apply_overrides(cfg, overrides):
    """Apply {possibly.dotted.key: value} overrides to an ArchConfig."""
    import dataclasses
    nested = {}
    flat = {}
    for k, v in overrides.items():
        if "." in k:
            head, rest = k.split(".", 1)
            nested.setdefault(head, {})[rest] = v
        else:
            flat[k] = v
    for head, sub in nested.items():
        child = getattr(cfg, head)
        flat[head] = dataclasses.replace(child, **sub)
    return cfg.replace(**flat)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, overrides: dict):
    from repro.configs.registry import get_arch
    from repro.configs.shapes import SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (lower_for_cell, model_flops_estimate,
                                    model_min_bytes_estimate)

    cfg = apply_overrides(get_arch(arch), overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, model, params_aval = lower_for_cell(cfg, mesh, shape)
    mf = model_flops_estimate(cfg, params_aval, shape)
    mb = model_min_bytes_estimate(cfg, params_aval, shape)
    return lowered, int(mesh.devices.size), mf, mb, cfg


def profile_cell(arch: str, shape_name: str, multi_pod: bool,
                 overrides: dict, top: int = 25) -> dict:
    import time

    from repro.launch.hlo_analysis import profile_hlo, roofline_from_compiled

    t0 = time.time()
    lowered, chips, mf, mb, _ = lower_cell(arch, shape_name, multi_pod,
                                           overrides)
    compiled = lowered.compile()
    t1 = time.time()
    text = compiled.as_text()
    terms, stats = roofline_from_compiled(compiled, chips, model_flops=mf,
                                          model_min_bytes=mb, hlo_text=text)
    rows = profile_hlo(text, top=top)
    return {"terms": terms, "stats": stats, "rows": rows,
            "compile_s": t1 - t0, "compiled": compiled}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable; dotted keys "
                         "reach nested configs, e.g. moe.capacity_factor=1.0)")
    args = ap.parse_args()

    overrides = dict(parse_override(kv) for kv in args.set)
    out = profile_cell(args.arch, args.shape, args.multi_pod, overrides,
                       args.top)
    terms, stats = out["terms"], out["stats"]
    print(f"\n== {args.arch} x {args.shape} "
          f"{'pod2' if args.multi_pod else 'pod1'}  overrides={overrides}")
    print(f"compile {out['compile_s']:.1f}s  "
          f"vmem-credited bodies: {stats.vmem_credited_bodies}")
    print(f"compute_s={terms.compute_s:.4f}  memory_s={terms.memory_s:.4f}  "
          f"collective_s={terms.collective_s:.4f}  dominant={terms.dominant}")
    print(f"roofline_frac={terms.roofline_fraction:.4f}  "
          f"mem_attain={terms.memory_attainment:.4f}  "
          f"bound_attain={terms.bound_attainment:.4f}  "
          f"useful_flops={terms.useful_flops_ratio:.3f}")
    print(f"collectives: { {k: f'{v:.3e}' for k, v in stats.collective_bytes_by_op.items()} }")
    print(f"\ntop-{args.top} HBM contributors (trip-weighted, per-device):")
    print(f"{'bytes':>12} {'flops':>12} {'w':>7}  {'opcode':20} "
          f"{'computation':40} type")
    for r in out["rows"]:
        print(f"{r['bytes']:12.3e} {r['flops']:12.3e} {r['weight']:7.0f}  "
              f"{r['opcode']:20} {r['comp'][:40]:40} {r['type']}")


if __name__ == "__main__":
    main()
