"""Serving launcher — thin CLI over the continuous-batching ServingEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --requests 8 --prompt-len 32 --gen 32

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke

``serve_waves`` is kept as the wave-based compatibility path (a whole batch
prefills together and decodes until the longest member finishes): it is the
reference the engine's greedy outputs are tested against, and the baseline
``benchmarks/bench_serving.py`` compares continuous batching to.

``sample_logits`` now lives in ``repro.serving.engine``; the re-export here
keeps existing imports working.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import sample_logits  # noqa: F401  (compat re-export)


def serve_waves(arch: str = "llama3.2-1b", preset: str = "reduced",
                batch: int = 4, prompt_len: int = 32, gen: int = 32,
                waves: int = 2, temperature: float = 0.8, top_k: int = 40,
                seed: int = 0, override_cfg=None, log: bool = True):
    """Wave-based batched serving (compatibility / baseline path)."""
    from repro.configs.registry import get_arch
    from repro.models.api import build_model

    cfg = override_cfg if override_cfg is not None else get_arch(arch)
    if preset == "reduced":
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    max_len = prompt_len + gen + 1

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    stats = {"prefill_tokens": 0, "prefill_s": 0.0,
             "decode_tokens": 0, "decode_s": 0.0}
    outputs = []

    for w in range(waves):
        prompts = rng.integers(0, cfg.vocab_size,
                               (batch, prompt_len)).astype(np.int32)
        batch_in = {"tokens": jnp.asarray(prompts)}
        if cfg.family == "vlm":
            batch_in["patch_embeds"] = jnp.zeros(
                (batch, cfg.frontend.num_tokens, cfg.frontend.feature_dim),
                jnp.dtype(cfg.compute_dtype))
        src_len = 0
        if cfg.family == "encdec":
            src_len = prompt_len
            batch_in["src_features"] = jnp.asarray(
                rng.standard_normal((batch, src_len,
                                     cfg.frontend.feature_dim)),
                jnp.dtype(cfg.compute_dtype))

        cache = model.init_cache(batch, max_len
                                 + (cfg.frontend.num_tokens
                                    if cfg.family == "vlm" else 0),
                                 src_len=src_len)
        t0 = time.time()
        logits, cache = jax.block_until_ready(
            prefill(params, batch_in, cache))
        stats["prefill_s"] += time.time() - t0
        stats["prefill_tokens"] += batch * prompt_len

        key, k = jax.random.split(key)
        tok = sample_logits(k, logits, temperature, top_k)[:, None]
        generated = [np.asarray(tok)]
        t0 = time.time()
        for _ in range(gen - 1):
            logits, cache = decode(params, tok, cache)
            key, k = jax.random.split(key)
            tok = sample_logits(k, logits, temperature, top_k)[:, None]
            generated.append(np.asarray(tok))
        jax.block_until_ready(tok)
        stats["decode_s"] += time.time() - t0
        stats["decode_tokens"] += batch * (gen - 1)
        outputs.append(np.concatenate(generated, axis=1))
        if log:
            print(f"  wave {w}: generated {outputs[-1].shape} tokens")

    if log:
        print(f"serve: prefill {stats['prefill_tokens']/max(stats['prefill_s'],1e-9):,.0f} tok/s, "
              f"decode {stats['decode_tokens']/max(stats['decode_s'],1e-9):,.0f} tok/s")
    return outputs, stats


def serve_continuous(arch: str = "llama3.2-1b", preset: str = "reduced",
                     num_requests: int = 8, num_slots: int = 4,
                     prompt_len: int = 32, gen: int = 32,
                     temperature: float = 0.8, top_k: int = 40,
                     seed: int = 0, execute: str = "auto",
                     dispatcher: str = "oracle",
                     adaptnet_ckpt: str = None, kv_layout: str = "auto",
                     prefill_chunk: int = None, prefix_cache: bool = False,
                     shared_prefix_decode: bool = False,
                     defrag_threshold: float = None,
                     shared_prefix_len: int = 0, trace_out: str = None,
                     sanitize: bool = False, chaos=None,
                     deadline_s: float = None, snapshot_dir: str = None,
                     snapshot_every: int = 0, spec_draft: str = None,
                     spec_k: int = 4,
                     override_cfg=None, log: bool = True):
    """Serve a request set through the continuous-batching engine.

    ``execute`` selects the GEMM backend every model site runs through
    the SARA dispatch layer with: "pallas" (RSA kernel), "xla", or
    "auto" (compiled Pallas on TPU, XLA elsewhere).  ``dispatcher``
    selects the recommendation source: "oracle" (analytic search) or
    "adaptnet" (trained ADAPTNET-TPU loaded from ``adaptnet_ckpt`` —
    the self-adaptive path, with oracle fallback out of trained range).
    ``kv_layout`` selects the decode KV storage: "paged" (physical page
    arena + paged flash-decode kernel), "dense" (stacked per-slot caches),
    or "auto" (paged for attention families on TPU; dense elsewhere and
    for recurrent-state families).  ``prefill_chunk`` (with the paged
    layout, dense/moe families) streams each prompt into KV pages that
    many tokens per engine step — chunked paged prefill — instead of one
    padded-bucket call per request.  ``trace_out`` enables full span
    recording (``EngineConfig.trace``) and writes a Chrome/Perfetto
    trace-event JSON (plus a ``.jsonl`` event stream) to that path after
    the run — load it at https://ui.perfetto.dev or chrome://tracing.
    ``sanitize`` runs the KV-arena sanitizer (``EngineConfig.sanitize``):
    freed pages are NaN-poisoned, decode block tables are
    generation-checked, the pool invariants run every step, and leaks
    are audited at drain — use-after-free raises instead of corrupting
    output.  ``prefix_cache`` (requires ``prefill_chunk``) turns on the
    cross-request prefix cache: prompts that open with an
    already-served token run map those KV pages refcounted/copy-on-write
    instead of recomputing them; ``shared_prefix_decode`` additionally
    batches decode attention over the common physical prefix (cascade).
    ``chaos`` (a :class:`repro.serving.faults.ChaosConfig`) turns on the
    seed-driven fault-injection harness — injected pool OOMs / poisoned
    pages / stalls / forced preemptions are contained by the engine's
    step error boundary instead of crashing the run.  ``deadline_s``
    attaches a per-request deadline (virtual steps under the default
    step clock): queued requests past it expire, and admission sheds
    requests the rolling-TTFT estimate says cannot make it.
    ``snapshot_dir`` / ``snapshot_every`` enable crash-safe periodic
    engine snapshots (``ServingEngine.snapshot``/``restore``).
    ``spec_draft`` turns on speculative decoding (requires
    ``prefill_chunk`` and greedy sampling, ``temperature=0``): "self"
    for self-speculation or a registry arch name for a separate draft
    model; the draft proposes up to ``spec_k`` tokens per lane per step
    and one target verify pass commits the longest agreeing prefix plus
    a corrected token — outputs stay bitwise-identical to plain greedy
    decode.
    """
    from repro.configs.registry import get_arch
    from repro.serving import EngineConfig, Request, ServingEngine

    cfg = override_cfg if override_cfg is not None else get_arch(arch)
    if preset == "reduced":
        cfg = cfg.reduced()
    rng = np.random.default_rng(seed)
    engine = ServingEngine(cfg, EngineConfig(
        num_slots=num_slots, max_len=prompt_len + gen + 1,
        temperature=temperature, top_k=top_k, seed=seed,
        src_len=prompt_len if cfg.family == "encdec" else 0,
        execute=execute, dispatcher_mode=dispatcher,
        adaptnet_dir=adaptnet_ckpt, kv_layout=kv_layout,
        prefill_chunk=prefill_chunk, prefix_cache=prefix_cache,
        shared_prefix_decode=shared_prefix_decode,
        defrag_threshold=defrag_threshold, trace=trace_out is not None,
        sanitize=sanitize, chaos=chaos, snapshot_dir=snapshot_dir,
        snapshot_every=snapshot_every, spec_draft=spec_draft,
        spec_k=spec_k))
    # ``shared_prefix_len`` > 0 makes every prompt open with the same token
    # run (a system-prompt-style workload) so the cross-request prefix cache
    # has something to hit; the tail stays per-request random.
    shared = (rng.integers(0, cfg.vocab_size,
                           min(shared_prefix_len, prompt_len)).astype(np.int32)
              if shared_prefix_len > 0 else None)
    reqs = []
    for i in range(num_requests):
        p = rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
        if shared is not None:
            p[:len(shared)] = shared
        extras = None
        if cfg.family == "encdec":
            extras = {"src_features": rng.standard_normal(
                (1, prompt_len, cfg.frontend.feature_dim)).astype(np.float32)}
        reqs.append(Request(rid=f"req-{i}", prompt=p, max_new_tokens=gen,
                            extras=extras, deadline_s=deadline_s))
    t0 = time.time()
    outputs = engine.run(reqs)
    if log:
        total = sum(len(v) for v in outputs.values())
        print(f"served {len(reqs)} requests / {total} tokens "
              f"in {time.time() - t0:.2f}s on {num_slots} slots "
              f"(kv_layout={engine.kv_layout})")
        print(engine.metrics.report(engine.dispatcher.cache_info(),
                                    engine.dispatch_stats()))
        print("  executed gemm plan (last step):")
        for site, desc in engine.gemm_plan.items():
            print(f"    {site:<24} {desc}")
    if trace_out is not None:
        jsonl = engine.export_trace(trace_out)
        if log:
            print(f"  trace: {trace_out} (+ {jsonl}) — "
                  f"{len(engine.obs)} events, open in ui.perfetto.dev")
    return outputs, engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="reduced")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--execute", default="auto",
                    choices=["auto", "pallas", "xla"],
                    help="GEMM backend for the dispatch layer")
    ap.add_argument("--dispatcher", default="oracle",
                    choices=["oracle", "adaptnet"],
                    help="recommendation source for every GEMM site")
    ap.add_argument("--adaptnet-ckpt", default=None,
                    help="trained ADAPTNET-TPU dir (launch.train_adaptnet)")
    ap.add_argument("--kv-layout", default="auto",
                    choices=["auto", "paged", "dense"],
                    help="decode KV storage: paged arena or dense slots")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help=">0: chunked paged prefill — stream each prompt "
                         "into KV pages this many tokens per step "
                         "(requires --kv-layout paged, dense/moe families)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cross-request prefix cache: refcounted "
                         "copy-on-write KV pages shared across prompts "
                         "with a common token prefix (requires "
                         "--prefill-chunk and the paged layout)")
    ap.add_argument("--shared-prefix-decode", action="store_true",
                    help="with --prefix-cache: cascade decode attention — "
                         "one pass over the common physical prefix pages "
                         "+ per-lane unique suffixes, merged by softmax "
                         "state (reassociates the softmax; opt-in)")
    ap.add_argument("--defrag-threshold", type=float, default=None,
                    help="auto-defragment the KV pool from the engine "
                         "step loop when fragmentation exceeds this "
                         "fraction (0..1)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help=">0: every request's prompt opens with the same "
                         "token run of this length (system-prompt-style "
                         "workload for exercising --prefix-cache)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable span tracing and write a Chrome/Perfetto "
                         "trace-event JSON here after the run")
    ap.add_argument("--waves", type=int, default=0,
                    help=">0: run the legacy wave-based path instead")
    ap.add_argument("--sanitize", action="store_true",
                    help="KV-arena sanitizer: poison freed pages, "
                         "generation-check decode tables, per-step pool "
                         "invariants, leak audit at drain")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="seed-driven fault injection (pool OOM, poisoned "
                         "pages, stalls, forced preemption); faults are "
                         "contained by the step error boundary, not fatal")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline (virtual steps under the "
                         "default step clock): queued requests past it "
                         "expire, hopeless admissions are shed")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="crash-safe engine snapshots go here "
                         "(ServingEngine.snapshot/restore)")
    ap.add_argument("--snapshot-every", type=int, default=0, metavar="N",
                    help=">0: auto-snapshot every N engine steps "
                         "(requires --snapshot-dir)")
    ap.add_argument("--spec-draft", default=None, metavar="DRAFT",
                    help="speculative decoding: 'self' or a registry "
                         "arch name for the draft model (requires "
                         "--prefill-chunk and --temperature 0; outputs "
                         "stay bitwise-identical to plain greedy decode)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per lane per spec step "
                         "(verified by one K+1-row target pass)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI smoke: tiny trace, assert completion")
    a = ap.parse_args()
    if a.smoke and a.chaos is not None:
        # Chaos smoke: the same greedy workload served twice — fault-free,
        # then with every injector armed at boosted probabilities.  The
        # chaotic run must terminate every request, contain at least one
        # injected fault inside the step boundary, and leave every
        # non-faulted request's tokens identical to the fault-free run.
        from repro.serving import ChaosConfig
        common = dict(
            arch=a.arch, num_requests=4, num_slots=2, prompt_len=12,
            gen=6, temperature=0.0, execute=a.execute,
            dispatcher=a.dispatcher, adaptnet_ckpt=a.adaptnet_ckpt,
            kv_layout="paged", prefill_chunk=a.prefill_chunk or 8,
            sanitize=True, log=False)
        base, _ = serve_continuous(**common)
        chaos = ChaosConfig(seed=a.chaos, pool_oom_p=0.15, poison_p=0.15,
                            stall_p=0.1, preempt_p=0.1)
        outputs, engine = serve_continuous(
            **common, chaos=chaos, deadline_s=a.deadline,
            snapshot_dir=a.snapshot_dir, snapshot_every=a.snapshot_every,
            trace_out=a.trace_out)
        s = engine.summary()
        assert s["faults_injected"] >= 1, s
        assert s["faults_contained"] >= 1, s
        outcomes = {r.rid: r.outcome for r in engine.requests.values()}
        assert all(outcomes.values()), outcomes   # every request terminal
        done = [rid for rid, o in outcomes.items() if o == "done"]
        for rid in done:
            assert np.array_equal(outputs[rid], base[rid]), \
                (rid, outputs[rid], base[rid])
        assert s["kv_leaked_tables"] == 0 and s["kv_leaked_refs"] == 0, s
        assert engine.pool.num_free == engine.pool.num_blocks
        print(f"chaos smoke OK (seed={a.chaos}: "
              f"{int(s['faults_injected'])} injected, "
              f"{int(s['faults_contained'])} contained, outcomes="
              f"{sorted(outcomes.values())}, greedy parity for "
              f"{len(done)} survivors)")
        return
    if a.smoke and a.prefix_cache:
        # Prefix-cache smoke: a shared-prefix workload served twice —
        # cache off, then cache on (+ optional cascade) — must agree
        # token-for-token under greedy sampling while the cached run
        # actually reuses pages.
        common = dict(
            arch=a.arch, num_requests=4, num_slots=2, prompt_len=24,
            gen=6, temperature=0.0, execute=a.execute,
            dispatcher=a.dispatcher, adaptnet_ckpt=a.adaptnet_ckpt,
            kv_layout="paged", prefill_chunk=a.prefill_chunk or 8,
            shared_prefix_len=16, defrag_threshold=a.defrag_threshold,
            sanitize=a.sanitize, log=False)
        base, _ = serve_continuous(**common)
        outputs, engine = serve_continuous(
            **common, prefix_cache=True,
            shared_prefix_decode=a.shared_prefix_decode,
            trace_out=a.trace_out)
        assert all(len(v) == 6 for v in outputs.values()), outputs
        assert set(outputs) == set(base)
        for rid in base:
            assert np.array_equal(outputs[rid], base[rid]), \
                (rid, outputs[rid], base[rid])
        stats = engine.prefix_cache.stats()
        assert stats["prefix_cache_hits"] > 0, stats
        assert stats["prefix_cache_reused_pages"] > 0, stats
        assert engine.metrics.cache_hit_tokens > 0
        engine.prefix_cache.clear()
        engine.pool.check()
        assert engine.pool.num_free == engine.pool.num_blocks
        print(f"prefix-cache smoke OK (hit_rate="
              f"{stats['prefix_cache_hit_rate']:.2f}, reused_pages="
              f"{stats['prefix_cache_reused_pages']}, greedy parity)")
        return
    if a.smoke and a.spec_draft:
        # Spec-decode smoke: the same greedy workload served twice —
        # plain, then speculatively — must agree token-for-token (every
        # committed token is a target verify argmax) while the spec run
        # actually accepts draft tokens and commits more than one token
        # per verify step.
        common = dict(
            arch=a.arch, num_requests=4, num_slots=2, prompt_len=12,
            gen=6, temperature=0.0, execute=a.execute,
            dispatcher=a.dispatcher, adaptnet_ckpt=a.adaptnet_ckpt,
            kv_layout="paged", prefill_chunk=a.prefill_chunk or 8,
            sanitize=a.sanitize, log=False)
        base, _ = serve_continuous(**common)
        outputs, engine = serve_continuous(
            **common, spec_draft=a.spec_draft, spec_k=a.spec_k,
            trace_out=a.trace_out)
        assert all(len(v) == 6 for v in outputs.values()), outputs
        assert set(outputs) == set(base)
        for rid in base:
            assert np.array_equal(outputs[rid], base[rid]), \
                (rid, outputs[rid], base[rid])
        s = engine.summary()
        assert s["spec_steps"] > 0, s
        assert s["spec_accepted_tokens"] >= 1, s
        if a.spec_draft == "self":
            assert s["spec_accepted_per_step"] > 1.0, s
        assert engine.spec.live_pages() == 0
        engine.pool.check()
        assert engine.pool.num_free == engine.pool.num_blocks
        print(f"spec-decode smoke OK (draft={a.spec_draft}, k={a.spec_k}: "
              f"greedy parity, {int(s['spec_accepted_tokens'])} accepted "
              f"draft tokens, "
              f"{s['spec_accepted_per_step']:.2f} committed/step over "
              f"{int(s['spec_steps'])} verify steps)")
        return
    if a.smoke:
        outputs, engine = serve_continuous(
            arch=a.arch, num_requests=3, num_slots=2, prompt_len=12, gen=6,
            temperature=0.0, execute=a.execute, dispatcher=a.dispatcher,
            adaptnet_ckpt=a.adaptnet_ckpt, kv_layout=a.kv_layout,
            trace_out=a.trace_out, sanitize=a.sanitize)
        assert all(len(v) == 6 for v in outputs.values()), outputs
        engine.pool.check()
        assert engine.pool.num_free == engine.pool.num_blocks
        if a.sanitize:
            s = engine.summary()
            assert s["kv_sanitize_checks"] > 0, s
            assert s["kv_poison_hits"] == 0 and \
                s["kv_generation_faults"] == 0, s
            assert s["kv_leaked_tables"] == 0 and s["kv_leaked_refs"] == 0
            print(f"sanitizer clean ({int(s['kv_sanitize_checks'])} checks, "
                  f"{int(s['kv_poison_fills'])} pages poisoned on free)")
        # the plan must be registry-backed: sites that actually traced
        assert engine.gemm_plan and "unembed" in engine.gemm_plan, \
            engine.gemm_plan
        assert engine.registry.scopes(), "no dispatch scopes traced"
        if a.dispatcher == "adaptnet":
            # the learned model (not the oracle) must have driven dispatch
            assert engine.dispatcher.mode == "adaptnet"
            src = engine.dispatcher.source_info()
            assert src["adaptnet"] > 0 or src["oracle_fallback"] > 0, src
            print(f"serving smoke OK (adaptnet: {src})")
            return
        print("serving smoke OK")
        return
    if a.waves > 0:
        serve_waves(arch=a.arch, preset=a.preset, batch=a.slots,
                    prompt_len=a.prompt_len, gen=a.gen, waves=a.waves,
                    temperature=a.temperature, top_k=a.top_k)
        return
    chaos = None
    if a.chaos is not None:
        from repro.serving import ChaosConfig
        chaos = ChaosConfig(seed=a.chaos, pool_oom_p=0.05,
                            poison_p=0.05 if a.sanitize else 0.0,
                            stall_p=0.05, preempt_p=0.05)
    serve_continuous(arch=a.arch, preset=a.preset, num_requests=a.requests,
                     num_slots=a.slots, prompt_len=a.prompt_len, gen=a.gen,
                     temperature=a.temperature, top_k=a.top_k,
                     execute=a.execute, dispatcher=a.dispatcher,
                     adaptnet_ckpt=a.adaptnet_ckpt, kv_layout=a.kv_layout,
                     prefill_chunk=a.prefill_chunk or None,
                     prefix_cache=a.prefix_cache,
                     shared_prefix_decode=a.shared_prefix_decode,
                     defrag_threshold=a.defrag_threshold,
                     shared_prefix_len=a.shared_prefix_len,
                     trace_out=a.trace_out, sanitize=a.sanitize,
                     chaos=chaos, deadline_s=a.deadline,
                     snapshot_dir=a.snapshot_dir,
                     snapshot_every=a.snapshot_every,
                     spec_draft=a.spec_draft, spec_k=a.spec_k)


if __name__ == "__main__":
    main()
