"""Batched serving launcher.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --batch 4 --prompt-len 32 --gen 32

Slot-based batched serving: a wave of `batch` requests is prefilled
together, then decoded step-by-step with temperature / top-k sampling;
finished sequences (EOS or budget) retire and a new wave begins.  Reports
prefill tokens/s and decode tokens/s.  The decode step is the same jitted
``serve_step`` the dry-run lowers at production shapes.
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def sample_logits(key, logits: jnp.ndarray, temperature: float = 1.0,
                  top_k: int = 0) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        thresh = vals[:, -1:]
        logits = jnp.where(logits < thresh, -1e30, logits)
    return jax.random.categorical(key, logits, -1).astype(jnp.int32)


def serve_waves(arch: str = "llama3.2-1b", preset: str = "reduced",
                batch: int = 4, prompt_len: int = 32, gen: int = 32,
                waves: int = 2, temperature: float = 0.8, top_k: int = 40,
                seed: int = 0, override_cfg=None, log: bool = True):
    from repro.configs.registry import get_arch
    from repro.models.api import build_model

    cfg = override_cfg if override_cfg is not None else get_arch(arch)
    if preset == "reduced":
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    max_len = prompt_len + gen + 1

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    stats = {"prefill_tokens": 0, "prefill_s": 0.0,
             "decode_tokens": 0, "decode_s": 0.0}
    outputs = []

    for w in range(waves):
        prompts = rng.integers(0, cfg.vocab_size,
                               (batch, prompt_len)).astype(np.int32)
        batch_in = {"tokens": jnp.asarray(prompts)}
        if cfg.family == "vlm":
            batch_in["patch_embeds"] = jnp.zeros(
                (batch, cfg.frontend.num_tokens, cfg.frontend.feature_dim),
                jnp.dtype(cfg.compute_dtype))
        src_len = 0
        if cfg.family == "encdec":
            src_len = prompt_len
            batch_in["src_features"] = jnp.asarray(
                rng.standard_normal((batch, src_len,
                                     cfg.frontend.feature_dim)),
                jnp.dtype(cfg.compute_dtype))

        cache = model.init_cache(batch, max_len
                                 + (cfg.frontend.num_tokens
                                    if cfg.family == "vlm" else 0),
                                 src_len=src_len)
        t0 = time.time()
        logits, cache = jax.block_until_ready(
            prefill(params, batch_in, cache))
        stats["prefill_s"] += time.time() - t0
        stats["prefill_tokens"] += batch * prompt_len

        key, k = jax.random.split(key)
        tok = sample_logits(k, logits, temperature, top_k)[:, None]
        generated = [np.asarray(tok)]
        t0 = time.time()
        for _ in range(gen - 1):
            logits, cache = decode(params, tok, cache)
            key, k = jax.random.split(key)
            tok = sample_logits(k, logits, temperature, top_k)[:, None]
            generated.append(np.asarray(tok))
        jax.block_until_ready(tok)
        stats["decode_s"] += time.time() - t0
        stats["decode_tokens"] += batch * (gen - 1)
        outputs.append(np.concatenate(generated, axis=1))
        if log:
            print(f"  wave {w}: generated {outputs[-1].shape} tokens")

    if log:
        print(f"serve: prefill {stats['prefill_tokens']/max(stats['prefill_s'],1e-9):,.0f} tok/s, "
              f"decode {stats['decode_tokens']/max(stats['decode_s'],1e-9):,.0f} tok/s")
    return outputs, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="reduced")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--waves", type=int, default=2)
    a = ap.parse_args()
    serve_waves(arch=a.arch, preset=a.preset, batch=a.batch,
                prompt_len=a.prompt_len, gen=a.gen, waves=a.waves)


if __name__ == "__main__":
    main()
