import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import: jax locks the device
count at first initialization.  This flag is dry-run-only — tests and
benchmarks see the single real CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k [--multi-pod] [--out results/dryrun2]
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all

Per cell this records: compile wall time, memory_analysis (per-device bytes),
cost_analysis (FLOPs/bytes), parsed collective bytes by opcode, the roofline
terms of §Roofline, and MODEL_FLOPS — into one JSON per cell.
"""

import argparse
import json
import time
import traceback
from pathlib import Path


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False, optimized: bool = False) -> dict:
    import jax
    from repro.configs.registry import get_arch
    from repro.configs.shapes import SHAPES, shape_applicable
    from repro.launch.hlo_analysis import roofline_from_compiled
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (lower_for_cell, model_flops_estimate,
                                    model_min_bytes_estimate)

    mesh_tag = "pod2" if multi_pod else "pod1"
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        if rec.get("status") in ("ok", "skipped"):
            print(f"[cached] {arch} x {shape_name} x {mesh_tag}: "
                  f"{rec['status']}")
            return rec

    cfg = get_arch(arch, optimized=optimized,
                   global_batch=SHAPES[shape_name].global_batch,
                   devices=512 if multi_pod else 256)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "kind": shape.kind}
    if not shape_applicable(cfg, shape_name):
        rec["status"] = "skipped"
        rec["reason"] = ("long_500k needs sub-quadratic attention; "
                         f"{arch} is full-attention (DESIGN.md §4)")
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[skip]   {arch} x {shape_name}: N/A")
        return rec

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = int(mesh.devices.size)
        t0 = time.time()
        lowered, model, params_aval = lower_for_cell(cfg, mesh, shape)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        ma = compiled.memory_analysis()
        mem = {}
        if ma is not None:
            for f in ("generated_code_size_in_bytes",
                      "argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes"):
                mem[f] = int(getattr(ma, f, 0))
            mem["per_device_hbm_bytes"] = (
                mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
                + mem["output_size_in_bytes"] - mem["alias_size_in_bytes"])
        print(f"  memory_analysis: {mem}")

        mf = model_flops_estimate(cfg, params_aval, shape)
        mb = model_min_bytes_estimate(cfg, params_aval, shape)
        terms, stats = roofline_from_compiled(compiled, chips, model_flops=mf,
                                              model_min_bytes=mb)
        print(f"  hlo (trip-weighted, per-dev): flops={stats.flops:.3e} "
              f"bytes={stats.hbm_bytes:.3e} "
              f"coll={stats.collective_bytes:.3e}")

        rec.update({
            "status": "ok",
            "chips": chips,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "num_params": int(model.num_params(params_aval)),
            "memory": mem,
            "cost_analysis_raw": stats.raw_cost_analysis,
            "collectives": {"bytes_by_op": stats.collective_bytes_by_op,
                            "count_by_op": stats.collective_count_by_op},
            "roofline": terms.to_dict(),
        })
        print(f"[ok]     {arch} x {shape_name} x {mesh_tag}: "
              f"compile {rec['compile_s']}s  dominant={terms.dominant}  "
              f"roofline_frac={terms.roofline_fraction:.3f}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL]   {arch} x {shape_name} x {mesh_tag}: {rec['error']}")

    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun2")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply per-arch OPTIMIZED_OVERRIDES (beyond-paper "
                         "configs from EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    from repro.configs.registry import ARCH_IDS
    from repro.configs.shapes import SHAPES

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, out_dir, force=args.force,
                               optimized=args.optimized)
                if rec["status"] == "error":
                    n_fail += 1
                else:
                    n_ok += 1
    print(f"\ndry-run sweep done: {n_ok} ok/skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
