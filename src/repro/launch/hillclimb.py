import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: run a named VARIANT (a set of config overrides)
of one (arch x shape) cell, record its roofline terms next to the baseline,
and print the delta on every term.

Each iteration of the hypothesis -> change -> measure -> validate loop is one
invocation; results accumulate in results/hillclimb/<arch>__<shape>.json as
an ordered log that EXPERIMENTS.md §Perf reproduces.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --arch gemma-2b \
      --shape train_4k --variant causal_skip \
      --hypothesis "tri-pairs halve attention score traffic" \
      --set flash_causal_skip=True
"""

import argparse
import json
from pathlib import Path

from repro.launch.profile import parse_override, profile_cell


def run_variant(arch: str, shape: str, variant: str, overrides: dict,
                hypothesis: str, out_dir: Path, multi_pod: bool = False,
                force: bool = False) -> dict:
    out_path = out_dir / f"{arch}__{shape}.json"
    log = json.loads(out_path.read_text()) if out_path.exists() else []
    for e in log:
        if e["variant"] == variant and not force:
            print(f"[cached] {variant}")
            return e

    out = profile_cell(arch, shape, multi_pod, overrides, top=0)
    terms, stats = out["terms"], out["stats"]
    ma = out["compiled"].memory_analysis()
    per_dev = 0
    if ma is not None:
        per_dev = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                   + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    entry = {
        "variant": variant,
        "overrides": {k: repr(v) for k, v in overrides.items()},
        "hypothesis": hypothesis,
        "compile_s": round(out["compile_s"], 1),
        "per_device_hbm_bytes": int(per_dev),
        "vmem_credited_bodies": stats.vmem_credited_bodies,
        "collective_bytes_by_op": stats.collective_bytes_by_op,
        "roofline": terms.to_dict(),
    }
    log = [e for e in log if e["variant"] != variant] + [entry]
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(log, indent=1))

    base = next((e for e in log if e["variant"] == "baseline"), None)
    _print_entry(entry, base)
    return entry


def _print_entry(e: dict, base: dict | None) -> None:
    r = e["roofline"]
    print(f"\n== {e['variant']}  ({e['hypothesis']})")
    print(f"   overrides: {e['overrides']}")
    for t in ("compute_s", "memory_s", "collective_s"):
        delta = ""
        if base and base is not e:
            b = base["roofline"][t]
            if b > 0:
                delta = f"  ({(r[t] - b) / b * 100:+.1f}% vs baseline)"
        print(f"   {t:14} {r[t]:10.4f}{delta}")
    print(f"   dominant={r['dominant']}  bound_attain={r['bound_attainment']:.4f} "
          f" roofline_frac={r['roofline_fraction']:.4f}  "
          f"hbm/dev={e['per_device_hbm_bytes'] / 1e9:.2f}GB")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()

    overrides = dict(parse_override(kv) for kv in args.set)
    run_variant(args.arch, args.shape, args.variant, overrides,
                args.hypothesis, Path(args.out), args.multi_pod, args.force)


if __name__ == "__main__":
    main()
