"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --preset reduced --steps 50 --batch 8 --seq 128 --data-axis 1

Uses the full substrate: synthetic pipeline, AdamW, sharded train_step
(pjit over whatever devices exist), fault-tolerant driver with periodic
async checkpoints + restart, straggler monitor.  The e2e ~100M-param run of
deliverable (b) is ``examples/train_lm.py`` which drives this module.
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np


def train_main(arch: str = "llama3.2-1b", preset: str = "reduced",
               steps: int = 50, global_batch: int = 8, seq_len: int = 128,
               data_axis: int = 1, model_axis: int = 1,
               checkpoint_dir: str = "/tmp/repro_ckpt",
               checkpoint_every: int = 25, lr: float = 1e-3,
               log_every: int = 10, seed: int = 0,
               execute: str = "auto",
               override_cfg=None, fail_injector=None,
               d_model: Optional[int] = None,
               num_layers: Optional[int] = None):
    from repro import dispatch
    from repro.configs.registry import get_arch
    from repro.data.pipeline import make_loader
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import _dispatch_ctx, build_train_step
    from repro.configs.shapes import input_specs, ShapeSpec
    from repro.parallel.hints import use_mesh
    from repro.parallel.sharding import batch_specs, to_named
    from repro.runtime.driver import DriverConfig, TrainDriver

    cfg = override_cfg if override_cfg is not None else get_arch(arch)
    if preset == "reduced":
        cfg = cfg.reduced()
    if d_model:
        cfg = cfg.replace(d_model=d_model,
                          head_dim=d_model // cfg.num_heads,
                          d_ff=4 * d_model)
    if num_layers:
        cfg = cfg.replace(num_layers=num_layers)
    cfg = cfg.replace(param_dtype="float32", compute_dtype="float32",
                      opt_state_dtype="float32")

    mesh = make_host_mesh(data_axis, model_axis)
    model, step_fn, (params_aval, opt_aval), (p_sh, o_sh) = \
        build_train_step(cfg, mesh, lr=lr)

    params = jax.device_put(model.init(jax.random.PRNGKey(seed)), p_sh)
    from repro.launch.steps import make_optimizer
    opt = make_optimizer(cfg, lr)
    opt_state = jax.device_put(opt.init(params), o_sh)

    shape = ShapeSpec("train", seq_len, global_batch, "train")
    b_sh = to_named(batch_specs(input_specs(cfg, shape), mesh), mesh)
    jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))

    loader = make_loader(cfg.vocab_size, seq_len, global_batch, seed=seed)
    batches = {}

    def make_batch(step: int):
        # pull from the prefetching loader; memoize for restart replay
        while loader.step <= step and step not in batches:
            b = next(loader)
            batches[loader.step - 1] = b
            for s in list(batches):
                if s < step - 2:
                    del batches[s]
        arr = batches.get(step) or next(loader)
        return jax.device_put({"tokens": arr["tokens"]}, b_sh)

    # the dispatch policy is consulted at trace time (first wrapped_step
    # call), so every training GEMM — fwd and the custom-VJP bwd pair —
    # executes with the SARA-recommended configuration
    registry = dispatch.SiteRegistry()

    def wrapped_step(params, opt_state, batch):
        with use_mesh(mesh, cfg.tp_strategy), mesh, \
                _dispatch_ctx("train_step", execute, registry):
            return jitted(params, opt_state, batch)

    driver = TrainDriver(
        DriverConfig(checkpoint_dir=checkpoint_dir,
                     checkpoint_every=checkpoint_every),
        train_step=wrapped_step, make_batch=make_batch,
        fail_injector=fail_injector)

    t0 = time.time()
    params, opt_state, history = driver.run(params, opt_state,
                                            start_step=0, num_steps=steps)
    dt = time.time() - t0
    losses = [h["loss"] for h in history]
    if log_every:
        for h in history[::log_every] + history[-1:]:
            print(f"  step {h['step']:5d} loss {h['loss']:.4f} "
                  f"grad_norm {h.get('grad_norm', 0):.3f}")
    tok_s = steps * global_batch * seq_len / dt
    print(f"train done: {steps} steps in {dt:.1f}s ({tok_s:,.0f} tok/s), "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"{driver.straggler_report()}")
    plan = registry.plan("train_step")
    if plan:
        print(f"  dispatch: {len(plan)} GEMM sites executed "
              f"({dict(registry.backends('train_step'))})")
    loader.close()
    return params, history, driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="reduced",
                    choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--execute", default="auto",
                    choices=["auto", "pallas", "xla"],
                    help="GEMM backend for the dispatch layer")
    a = ap.parse_args()
    train_main(arch=a.arch, preset=a.preset, steps=a.steps,
               global_batch=a.batch, seq_len=a.seq, data_axis=a.data_axis,
               model_axis=a.model_axis, lr=a.lr, checkpoint_dir=a.ckpt,
               execute=a.execute)


if __name__ == "__main__":
    main()
