"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256-class).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is
data-parallel by default (gradient all-reduce crosses the pod boundary) and
can optionally host a 2-stage pipeline (ArchConfig.pipeline_stages=2).

Defined as functions so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before any jax import; smoke tests see 1 device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
