"""ADAPTNET-TPU serving trainer — the offline half of the self-adaptive
loop.

Trains the recommendation network on a *serving-realistic* shape
distribution (logbucket encoding, so lm_head-scale dims are
representable), evaluates plan quality against the analytic oracle, and
saves the params as a loadable artifact (checkpoint/manager.py layout)
that ``SaraDispatcher.from_checkpoint`` / ``serve.py --dispatcher
adaptnet`` consume:

  PYTHONPATH=src python -m repro.launch.train_adaptnet \\
      --samples 200000 --epochs 10 --out /tmp/adaptnet_tpu
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \\
      --dispatcher adaptnet --adaptnet-ckpt /tmp/adaptnet_tpu

The shape distribution mixes (paper §III-B, adapted to serving):

  sites       the (M, K, N) of every GEMM site of the registry
              architectures across decode batch sizes (M = live lanes)
              and prefill bucket sizes — including lm_head columns at
              full vocab (llama3.2-1b 128256, gemma-2b 256000), which
              the paper's raw [0, 10^4] embedding cannot represent;
  background  log-uniform over [1, max_dim]^3 for generalization to
              shapes outside the site list (reduced test configs, new
              architectures).

Labels come from the exhaustive tile-space oracle (closed-form cost
model), exactly like the paper's SCALE-Sim sweep but in seconds.
"""

from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import adaptnet as A
from repro.core import tpu_costmodel as tcm
from repro.core.dataset import Dataset, sample_workloads

DECODE_MS = (1, 2, 4, 8, 16, 32, 64)
PREFILL_MS = (16, 32, 64, 128, 256, 512, 1024, 2048, 4096)
DEFAULT_ARCHS = ("llama3.2-1b", "gemma-2b", "qwen2-moe-a2.7b",
                 "deepseek-coder-33b")


def serving_gemm_shapes(archs: Sequence[str] = DEFAULT_ARCHS,
                        ms: Sequence[int] = DECODE_MS + PREFILL_MS,
                        reduced: bool = False
                        ) -> List[Tuple[int, int, int]]:
    """Distinct (M, K, N) of every GEMM site the serving engine would run
    for these architectures across decode/prefill token counts."""
    from repro.configs.registry import get_arch
    from repro.serving.engine import gemm_sites

    shapes = set()
    for name in archs:
        cfg = get_arch(name)
        if reduced:
            cfg = cfg.reduced()
        for m in ms:
            for _, M, K, N in gemm_sites(cfg, m):
                shapes.add((int(M), int(K), int(N)))
    return sorted(shapes)


def build_serving_dataset(n: int, *,
                          shapes: Optional[Sequence[Tuple[int, int, int]]]
                          = None,
                          max_dim: int = A.MAX_DIM_SERVING,
                          site_frac: float = 0.5, seed: int = 0,
                          chunk: int = 100_000) -> Dataset:
    """``site_frac`` of the samples are draws from the serving site list
    (teaching the net the shapes it will actually be asked about), the
    rest log-uniform background over [1, max_dim]^3."""
    sites = np.asarray(shapes if shapes is not None else
                       serving_gemm_shapes(), np.int64)
    sites = sites[(sites <= max_dim).all(axis=1)]
    if not len(sites):
        raise ValueError(f"no serving shapes fit max_dim={max_dim}")
    rng = np.random.default_rng(seed)
    n_sites = int(n * site_frac)
    feats = np.concatenate([
        sites[rng.integers(0, len(sites), n_sites)],
        sample_workloads(n - n_sites, dist="loguniform", seed=seed + 1,
                         max_dim=max_dim).astype(np.int64),
    ]).astype(np.int32)
    rng.shuffle(feats)
    labels = np.empty(n, np.int32)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)
        labels[lo:hi] = tcm.best_tile_config(
            feats[lo:hi, 0], feats[lo:hi, 1], feats[lo:hi, 2])
    return Dataset(feats, labels, num_classes=tcm.NUM_TILE_CLASSES)


def train_serving_adaptnet(samples: int = 200_000, epochs: int = 10, *,
                           shapes: Optional[Sequence[Tuple[int, int, int]]]
                           = None,
                           max_dim: int = A.MAX_DIM_SERVING,
                           num_buckets: int = 256, site_frac: float = 0.5,
                           seed: int = 0, log: bool = True
                           ) -> Tuple[Dict, dict]:
    """Train ADAPTNET-TPU (logbucket encoding) on the serving shape
    distribution; returns (params, info) where info carries accuracy,
    geomean relative tile cost, and the encoding metadata that gets
    persisted alongside the checkpoint."""
    ds = build_serving_dataset(samples, shapes=shapes, max_dim=max_dim,
                               site_frac=site_frac, seed=seed)
    tr, te = ds.split()
    cfg = A.AdaptNetConfig(num_classes=ds.num_classes, encoding="logbucket",
                           num_buckets=num_buckets, max_dim=max_dim)
    res = A.train(tr, te, epochs=epochs, seed=seed, log=log, cfg=cfg)
    pred = A.predict(res.params, te.features)
    cost = tcm.tile_cost_seconds(te.features[:, 0], te.features[:, 1],
                                 te.features[:, 2])
    chosen = np.take_along_axis(cost, pred[:, None].astype(int), -1)[:, 0]
    rel = np.clip(chosen / cost.min(-1), 1.0, None)
    info = {
        "encoding": "logbucket",
        "num_buckets": num_buckets,
        "max_dim": int(max_dim),
        "num_classes": int(ds.num_classes),
        "samples": int(samples),
        "epochs": int(epochs),
        "site_frac": float(site_frac),
        "accuracy": float(res.test_accuracy),
        "geomean_rel_time": float(np.exp(np.mean(np.log(rel)))),
        "train_seconds": float(res.train_seconds),
    }
    return res.params, info


def save_adaptnet(directory: str, params: Dict, info: dict) -> None:
    """Persist a trained ADAPTNET-TPU as a step-0 checkpoint; the params
    dict (bucket_edges/dim_max included) restores with
    ``core.sara.load_adaptnet`` / ``SaraDispatcher.from_checkpoint``."""
    from repro.checkpoint.manager import CheckpointManager
    CheckpointManager(directory, keep=1).save(0, params, metadata=info)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=200_000)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--out", default="/tmp/adaptnet_tpu",
                    help="checkpoint directory for the trained artifact")
    ap.add_argument("--max-dim", type=int, default=A.MAX_DIM_SERVING)
    ap.add_argument("--buckets", type=int, default=256)
    ap.add_argument("--site-frac", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    a = ap.parse_args()

    params, info = train_serving_adaptnet(
        a.samples, a.epochs, max_dim=a.max_dim, num_buckets=a.buckets,
        site_frac=a.site_frac, seed=a.seed, log=not a.quiet)
    save_adaptnet(a.out, params, info)

    # round-trip through the loader the dispatcher uses, and sanity-check a
    # recommendation on a real serving shape (llama3.2-1b lm_head)
    from repro.core.sara import SaraDispatcher, load_adaptnet
    params2, meta = load_adaptnet(a.out)
    assert meta["accuracy"] == info["accuracy"]
    disp = SaraDispatcher(mode="adaptnet", adaptnet_params=params2)
    cfg = disp.recommend(64, 2048, 128256)
    src = disp.source_of(64, 2048, 128256)
    print(f"adaptnet-tpu: acc={info['accuracy']:.4f} "
          f"geomean_rel_time={info['geomean_rel_time']:.4f} "
          f"-> saved to {a.out}")
    print(f"  lm_head probe (64x2048x128256): [{cfg.describe()}] src={src}")


if __name__ == "__main__":
    main()
