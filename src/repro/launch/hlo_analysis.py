"""Post-optimization HLO analysis: trip-count-aware FLOPs / bytes /
collective traffic, and the §Roofline terms.

Why not ``compiled.cost_analysis()`` alone?  XLA's cost analysis counts each
``while`` body ONCE — a 61-layer scanned transformer reports ~1/61 of its
real FLOPs (verified empirically on the CPU backend).  Since every model
here runs scan-over-layers (mandatory for 512-device compile times), we parse
the optimized HLO text ourselves:

1. split the module into computations;
2. build the call graph (while body/condition, call/conditional, fusion);
3. extract while trip counts from the loop-condition constant;
4. propagate execution weights from ENTRY through the graph;
5. count, per computation and weighted:
   - FLOPs of every ``dot`` (2 * prod(out_shape) * contracted size, operand
     shapes resolved through the instruction table),
   - HBM traffic at fusion boundaries (operands + results of non-trivial
     instructions — XLA has already fused elementwise chains, so fusion
     parameters/results are exactly the tensors that cross HBM),
   - collective bytes by opcode (all-reduce counted 2x; reduce-scatter
     scaled by group size).

Shapes in SPMD HLO are PER-DEVICE; *_global figures multiply by chip count.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.hw import TPU_V5E, TPUChip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s*->")
# type part may be a tuple "(s32[], bf16[2,4]{1,0})" or a plain shape with a
# layout "bf16[64,256]{1,0}"; opcode is the first bare word followed by "(".
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CALL_ATTRS = re.compile(
    r"(?:condition|body|to_apply|calls)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

# opcodes whose operands/results do NOT cross HBM (control / aliasing / glue).
# `copy` is buffer-safety glue the CPU backend inserts around while-loop
# carries; TPU buffer assignment elides it (aliased in-place).
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call", "custom-call", "get-dimension-size", "copy",
    "copy-start", "copy-done", "optimization-barrier",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "ragged-all-to-all", "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    line: str
    operands: List[str]


@dataclass
class _Comp:
    name: str
    instrs: Dict[str, _Instr] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    root: Optional[str] = None


def _parse_computations(hlo_text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry: Optional[str] = None
    cur: Optional[_Comp] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = _COMP_HEADER.match(stripped)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY") or raw.startswith("ENTRY"):
                    entry = cur.name
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, type_str, opcode = im.groups()
        paren = line[im.end():]
        # operand list = up to the matching close paren (flat heuristic:
        # operands come first, attrs after "),")
        op_part = paren.split(")", 1)[0]
        operands = _OPERAND.findall(op_part)
        cur.instrs[name] = _Instr(name, type_str.strip(), opcode, line,
                                  operands)
        cur.order.append(name)
        if stripped.startswith("ROOT"):
            cur.root = name
    return comps, entry


def _trip_count(comps: Dict[str, _Comp], cond_name: str) -> int:
    """Max integer constant in the loop condition (and its fusion callees)."""
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        cn = stack.pop()
        if cn in seen or cn not in comps:
            continue
        seen.add(cn)
        for iname in comps[cn].order:
            ins = comps[cn].instrs[iname]
            for c in _CONST_INT.findall(ins.line):
                best = max(best, int(c))
            if ins.opcode == "fusion":
                stack.extend(_CALL_ATTRS.findall(ins.line))
    return best


def _call_edges(comps: Dict[str, _Comp]) -> Dict[str, List[Tuple[str, float]]]:
    """caller -> [(callee, multiplier)]; while bodies weighted by trip count."""
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    for cname, comp in comps.items():
        for iname in comp.order:
            ins = comp.instrs[iname]
            if ins.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                cond = mc.group(1) if mc else None
                trip = _trip_count(comps, cond) if cond else 1
                if mb and mb.group(1) in comps:
                    edges[cname].append((mb.group(1), float(trip)))
                if cond in comps:
                    edges[cname].append((cond, float(trip)))
            else:
                callees = _CALL_ATTRS.findall(ins.line)
                bm = _BRANCHES.search(ins.line)
                if bm:
                    callees += _OPERAND.findall(bm.group(1))
                for cal in callees:
                    if cal in comps:
                        edges[cname].append((cal, 1.0))
    return edges


def _weights(comps: Dict[str, _Comp], entry: str) -> Dict[str, float]:
    """Execution count per computation: topological accumulation over the
    (acyclic) call graph, SUMMING over call sites, multiplying trip counts."""
    edges = _call_edges(comps)
    # Kahn topological order
    indeg: Dict[str, int] = {c: 0 for c in comps}
    for cname, outs in edges.items():
        for cal, _ in outs:
            indeg[cal] += 1
    ready = [c for c, d in indeg.items() if d == 0]
    order: List[str] = []
    while ready:
        c = ready.pop()
        order.append(c)
        for cal, _ in edges[c]:
            indeg[cal] -= 1
            if indeg[cal] == 0:
                ready.append(cal)
    weights: Dict[str, float] = {c: 0.0 for c in comps}
    if entry in weights:
        weights[entry] = 1.0
    for c in order:
        w = weights.get(c, 0.0)
        if w <= 0.0:
            continue
        for cal, mult in edges[c]:
            weights[cal] += w * mult
    return weights


# computations reachable only via fusion/reduce `calls=`/`to_apply=` hold no
# HBM traffic of their own (their cost sits at the call site), but they DO
# hold dot ops (XLA wraps dots in kOutput fusions on some backends).
def _control_flow_reachable(comps, entry) -> set:
    seen = set()
    stack = [entry]
    while stack:
        cn = stack.pop()
        if cn in seen or cn not in comps:
            continue
        seen.add(cn)
        for iname in comps[cn].order:
            ins = comps[cn].instrs[iname]
            if ins.opcode in ("while", "conditional", "call"):
                stack.extend(_CALL_ATTRS.findall(ins.line))
                bm = _BRANCHES.search(ins.line)
                if bm:
                    stack.extend(_OPERAND.findall(bm.group(1)))
    return seen


@dataclass
class HLOStats:
    flops: float = 0.0                    # per-device, trip-weighted
    hbm_bytes: float = 0.0                # per-device, fusion-boundary traffic
    collective_bytes_by_op: Dict[str, float] = field(default_factory=dict)
    collective_count_by_op: Dict[str, int] = field(default_factory=dict)
    raw_cost_analysis: Dict[str, float] = field(default_factory=dict)
    vmem_credited_bodies: int = 0         # while bodies under the VMEM rule

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective_bytes_by_op.values())


def _update_bytes(comp: _Comp, ins: _Instr) -> int:
    """dus/scatter: bytes of the update operand (in-place region)."""
    if len(ins.operands) >= 2:
        t = comp.instrs.get(ins.operands[1])
        if t:
            return _type_bytes(t.type_str)
    return _type_bytes(ins.type_str)


def _dus_fusion_bytes(comps: Dict[str, _Comp], comp: _Comp,
                      ins: _Instr, credited: bool = False) -> Optional[float]:
    """In-place-update bytes for a fusion whose root is (a tuple of)
    dynamic-update-slice — the functional carry-and-update pattern XLA
    emits for loop-state writes.  TPU buffer assignment updates the
    aliased buffer in place, so traffic is the updated region (RMW),
    not the whole buffer.  Returns None when the fusion is not
    update-shaped."""
    m = _CALL_ATTRS.search(ins.line)
    fc = comps.get(m.group(1)) if m else None
    if fc is None or fc.root is None:
        return None

    def strip_casts(r: Optional[_Instr]) -> Optional[_Instr]:
        # CPU backend wraps the dus in bf16<->f32 converts; follow through
        seen = 0
        while r is not None and r.opcode in ("convert", "bitcast", "copy") \
                and r.operands and seen < 8:
            r = fc.instrs.get(r.operands[0])
            seen += 1
        return r

    root = strip_casts(fc.instrs.get(fc.root))
    if root is None:
        return None
    roots = [root]
    if root.opcode == "tuple":
        roots = [strip_casts(fc.instrs.get(o)) for o in root.operands]
    if not roots or any(r is None or r.opcode != "dynamic-update-slice"
                        for r in roots):
        return None
    total = 0.0
    f = 1.0 if credited else 2.0
    for r in roots:
        scale = 1.0
        if len(r.operands) >= 2:
            scale = _semantic_dtype_scale(fc, r.operands[1])
        total += f * _update_bytes(fc, r) * scale
    if credited:
        return total       # non-buffer operands are VMEM-resident
    # external operands the fusion reads, except the aliased buffers
    # (matched on element count — dtype may differ through converts)
    def elems(type_str: str) -> int:
        n = 0
        for _, dims in _SHAPE.findall(type_str):
            e = 1
            for d in dims.split(","):
                if d.strip():
                    e *= int(d)
            n += e
        return n

    buf_elems = {elems(r.type_str) for r in roots}
    for opn in ins.operands:
        t = comp.instrs.get(opn)
        if t and elems(t.type_str) not in buf_elems:
            total += _type_bytes(t.type_str)
    return total


def _while_bodies(comps: Dict[str, _Comp]) -> set:
    bodies = set()
    for comp in comps.values():
        for iname in comp.order:
            ins = comp.instrs[iname]
            if ins.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if mb:
                    bodies.add(mb.group(1))
    return bodies


def _body_working_set(comps: Dict[str, _Comp], comp: _Comp) -> float:
    """One-iteration working set: sum of non-free instruction outputs
    (dus — bare or fusion-rooted — counts its in-place update region,
    not the full aliased buffer)."""
    ws = 0.0
    for iname in comp.order:
        ins = comp.instrs[iname]
        if ins.opcode in _FREE_OPS:
            continue
        if ins.opcode in ("dynamic-update-slice", "scatter"):
            ws += _update_bytes(comp, ins)
            continue
        if ins.opcode == "fusion":
            ub = _dus_fusion_bytes(comps, comp, ins)
            if ub is not None:
                ws += ub
                continue
        ws += _type_bytes(ins.type_str)
    return ws


def _vmem_credited(comps: Dict[str, _Comp],
                   budget: float) -> set:
    """While bodies whose full iteration working set fits in VMEM.

    TPU adaptation rule (DESIGN.md §2.2): a loop body whose entire
    iteration working set fits in VMEM does not round-trip HBM for
    intra-body intermediates — only its HBM block reads (dynamic-slice /
    gather) and block writes (dynamic-update-slice / scatter) are real
    traffic.  This is what a hand-written Pallas kernel achieves by
    construction (BlockSpec streaming + VMEM scratch), and is the TPU
    analogue of the paper's systolic-cell operand-reuse argument.  The rule
    is applied uniformly: big XLA scan bodies (e.g. whole-batch blockwise
    attention steps, 100+ MB) do NOT qualify; restructuring the loop so the
    working set fits (what kernels/flash_attn.py does) is the optimization.
    """
    credited = set()
    for bname in _while_bodies(comps):
        comp = comps.get(bname)
        if comp is not None and _body_working_set(comps, comp) <= budget:
            credited.add(bname)
    return credited


def analyze_hlo(hlo_text: str,
                vmem_credit_budget: Optional[float] = None) -> HLOStats:
    comps, entry = _parse_computations(hlo_text)
    stats = HLOStats()
    if entry is None:
        return stats
    weights = _weights(comps, entry)
    cf_comps = _control_flow_reachable(comps, entry)
    if vmem_credit_budget is None:
        vmem_credit_budget = TPU_V5E.vmem_bytes
    credited = _vmem_credited(comps, vmem_credit_budget)
    stats.vmem_credited_bodies = len(credited)

    def lookup_type(comp: _Comp, name: str) -> Optional[str]:
        ins = comp.instrs.get(name)
        return ins.type_str if ins else None

    for cname, comp in comps.items():
        w = weights.get(cname, 0.0)
        if w <= 0.0:
            continue
        in_cf = cname in cf_comps
        is_credited = cname in credited
        for iname in comp.order:
            ins = comp.instrs[iname]
            # ---- FLOPs: dots anywhere -----------------------------------
            if ins.opcode == "dot":
                out_elems = 1
                for d in _shape_dims(ins.type_str):
                    out_elems *= d
                k = 1
                cm = _CONTRACT.search(ins.line)
                if cm and ins.operands:
                    lhs_t = lookup_type(comp, ins.operands[0])
                    if lhs_t:
                        lhs_dims = _shape_dims(lhs_t)
                        for ci in cm.group(1).split(","):
                            if ci.strip() and int(ci) < len(lhs_dims):
                                k *= lhs_dims[int(ci)]
                stats.flops += w * 2.0 * out_elems * k
            # ---- collectives ---------------------------------------------
            if ins.opcode in _COLLECTIVES or \
                    any(ins.opcode == c + "-start" for c in _COLLECTIVES):
                op = ins.opcode.replace("-start", "")
                size = _type_bytes(ins.type_str)
                gs = 1
                gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", ins.line)
                if gm:
                    gs = int(gm.group(2))
                else:
                    gl = re.search(r"replica_groups=\{\{([^}]*)\}", ins.line)
                    if gl:
                        gs = len([x for x in gl.group(1).split(",")
                                  if x.strip()])
                if op == "all-reduce":
                    size *= 2
                elif op == "reduce-scatter":
                    size *= gs
                stats.collective_bytes_by_op[op] = \
                    stats.collective_bytes_by_op.get(op, 0.0) + w * size
                stats.collective_count_by_op[op] = \
                    stats.collective_count_by_op.get(op, 0) + int(w)
            # ---- HBM traffic at fusion boundaries ------------------------
            if in_cf and ins.opcode not in _FREE_OPS:
                stats.hbm_bytes += w * _instr_traffic(comps, comp, ins,
                                                      is_credited)
    return stats


def _semantic_dtype_scale(comp: _Comp, name: str) -> float:
    """CPU-excess-precision normalization: if `name` resolves to a convert
    from a narrower dtype (bf16 -> f32 upcast the CPU backend inserts around
    every region the TPU would keep in bf16), scale its bytes down to the
    source width.  Applied to sliced/updated regions only."""
    ins = comp.instrs.get(name)
    if ins is None or ins.opcode != "convert" or not ins.operands:
        return 1.0
    src = comp.instrs.get(ins.operands[0])
    if src is None:
        return 1.0
    out_dt = _SHAPE.search(ins.type_str)
    src_dt = _SHAPE.search(src.type_str)
    if not out_dt or not src_dt:
        return 1.0
    ob = _DTYPE_BYTES.get(out_dt.group(1), 4)
    sb = _DTYPE_BYTES.get(src_dt.group(1), 4)
    return sb / ob if 0 < sb < ob else 1.0


def _instr_traffic(comps: Dict[str, _Comp], comp: _Comp, ins: _Instr,
                   credited: bool) -> float:
    """HBM bytes attributed to one instruction execution.

    In a VMEM-credited while body, only block reads (ds/slice/gather) and
    block writes (dus/scatter) touch HBM; everything else is VMEM-resident —
    and those block transfers move once (the result lives in VMEM).  In an
    uncredited body a slice result is also materialized back (read+write,
    2x).  Fusions rooted in dynamic-update-slice count as in-place updates.
    """
    f = 1.0 if credited else 2.0
    if ins.opcode in ("dynamic-slice", "slice", "gather"):
        scale = _semantic_dtype_scale(comp, ins.operands[0]) \
            if credited and ins.operands else 1.0
        return f * _type_bytes(ins.type_str) * scale
    if ins.opcode in ("dynamic-update-slice", "scatter"):
        scale = 1.0
        if credited and len(ins.operands) >= 2:
            scale = _semantic_dtype_scale(comp, ins.operands[1])
        return f * _update_bytes(comp, ins) * scale
    if ins.opcode == "fusion":
        ub = _dus_fusion_bytes(comps, comp, ins, credited)
        if ub is not None:
            return ub
    if credited:
        return 0.0
    traffic = float(_type_bytes(ins.type_str))
    for opn in ins.operands:
        t = comp.instrs.get(opn)
        if t:
            traffic += _type_bytes(t.type_str)
    return traffic


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

@dataclass
class RooflineTerms:
    """All terms in SECONDS (per the assignment formulas)."""
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_global: float
    hlo_bytes_global: float
    collective_bytes_global: float
    chips: int
    model_flops: float = 0.0
    model_min_bytes: float = 0.0   # compulsory HBM traffic (global, bytes)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        if self.hlo_flops_global <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops_global

    @property
    def ideal_compute_s(self) -> float:
        return self.model_flops / (self.chips * TPU_V5E.peak_bf16_flops)

    @property
    def ideal_memory_s(self) -> float:
        return self.model_min_bytes / (self.chips * TPU_V5E.hbm_bw)

    @property
    def roofline_fraction(self) -> float:
        """time(MODEL_FLOPS at peak on all chips) / max(term) — MFU-style."""
        if self.bound_s <= 0:
            return 0.0
        return self.ideal_compute_s / self.bound_s

    @property
    def memory_attainment(self) -> float:
        """compulsory traffic / achieved traffic — how tight the memory term
        is vs. its floor (the honest metric for memory-bound steps)."""
        if self.memory_s <= 0:
            return 0.0
        return self.ideal_memory_s / self.memory_s

    @property
    def bound_attainment(self) -> float:
        """max(ideal compute, compulsory memory) / max(term): the roofline
        fraction that credits memory-bound steps (decode) with their
        unavoidable weight/cache traffic instead of scoring them as MFU≈0."""
        if self.bound_s <= 0:
            return 0.0
        return max(self.ideal_compute_s, self.ideal_memory_s) / self.bound_s

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops_global": self.hlo_flops_global,
            "hlo_bytes_global": self.hlo_bytes_global,
            "collective_bytes_global": self.collective_bytes_global,
            "chips": self.chips, "model_flops": self.model_flops,
            "model_min_bytes": self.model_min_bytes,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_attainment": self.memory_attainment,
            "bound_attainment": self.bound_attainment,
        }


def roofline_from_stats(stats: HLOStats, chips: int, model_flops: float = 0.0,
                        chip: TPUChip = TPU_V5E,
                        model_min_bytes: float = 0.0) -> RooflineTerms:
    return RooflineTerms(
        compute_s=stats.flops / chip.peak_bf16_flops,
        memory_s=stats.hbm_bytes / chip.hbm_bw,
        collective_s=stats.collective_bytes / chip.ici_link_bw,
        hlo_flops_global=stats.flops * chips,
        hlo_bytes_global=stats.hbm_bytes * chips,
        collective_bytes_global=stats.collective_bytes * chips,
        chips=chips,
        model_flops=model_flops,
        model_min_bytes=model_min_bytes,
    )


def roofline_from_compiled(compiled, chips: int, model_flops: float = 0.0,
                           chip: TPUChip = TPU_V5E,
                           hlo_text: Optional[str] = None,
                           model_min_bytes: float = 0.0
                           ) -> Tuple[RooflineTerms, HLOStats]:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    stats = analyze_hlo(text)
    ca = compiled.cost_analysis() or {}
    stats.raw_cost_analysis = {k: float(v) for k, v in ca.items()
                               if isinstance(v, (int, float))}
    return (roofline_from_stats(stats, chips, model_flops, chip,
                                model_min_bytes), stats)


# ---------------------------------------------------------------------------
# profile: top HBM/FLOP contributors (the dry-run "profiler" for §Perf)
# ---------------------------------------------------------------------------

def profile_hlo(hlo_text: str, top: int = 25,
                vmem_credit_budget: Optional[float] = None) -> List[dict]:
    """Trip-weighted per-instruction traffic/FLOPs, sorted by HBM bytes.

    Returns the top-k rows: computation, instruction name, opcode, output
    type, weighted bytes, weighted flops.  This is the hypothesis generator
    for the §Perf loop: 'which tensors cross HBM most?'.  Uses the same
    VMEM-credit rule as analyze_hlo.
    """
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        return []
    weights = _weights(comps, entry)
    cf_comps = _control_flow_reachable(comps, entry)
    if vmem_credit_budget is None:
        vmem_credit_budget = TPU_V5E.vmem_bytes
    credited = _vmem_credited(comps, vmem_credit_budget)
    rows: List[dict] = []
    for cname, comp in comps.items():
        w = weights.get(cname, 0.0)
        if w <= 0.0:
            continue
        in_cf = cname in cf_comps
        is_credited = cname in credited
        for iname in comp.order:
            ins = comp.instrs[iname]
            flops = 0.0
            if ins.opcode == "dot":
                out_elems = 1
                for d in _shape_dims(ins.type_str):
                    out_elems *= d
                k = 1
                cm = _CONTRACT.search(ins.line)
                if cm and ins.operands:
                    t = comp.instrs.get(ins.operands[0])
                    if t:
                        lhs_dims = _shape_dims(t.type_str)
                        for ci in cm.group(1).split(","):
                            if ci.strip() and int(ci) < len(lhs_dims):
                                k *= lhs_dims[int(ci)]
                flops = w * 2.0 * out_elems * k
            traffic = 0.0
            if in_cf and ins.opcode not in _FREE_OPS:
                traffic = w * _instr_traffic(comps, comp, ins, is_credited)
            if traffic > 0 or flops > 0:
                rows.append({"comp": cname + ("*" if is_credited else ""),
                             "instr": iname,
                             "opcode": ins.opcode,
                             "type": ins.type_str[:60],
                             "weight": w, "bytes": traffic, "flops": flops})
    rows.sort(key=lambda r: r["bytes"], reverse=True)
    return rows[:top]


def profile_by_opcode(hlo_text: str) -> List[dict]:
    """Aggregate trip-weighted bytes/flops by opcode (whole-program view)."""
    agg: Dict[str, dict] = {}
    for r in profile_hlo(hlo_text, top=10 ** 9):
        a = agg.setdefault(r["opcode"], {"opcode": r["opcode"], "bytes": 0.0,
                                         "flops": 0.0, "count": 0})
        a["bytes"] += r["bytes"]
        a["flops"] += r["flops"]
        a["count"] += 1
    rows = sorted(agg.values(), key=lambda r: r["bytes"], reverse=True)
    return rows
