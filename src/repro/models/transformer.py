"""Unified model assembly for all assigned architecture families.

Families:
  dense   — pre-norm decoder: GQA/MQA attention + gated MLP
  moe     — attention + (shared + routed top-k) MoE FFN
  ssm     — RWKV6 blocks (attention-free)
  hybrid  — Mamba2 blocks with one weight-shared attention block every
            `shared_attn_every` slots (zamba2-style)
  encdec  — bidirectional encoder + causal decoder with cross-attention
            (audio frontend stub feeds the encoder)
  vlm     — decoder LM with vision-patch embeddings (stub) prepended

All homogeneous layer stacks run under ``jax.lax.scan`` over stacked
parameters (O(1) HLO size — essential for 512-device dry-run compiles), with
optional per-block remat.  Caches for decode are stacked along the layer axis
and scanned in lock-step with the parameters.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (KVCache, cross_attention_kv,
                                    gqa_cross_attention, gqa_self_attention,
                                    init_gqa, init_gqa_cache, init_mla,
                                    init_mla_cache, mla_self_attention)
from repro.models.mlp import init_mlp, mlp_apply
from repro.models.moe import init_moe, moe_apply
from repro.models.modules import (dense, dense_init, embed_init, rmsnorm,
                                  stack_layer_params)
from repro.parallel.hints import hint

Params = Dict[str, Any]

AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_drop_frac")


def _zero_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _is_moe_layer(cfg: ArchConfig, layer_idx: int) -> bool:
    if cfg.moe is None:
        return False
    if layer_idx < cfg.first_dense_layers:
        return False
    return (layer_idx - cfg.first_dense_layers) % cfg.moe_every == 0


def init_decoder_layer(key, cfg: ArchConfig, layer_idx: int,
                       cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    p: Params = {"ln1": jnp.zeros((cfg.d_model,), dt),
                 "ln2": jnp.zeros((cfg.d_model,), dt)}
    if cfg.attention_type == "mla":
        p["attn"] = init_mla(ks[0], cfg)
    else:
        p["attn"] = init_gqa(ks[0], cfg)
    if _is_moe_layer(cfg, layer_idx):
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg)
    if cross:
        p["ln_cross"] = jnp.zeros((cfg.d_model,), dt)
        p["cross"] = init_gqa(ks[2], cfg, d_in=cfg.d_model, cross=True)
    return p


def decoder_layer_apply(p: Params, x, positions, cfg: ArchConfig, *,
                        cache: Optional[KVCache] = None,
                        update_cache: bool = False,
                        enc_kv=None) -> Tuple[jnp.ndarray, Optional[KVCache],
                                              Dict[str, jnp.ndarray]]:
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.attention_type == "mla":
        a, new_cache = mla_self_attention(p["attn"], h, positions, cfg,
                                          cache=cache, update_cache=update_cache)
    else:
        a, new_cache = gqa_self_attention(p["attn"], h, positions, cfg,
                                          cache=cache, update_cache=update_cache)
    x = x + a.astype(x.dtype)
    if enc_kv is not None:
        hc = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        x = x + gqa_cross_attention(p["cross"], hc, enc_kv, cfg).astype(x.dtype)
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    aux = _zero_aux()
    if "moe" in p:
        f, moe_aux = moe_apply(p["moe"], h2, cfg)
        aux.update(moe_aux)
    else:
        f = mlp_apply(p["mlp"], h2, cfg)
    x = x + f.astype(x.dtype)
    return x, new_cache, aux


def paged_decoder_layer_apply(p: Params, x, positions, cfg: ArchConfig, *,
                              k_arena, v_arena, block_tables, kv_lens,
                              write_mask, enc_kv=None):
    """One decoder layer's batched single-token decode through the paged KV
    arena (mirrors :func:`decoder_layer_apply`; see
    models/attention.py::gqa_paged_decode for the arena contract).
    Returns (x, new_k_arena, new_v_arena)."""
    from repro.models.attention import gqa_paged_decode, mla_paged_decode

    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    paged = dict(block_tables=block_tables, kv_lens=kv_lens,
                 write_mask=write_mask)
    if cfg.attention_type == "mla":
        a, nk, nv = mla_paged_decode(p["attn"], h, positions, cfg,
                                     ckv_arena=k_arena, krope_arena=v_arena,
                                     **paged)
    else:
        a, nk, nv = gqa_paged_decode(p["attn"], h, positions, cfg,
                                     k_arena=k_arena, v_arena=v_arena,
                                     **paged)
    x = x + a.astype(x.dtype)
    if enc_kv is not None:
        hc = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        x = x + gqa_cross_attention(p["cross"], hc, enc_kv, cfg).astype(x.dtype)
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, _ = moe_apply(p["moe"], h2, cfg)
    else:
        f = mlp_apply(p["mlp"], h2, cfg)
    x = x + f.astype(x.dtype)
    return x, nk, nv


def paged_shared_decoder_layer_apply(p: Params, x, positions,
                                     cfg: ArchConfig, *, k_arena, v_arena,
                                     block_tables, kv_lens, write_mask,
                                     prefix_pages, prefix_lens,
                                     unique_tables, unique_lens):
    """Cascade-decode twin of :func:`paged_decoder_layer_apply`: attention
    over a shared page prefix is computed once per step for every lane in
    the sharing group (models/attention.py::gqa_paged_shared_decode).  GQA
    families only — absorbed MLA keeps the plain paged path.  Returns
    (x, new_k_arena, new_v_arena)."""
    from repro.models.attention import gqa_paged_shared_decode

    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, nk, nv = gqa_paged_shared_decode(
        p["attn"], h, positions, cfg, k_arena=k_arena, v_arena=v_arena,
        block_tables=block_tables, kv_lens=kv_lens, write_mask=write_mask,
        prefix_pages=prefix_pages, prefix_lens=prefix_lens,
        unique_tables=unique_tables, unique_lens=unique_lens)
    x = x + a.astype(x.dtype)
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, _ = moe_apply(p["moe"], h2, cfg)
    else:
        f = mlp_apply(p["mlp"], h2, cfg)
    x = x + f.astype(x.dtype)
    return x, nk, nv


def paged_prefill_layer_apply(p: Params, x, positions, cfg: ArchConfig, *,
                              k_arena, v_arena, block_tables, kv_lens,
                              chunk_lens):
    """One decoder layer's chunked-prefill pass through the paged KV arena
    (mirrors :func:`paged_decoder_layer_apply` widened to C causal rows per
    lane; see models/attention.py::gqa_paged_prefill for the arena
    contract).  Returns (x, new_k_arena, new_v_arena)."""
    from repro.models.attention import gqa_paged_prefill, mla_paged_prefill

    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    paged = dict(block_tables=block_tables, kv_lens=kv_lens,
                 chunk_lens=chunk_lens)
    if cfg.attention_type == "mla":
        a, nk, nv = mla_paged_prefill(p["attn"], h, positions, cfg,
                                      ckv_arena=k_arena, krope_arena=v_arena,
                                      **paged)
    else:
        a, nk, nv = gqa_paged_prefill(p["attn"], h, positions, cfg,
                                      k_arena=k_arena, v_arena=v_arena,
                                      **paged)
    x = x + a.astype(x.dtype)
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, _ = moe_apply(p["moe"], h2, cfg)
    else:
        f = mlp_apply(p["mlp"], h2, cfg)
    x = x + f.astype(x.dtype)
    return x, nk, nv


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig) -> Params:
    dt = cfg.param_dtype
    keys = jax.random.split(key, cfg.num_layers + cfg.encoder_layers + 8)
    p: Params = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "ln_f": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dt)

    if cfg.frontend.kind == "audio_frames":
        p["frontend"] = {
            "proj": dense_init(keys[2], cfg.frontend.feature_dim, cfg.d_model, dt)}
    elif cfg.frontend.kind == "vision_patches":
        k1, k2 = jax.random.split(keys[2])
        p["frontend"] = {   # 2-layer MLP projector (InternVL-style)
            "proj1": dense_init(k1, cfg.frontend.feature_dim, cfg.d_model, dt),
            "proj2": dense_init(k2, cfg.d_model, cfg.d_model, dt),
        }

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        nd = cfg.first_dense_layers if cfg.moe is not None else 0
        if cfg.moe is not None and cfg.moe_every != 1:
            raise NotImplementedError("moe_every != 1 (stacks must be "
                                      "homogeneous for scan)")
        if nd:
            p["dense_layers"] = stack_layer_params(
                [init_decoder_layer(keys[8 + i], cfg, i) for i in range(nd)])
        layers = [init_decoder_layer(keys[8 + i], cfg, i)
                  for i in range(nd, cfg.num_layers)]
        p["layers"] = stack_layer_params(layers)
    elif fam == "ssm":
        layers = [{"ln1": jnp.zeros((cfg.d_model,), dt),
                   **{"blk": ssm_mod.init_rwkv_block(keys[8 + i], cfg)}}
                  for i in range(cfg.num_layers)]
        p["layers"] = stack_layer_params(layers)
    elif fam == "hybrid":
        n_m, n_groups, per_group, rem = hybrid_layout(cfg)
        layers = [{"ln1": jnp.zeros((cfg.d_model,), dt),
                   "blk": ssm_mod.init_mamba_block(keys[8 + i], cfg)}
                  for i in range(n_m)]
        p["layers"] = stack_layer_params(layers)
        p["shared_attn"] = init_decoder_layer(keys[4], cfg, layer_idx=-1)
    elif fam == "encdec":
        enc = [init_encoder_layer(keys[8 + i], cfg)
               for i in range(cfg.encoder_layers)]
        dec = [init_decoder_layer(keys[8 + cfg.encoder_layers + i], cfg, i,
                                  cross=True)
               for i in range(cfg.num_layers)]
        p["enc_layers"] = stack_layer_params(enc)
        p["layers"] = stack_layer_params(dec)
        p["ln_enc"] = jnp.zeros((cfg.d_model,), dt)
    else:
        raise ValueError(f"unknown family {fam}")
    return p


def init_encoder_layer(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 2)
    dt = cfg.param_dtype
    return {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "attn": init_gqa(ks[0], cfg),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg),
    }


def hybrid_layout(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    """(num_mamba_layers, num_groups, mamba_per_group, remainder).

    Layer slots: every `shared_attn_every`-th slot is the shared attention
    block; the rest are Mamba2 blocks.  num_layers counts all slots.
    """
    k = cfg.shared_attn_every
    n_groups = cfg.num_layers // k
    per_group = k - 1
    rem = cfg.num_layers - n_groups * k
    n_m = n_groups * per_group + rem
    return n_m, n_groups, per_group, rem


# ---------------------------------------------------------------------------
# forward passes (train: no cache)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ArchConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _embed(params, tokens, cfg: ArchConfig):
    cdt = jnp.dtype(cfg.compute_dtype)
    return hint(params["embed"][tokens].astype(cdt), "B", None, None)


def _frontend_embed(params, feats, cfg: ArchConfig):
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.frontend.kind == "audio_frames":
        return dense(feats, params["frontend"]["proj"], None, cdt,
                     site="frontend.proj")
    h = dense(feats, params["frontend"]["proj1"], None, cdt,
              site="frontend.proj1")
    return dense(jax.nn.gelu(h), params["frontend"]["proj2"], None, cdt,
                 site="frontend.proj2")


def _scan_decoder(params, x, positions, cfg: ArchConfig, enc_kv=None):
    """Scan homogeneous decoder layers (dense/moe/vlm/encdec-decoder).

    MoE models with leading dense layers (deepseek-v3) carry them as a
    second homogeneous stack under params["dense_layers"]."""

    def body(carry, layer_p):
        h, aux = carry
        h = hint(h, "B", None, None)
        h, _, a = decoder_layer_apply(layer_p, h, positions, cfg, enc_kv=enc_kv)
        aux = {k: aux[k] + a[k] for k in AUX_KEYS}
        return (h, aux), None

    body = _maybe_remat(body, cfg)
    carry = (x, _zero_aux())
    if "dense_layers" in params:
        carry, _ = jax.lax.scan(body, carry, params["dense_layers"])
    (x, aux), _ = jax.lax.scan(body, carry, params["layers"])
    return x, aux


def _scan_rwkv(params, x, cfg: ArchConfig, states):
    def body(carry, xs):
        h = carry
        layer_p, st = xs
        hn = rmsnorm(h, layer_p["ln1"], cfg.norm_eps)
        y, new_st = ssm_mod.rwkv_block_apply(layer_p["blk"], hn, cfg, st)
        return h + y.astype(h.dtype), new_st

    body = _maybe_remat(body, cfg)
    x, new_states = jax.lax.scan(body, x, (params["layers"], states))
    return x, new_states


def _scan_mamba_span(layer_params, x, cfg: ArchConfig, states):
    def body(carry, xs):
        h = carry
        layer_p, st = xs
        hn = rmsnorm(h, layer_p["ln1"], cfg.norm_eps)
        y, new_st = ssm_mod.mamba_block_apply(layer_p["blk"], hn, cfg, st)
        return h + y.astype(h.dtype), new_st

    body = _maybe_remat(body, cfg)
    x, new_states = jax.lax.scan(body, x, (layer_params, states))
    return x, new_states


def _hybrid_forward(params, x, positions, cfg: ArchConfig, states,
                    attn_caches=None, update_cache: bool = False):
    """zamba2-style: groups of (per_group mamba) + shared attn; remainder."""
    n_m, n_groups, per_group, rem = hybrid_layout(cfg)
    lp = params["layers"]

    def take(tree, lo, hi):
        return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)

    def reshape_groups(tree, lo, hi):
        return jax.tree_util.tree_map(
            lambda a: a[lo:hi].reshape((n_groups, per_group) + a.shape[1:]),
            tree)

    grouped_p = reshape_groups(lp, 0, n_groups * per_group)
    grouped_s = reshape_groups(states, 0, n_groups * per_group)
    shared_p = params["shared_attn"]

    # outer scan over groups; shared attention params enter via closure.
    def body(carry, xs):
        h, aux = carry
        if attn_caches is not None:
            g_params, g_states, a_cache = xs
        else:
            g_params, g_states = xs
            a_cache = None
        h, new_g_states = _scan_mamba_span(g_params, h, cfg, g_states)
        h, new_a_cache, a = decoder_layer_apply(
            shared_p, h, positions, cfg, cache=a_cache,
            update_cache=update_cache)
        aux = {k: aux[k] + a[k] for k in AUX_KEYS}
        outs = (new_g_states, new_a_cache) if attn_caches is not None \
            else (new_g_states, None)
        return (h, aux), outs

    body = _maybe_remat(body, cfg)
    xs = (grouped_p, grouped_s, attn_caches) if attn_caches is not None \
        else (grouped_p, grouped_s)
    (x, aux), (new_grouped_s, new_attn_caches) = jax.lax.scan(
        body, (x, _zero_aux()), xs)

    new_states_flat = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups * per_group,) + a.shape[2:]),
        new_grouped_s)
    if rem:
        rem_p = take(lp, n_m - rem, n_m)
        rem_s = take(states, n_m - rem, n_m)
        x, new_rem_s = _scan_mamba_span(rem_p, x, cfg, rem_s)
        new_states_flat = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0),
            new_states_flat, new_rem_s)
    return x, aux, new_states_flat, new_attn_caches


def forward(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray]:
    """Training/eval forward (no cache).

    Returns (hidden_states (B,S,d) AFTER final norm, aux, loss_mask (B,S)).
    Logits are NOT materialized here — the loss computes them chunked
    (vocab-parallel + seq-chunked CE); use `logits()` for small-scale eval.
    """
    fam = cfg.family
    tokens = batch["tokens"]
    B = tokens.shape[0]

    if fam == "encdec":
        enc_in = _frontend_embed(params, batch["src_features"], cfg)
        enc_pos = jnp.arange(enc_in.shape[1])[None, :]

        def enc_body(h, layer_p):
            hn = rmsnorm(h, layer_p["ln1"], cfg.norm_eps)
            a, _ = gqa_self_attention(layer_p["attn"], hn, enc_pos, cfg,
                                      causal=False)   # bidirectional encoder
            h = h + a.astype(h.dtype)
            h2 = rmsnorm(h, layer_p["ln2"], cfg.norm_eps)
            return h + mlp_apply(layer_p["mlp"], h2, cfg).astype(h.dtype), None

        enc_body = _maybe_remat(enc_body, cfg)
        enc_out, _ = jax.lax.scan(enc_body, enc_in, params["enc_layers"])
        enc_out = rmsnorm(enc_out, params["ln_enc"], cfg.norm_eps)

        x = _embed(params, tokens[:, :-1], cfg)
        positions = jnp.arange(x.shape[1])[None, :]

        def dec_body(carry, layer_p):
            h, aux = carry
            enc_kv = cross_attention_kv(layer_p["cross"], enc_out, cfg)
            h, _, a = decoder_layer_apply(layer_p, h, positions, cfg,
                                          enc_kv=enc_kv)
            aux = {k: aux[k] + a[k] for k in AUX_KEYS}
            return (h, aux), None

        dec_body = _maybe_remat(dec_body, cfg)
        (x, aux), _ = jax.lax.scan(dec_body, (x, _zero_aux()), params["layers"])
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        mask = jnp.ones(tokens[:, 1:].shape, jnp.float32)
        return x, aux, mask

    if fam == "vlm":
        img = _frontend_embed(params, batch["patch_embeds"], cfg)
        txt = _embed(params, tokens[:, :-1], cfg)
        x = jnp.concatenate([img, txt], axis=1)
        n_img = img.shape[1]
        positions = jnp.arange(x.shape[1])[None, :]
        x, aux = _scan_decoder(params, x, positions, cfg)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        x = x[:, n_img:]     # predictions only over text positions
        mask = jnp.ones(tokens[:, 1:].shape, jnp.float32)
        return x, aux, mask

    x = _embed(params, tokens[:, :-1], cfg)
    positions = jnp.arange(x.shape[1])[None, :]
    if fam in ("dense", "moe"):
        x, aux = _scan_decoder(params, x, positions, cfg)
    elif fam == "ssm":
        states = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape),
            ssm_mod.init_rwkv_state(cfg, B, x.dtype))
        x, _ = _scan_rwkv(params, x, cfg, states)
        aux = _zero_aux()
    elif fam == "hybrid":
        n_m, _, _, _ = hybrid_layout(cfg)
        states = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n_m,) + a.shape),
            ssm_mod.init_mamba_state(cfg, B, x.dtype))
        x, aux, _, _ = _hybrid_forward(params, x, positions, cfg, states)
    else:
        raise ValueError(fam)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    mask = jnp.ones(tokens[:, 1:].shape, jnp.float32)
    return x, aux, mask


# ---------------------------------------------------------------------------
# loss: vocab-parallel, sequence-chunked cross-entropy (never materializes
# the full (B,S,V) logits tensor; each chunk is rematerialized in backward)
# ---------------------------------------------------------------------------

def _unembed_weight(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"].T           # (d, V)
    return params["unembed"]


def chunked_ce_loss(params, hidden, labels, mask, cfg: ArchConfig):
    """hidden: (B,S,d); labels: (B,S) int32; mask: (B,S)."""
    w = _unembed_weight(params, cfg)
    B, S, d = hidden.shape
    c = min(cfg.loss_chunk, S)
    n = -(-S // c)
    pad = n * c - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hidden = hidden.reshape(B, n, c, d).swapaxes(0, 1)     # (n,B,c,d)
    labels = labels.reshape(B, n, c).swapaxes(0, 1)
    mask = mask.reshape(B, n, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(h, y, m):
        logits = dense(h, w, None, jnp.float32, site="loss.unembed")
        logits = hint(logits, "B", None, "M")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * m), jnp.sum(m)

    def body(carry, xs):
        tot, cnt = carry
        h, y, m = xs
        l, k = chunk_loss(h, y, m)
        return (tot + l, cnt + k), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (hidden, labels, mask))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ArchConfig,
            moe_loss_weight: float = 0.01, z_loss_weight: float = 1e-4):
    hidden, aux, mask = forward(params, batch, cfg)
    labels = batch["tokens"][:, 1:]
    loss = chunked_ce_loss(params, hidden, labels, mask, cfg)
    total = loss
    if cfg.moe is not None:
        total = total + moe_loss_weight * aux["moe_lb_loss"] + \
            z_loss_weight * aux["moe_z_loss"]
    metrics = {"ce_loss": loss, **aux}
    return total, metrics


def logits(params, batch, cfg: ArchConfig):
    """Full logits for small-scale eval/tests only."""
    hidden, _, _ = forward(params, batch, cfg)
    w = _unembed_weight(params, cfg)
    return dense(hidden, w, None, jnp.float32, site="unembed")
