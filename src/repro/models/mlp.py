"""Gated feed-forward (SwiGLU / GeGLU) blocks."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.modules import activation, dense, dense_init
from repro.parallel.hints import hint

Params = Dict[str, Any]


def init_mlp(key, d_model: int, d_ff: int, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.param_dtype
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dt),
        "w_up": dense_init(k2, d_model, d_ff, dt),
        "w_down": dense_init(k3, d_ff, d_model, dt,
                             scale=1.0 / (d_ff ** 0.5 * (2 * cfg.num_layers) ** 0.5)),
    }


def mlp_apply(params: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    cdt = jnp.dtype(cfg.compute_dtype)
    act = activation(cfg.mlp_activation)
    g = act(hint(dense(x, params["w_gate"], None, cdt,
                       site="layer.mlp.gate"), "B", None, "M"))
    u = hint(dense(x, params["w_up"], None, cdt,
                   site="layer.mlp.up"), "B", None, "M")
    return hint(dense(g * u, params["w_down"], None, cdt,
                      site="layer.mlp.down"), "B", None, None)
