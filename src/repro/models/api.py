"""Public model API: build a model object from an ArchConfig."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import serving, transformer
from repro.models.modules import param_count


class Model:
    """Functional model wrapper — all methods are pure and jit-friendly."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ---- params -----------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        return transformer.init_params(key, self.cfg)

    def init_abstract(self) -> Dict[str, Any]:
        """Parameter avals without allocation (for dry-run lowering)."""
        return jax.eval_shape(
            lambda k: transformer.init_params(k, self.cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))

    def num_params(self, params=None) -> int:
        tree = params if params is not None else self.init_abstract()
        return param_count(tree)

    # ---- training ---------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        return transformer.loss_fn(params, batch, self.cfg)

    def logits(self, params, batch) -> jnp.ndarray:
        return transformer.logits(params, batch, self.cfg)

    def forward(self, params, batch):
        return transformer.forward(params, batch, self.cfg)

    # ---- serving ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, src_len: int = 0):
        return serving.init_cache(self.cfg, batch, max_len, src_len)

    def prefill(self, params, batch, cache, length=None):
        return serving.prefill(params, batch, self.cfg, cache, length)

    def decode_step(self, params, tokens, cache):
        return serving.decode_step(params, tokens, self.cfg, cache)

    # ---- paged serving (physical KV arena; serving/kv_pool.py) ------------
    def init_paged_arena(self, num_blocks: int, block_size: int):
        return serving.init_paged_arena(self.cfg, num_blocks, block_size)

    def init_paged_state(self, num_slots: int, src_len: int = 0):
        return serving.init_paged_state(self.cfg, num_slots, src_len)

    def paged_prefill_write(self, arena, layers_cache, block_ids):
        # saralint: ok[cow-gate] pass-through to the bucketed prefill scatter; the engine only hands it freshly alloc'd, never-shared pages
        return serving.paged_prefill_write(arena, layers_cache, block_ids)

    def paged_prefill_step(self, params, tokens, arena, block_tables,
                           kv_lens, chunk_lens):
        return serving.paged_prefill_step(params, tokens, self.cfg, arena,
                                          block_tables, kv_lens, chunk_lens)

    def paged_verify_step(self, params, tokens, arena, block_tables,
                          kv_lens, chunk_lens):
        return serving.paged_verify_step(params, tokens, self.cfg, arena,
                                         block_tables, kv_lens, chunk_lens)

    def paged_decode_step(self, params, tokens, state, arena, block_tables,
                          kv_lens, write_mask):
        return serving.paged_decode_step(params, tokens, self.cfg, state,
                                         arena, block_tables, kv_lens,
                                         write_mask)

    def paged_shared_decode_step(self, params, tokens, state, arena,
                                 block_tables, kv_lens, write_mask,
                                 prefix_pages, prefix_lens, unique_tables,
                                 unique_lens):
        return serving.paged_shared_decode_step(
            params, tokens, self.cfg, state, arena, block_tables, kv_lens,
            write_mask, prefix_pages, prefix_lens, unique_tables,
            unique_lens)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
