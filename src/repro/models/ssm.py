"""SSM blocks: RWKV6 (Finch) time/channel mixing and Mamba2 (SSD).

Both use the same *chunked parallel scan* structure for train/prefill:
sequence is split into chunks; within a chunk the recurrence is evaluated in
closed form (O(Lc^2) masked einsum — this is the part the Pallas
`linear_attn` kernel accelerates on TPU), across chunks a `lax.scan` carries
the recurrent state.  Decode is the exact one-step recurrence on a carried
state, so "KV cache" size is O(1) in sequence length — this is what makes the
long_500k cells runnable for rwkv6-1.6b / zamba2-7b.

Numerical notes:
- decays are handled in log space; intra-chunk decay differences are
  evaluated inside a masked (Lc, Lc) block so no exp() of a positive sum of
  logs ever occurs (stable for arbitrary chunk length).
- RWKV6 follows the Finch formulation o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T),
  S_t = diag(w_t) S_{t-1} + k_t v_t^T with data-dependent w_t produced by a
  low-rank (LoRA) head on the token-shifted input.  We use first-order token
  shift mixing (RWKV5-style mu) + the LoRA decay head; the higher-order DDLerp
  data-dependence on the *mix* coefficients is simplified away (documented in
  DESIGN.md §2.1 — it does not change dataflow shape or cost).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.modules import dense, dense_init
from repro.parallel.hints import hint

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _shift(x: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
    """Token shift: returns the previous token's features.

    x: (B, S, d); prev: (B, d) — feature vector of the token before x[:, 0].
    """
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _chunk(x: jnp.ndarray, lc: int) -> Tuple[jnp.ndarray, int, int]:
    """(B, S, ...) -> (B, n, lc, ...) with zero padding."""
    B, S = x.shape[0], x.shape[1]
    n = -(-S // lc)
    pad = n * lc - S
    if pad:
        x = jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
    return x.reshape((B, n, lc) + x.shape[2:]), n, S


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

class RWKVState(NamedTuple):
    wkv: jnp.ndarray       # (B, H, K, V)
    shift_t: jnp.ndarray   # (B, d) time-mix shift
    shift_c: jnp.ndarray   # (B, d) channel-mix shift


def rwkv_num_heads(cfg: ArchConfig) -> int:
    return cfg.d_model // cfg.ssm.head_dim


def init_rwkv_block(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    dt = cfg.param_dtype
    H = rwkv_num_heads(cfg)
    K = cfg.ssm.head_dim
    lora = max(32, d // 32)
    ks = jax.random.split(key, 12)
    return {
        "mu_r": jnp.full((d,), 0.5, dt), "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt), "mu_w": jnp.full((d,), 0.5, dt),
        "mu_g": jnp.full((d,), 0.5, dt),
        "w_r": dense_init(ks[0], d, d, dt),
        "w_k": dense_init(ks[1], d, d, dt),
        "w_v": dense_init(ks[2], d, d, dt),
        "w_g": dense_init(ks[3], d, d, dt),
        "w_o": dense_init(ks[4], d, d, dt,
                          scale=1.0 / (d ** 0.5 * (2 * cfg.num_layers) ** 0.5)),
        # data-dependent decay LoRA head: d -> lora -> d
        "w_decay_a": dense_init(ks[5], d, lora, dt),
        "w_decay_b": dense_init(ks[6], lora, d, dt, scale=0.01),
        "decay_base": jnp.full((d,), -6.0, dt),   # w = exp(-exp(.)) ~ 0.9975
        "bonus_u": jnp.zeros((H, K), dt),
        "ln_scale": jnp.ones((H, K), dt),         # per-head groupnorm
        "ln_bias": jnp.zeros((H, K), dt),
        # channel mix
        "mu_ck": jnp.full((d,), 0.5, dt), "mu_cr": jnp.full((d,), 0.5, dt),
        "w_ck": dense_init(ks[7], d, cfg.d_ff, dt),
        "w_cv": dense_init(ks[8], cfg.d_ff, d, dt,
                           scale=1.0 / (cfg.d_ff ** 0.5 * (2 * cfg.num_layers) ** 0.5)),
        "w_cr": dense_init(ks[9], d, d, dt),
    }


def _wkv_chunked(r, k, v, logw, u, state0, lc: int):
    """Chunked RWKV6 linear attention.

    r,k: (B,S,H,K); v: (B,S,H,V); logw: (B,S,H,K) (negative log decays);
    u: (H,K); state0: (B,H,K,V).  Returns (out (B,S,H,V), state (B,H,K,V)).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    lc = min(lc, S)
    rc, n, S0 = _chunk(r, lc)
    kc, _, _ = _chunk(k, lc)
    vc, _, _ = _chunk(v, lc)
    wc, _, _ = _chunk(logw, lc)

    # mask padded positions: decay 1 (log 0), k=0 so they do not contribute
    if n * lc != S0:
        valid = (jnp.arange(n * lc) < S0).reshape(1, n, lc, 1, 1)
        kc = kc * valid
        wc = wc * valid

    cs = jnp.cumsum(wc, axis=2)                      # (B,n,lc,H,K) inclusive
    cs_prev = cs - wc                                 # exclusive cumsum

    def step(h, inputs):
        rcb, kcb, vcb, csb, csb_prev, wsum = inputs   # (B,lc,H,K) etc
        # inter-chunk: o_t += (r_t * exp(cs_prev_t)) @ h
        r_dec = rcb * jnp.exp(csb_prev)
        # saralint: ok[dispatch-escape] WKV recurrence readout against the running state, all activations
        o_inter = jnp.einsum("blhk,bhkv->blhv", r_dec, h)
        # intra-chunk: A[t,j] = sum_k r[t,k] k[j,k] exp(cs_prev[t,k]-cs[j,k]), j<t
        diff = csb_prev[:, :, None] - csb[:, None, :, :, :]   # (B,t,j,H,K)
        tri = jnp.tril(jnp.ones((lc, lc), bool), k=-1)
        diff = jnp.where(tri[None, :, :, None, None], diff, -1e30)
        # saralint: ok[dispatch-escape] intra-chunk decay-weighted receptance x key, all activations
        A = jnp.einsum("bthk,bjhk,btjhk->bthj",
                       rcb, kcb, jnp.exp(diff))
        # saralint: ok[dispatch-escape] intra-chunk mix against values, all activations
        o_intra = jnp.einsum("bthj,bjhv->bthv", A, vcb)
        # bonus diagonal: o_t += (r_t * u * k_t) . v_t
        # saralint: ok[dispatch-escape] elementwise diagonal bonus reduction, not a GEMM site
        diag = jnp.einsum("blhk,blhk->blh", rcb * u[None, None], kcb)
        o_diag = diag[..., None] * vcb
        # state update: h' = exp(wsum) h + sum_j exp(wsum - cs_j) k_j v_j^T
        kdec = kcb * jnp.exp(wsum[:, None] - csb)
        # saralint: ok[dispatch-escape] WKV state update (key x value outer product), all activations
        kv_outer = jnp.einsum("blhk,blhv->bhkv", kdec, vcb)
        h_new = jnp.exp(wsum)[:, :, :, None] * h + kv_outer
        return h_new, o_inter + o_intra + o_diag

    wsum = cs[:, :, -1]                               # (B,n,H,K)
    inputs = (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
              jnp.moveaxis(vc, 1, 0), jnp.moveaxis(cs, 1, 0),
              jnp.moveaxis(cs_prev, 1, 0), jnp.moveaxis(wsum, 1, 0))
    # remat the chunk body: the (B,lc,lc,H,K) decay tensor is recomputed in
    # backward instead of being saved for every chunk.
    state, out = jax.lax.scan(jax.checkpoint(step), state0, inputs)
    out = jnp.moveaxis(out, 0, 1).reshape(B, n * lc, H, V)[:, :S0]
    return out, state


def _wkv_pallas_sharded(r, k, v, logw, u, state0, cfg: ArchConfig):
    """Route the WKV scan through the Pallas kernel, per-shard.

    Heads shard over `model` when divisible (rwkv6-1.6b: 32 heads / 16 = 2
    per device); batch over the data axes.  The kernel's VMEM-resident
    (lc, lc) decay block is the §Perf lever for the rwkv prefill cells.
    """
    import jax as _jax
    from jax.sharding import PartitionSpec as P

    from repro.kernels import ops as kops
    from repro.parallel.hints import current_layout, current_mesh

    S = r.shape[1]
    chunk = min(cfg.ssm.chunk, S)
    kw = dict(chunk=chunk, interpret=True)
    mesh = current_mesh()
    if mesh is None:
        return kops.wkv_attention(r, k, v, logw, u, state0, **kw)

    def asize(names):
        n = 1
        for a in names:
            n *= mesh.devices.shape[mesh.axis_names.index(a)]
        return n

    B, _, H, _ = r.shape
    b_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if current_layout().startswith("dp_all"):
        b_axes = b_axes + ("model",)
    b_ax = b_axes if B % asize(b_axes) == 0 else None
    m_sz = asize(("model",)) if ("model" in mesh.axis_names
                                 and current_layout() == "tp") else 0
    h_ax = "model" if (m_sz and H % m_sz == 0) else None
    seq = P(b_ax, None, h_ax, None)
    f = _jax.shard_map(
        lambda r_, k_, v_, w_, u_, s_: kops.wkv_attention(r_, k_, v_, w_,
                                                          u_, s_, **kw),
        mesh=mesh, in_specs=(seq, seq, seq, seq, P(h_ax, None),
                             P(b_ax, h_ax, None, None)),
        out_specs=(seq, P(b_ax, h_ax, None, None)), check_vma=False)
    return f(r, k, v, logw, u, state0)


def rwkv_block_apply(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                     state: RWKVState) -> Tuple[jnp.ndarray, RWKVState]:
    """Full RWKV6 block (time mix + channel mix), pre-norm residuals handled
    by the caller.  x: (B,S,d) normalized input for time-mix."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    H = rwkv_num_heads(cfg)
    K = cfg.ssm.head_dim
    x = x.astype(cdt)

    xx = _shift(x, state.shift_t.astype(cdt))

    def mix(mu):
        return x + (xx - x) * mu.astype(cdt)

    xr, xk, xv, xw, xg = (mix(params[m]) for m in
                          ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"))
    r = hint(dense(xr, params["w_r"], None, cdt, site="ssm.r").reshape(B, S, H, K),
             "B", None, "M", None)
    k = hint(dense(xk, params["w_k"], None, cdt, site="ssm.k").reshape(B, S, H, K),
             "B", None, "M", None)
    v = hint(dense(xv, params["w_v"], None, cdt, site="ssm.v").reshape(B, S, H, K),
             "B", None, "M", None)
    g = jax.nn.silu(dense(xg, params["w_g"], None, cdt, site="ssm.g"))

    # data-dependent decay (log space, always <= -exp(-10) < 0)
    lora = jnp.tanh(dense(xw, params["w_decay_a"], None, cdt, site="ssm.decay_a"))
    dec = dense(lora, params["w_decay_b"], None, cdt, site="ssm.decay_b") + \
        params["decay_base"].astype(cdt)
    logw = -jnp.exp(jnp.clip(dec, -12.0, 1.0)).astype(jnp.float32)  # (B,S,d)
    logw = logw.reshape(B, S, H, K)

    wkv_args = (r.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), logw,
                params["bonus_u"].astype(jnp.float32),
                hint(state.wkv.astype(jnp.float32), "B", "M", None, None))
    if cfg.ssm_impl == "pallas":
        out, wkv_state = _wkv_pallas_sharded(*wkv_args, cfg)
    else:
        out, wkv_state = _wkv_chunked(*wkv_args, cfg.ssm.chunk)
    out = hint(out, "B", None, "M", None)

    # per-head groupnorm
    mu = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 64e-5)
    out = out * params["ln_scale"].astype(jnp.float32) + \
        params["ln_bias"].astype(jnp.float32)
    out = (out.reshape(B, S, d).astype(cdt)) * g
    y_time = dense(out, params["w_o"], None, cdt, site="ssm.out")

    # ---- channel mix ------------------------------------------------------
    xc = x + y_time           # pre-norm simplification: mix on residual stream
    xxc = _shift(xc, state.shift_c.astype(cdt))
    xck = xc + (xxc - xc) * params["mu_ck"].astype(cdt)
    xcr = xc + (xxc - xc) * params["mu_cr"].astype(cdt)
    kk = jnp.square(jax.nn.relu(dense(xck, params["w_ck"], None, cdt, site="ssm.channel_k")))
    vv = dense(kk, params["w_cv"], None, cdt, site="ssm.channel_v")
    rr = jax.nn.sigmoid(dense(xcr, params["w_cr"], None, cdt, site="ssm.channel_r"))
    y = y_time + rr * vv

    new_state = RWKVState(
        wkv=wkv_state.astype(state.wkv.dtype),
        shift_t=x[:, -1, :].astype(state.shift_t.dtype),
        shift_c=xc[:, -1, :].astype(state.shift_c.dtype))
    return y.astype(x.dtype), new_state


def init_rwkv_state(cfg: ArchConfig, batch: int, dtype) -> RWKVState:
    H = rwkv_num_heads(cfg)
    K = cfg.ssm.head_dim
    return RWKVState(
        wkv=jnp.zeros((batch, H, K, K), jnp.float32),
        shift_t=jnp.zeros((batch, cfg.d_model), dtype),
        shift_c=jnp.zeros((batch, cfg.d_model), dtype))


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

class MambaState(NamedTuple):
    ssm: jnp.ndarray        # (B, H, P, N)
    conv: jnp.ndarray       # (B, W-1, conv_channels)


def mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = s.num_heads or d_inner // s.head_dim
    return d_inner, H, s.head_dim, s.state_dim


def init_mamba_block(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    dt = cfg.param_dtype
    d_inner, H, P, N = mamba_dims(cfg)
    conv_ch = d_inner + 2 * N       # x ++ B ++ C  (n_groups = 1)
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], d, 2 * d_inner + 2 * N + H, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm.conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt),
        "dt_bias": jnp.full((H,), -2.0, dt),
        "D": jnp.ones((H,), dt),
        "norm_scale": jnp.zeros((d_inner,), dt),
        "w_out": dense_init(ks[3], d_inner, d, dt,
                            scale=1.0 / (d_inner ** 0.5 * (2 * cfg.num_layers) ** 0.5)),
    }


def _ssd_chunked(xh, Bm, Cm, loga, state0, lc: int):
    """Chunked SSD scan.

    xh: (B,S,H,P) — dt-scaled inputs;  Bm, Cm: (B,S,N);  loga: (B,S,H) (<=0);
    state0: (B,H,P,N).  Returns (y (B,S,H,P), state).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    lc = min(lc, S)
    xc, n, S0 = _chunk(xh, lc)
    bc, _, _ = _chunk(Bm, lc)
    cc, _, _ = _chunk(Cm, lc)
    ac, _, _ = _chunk(loga, lc)
    if n * lc != S0:
        valid = (jnp.arange(n * lc) < S0).reshape(1, n, lc)
        xc = xc * valid[..., None, None]
        ac = ac * valid[..., None]

    cs = jnp.cumsum(ac, axis=2)                       # (B,n,lc,H) inclusive
    cs_prev = cs - ac

    def step(h, inputs):
        xb, bb, cb, csb, csb_prev, asum = inputs
        # inter: y_t += exp(cs_prev_t) * C_t . h     -- careful: state h already
        # includes decay up to chunk start; token t sees h decayed by cs_prev_t
        # PLUS its own a_t?  Recurrence h_t = exp(a_t) h_{t-1} + x_t B_t^T means
        # y_t = C_t . h_t, so h_0 is decayed by cs_t (inclusive).
        # saralint: ok[dispatch-escape] SSD recurrence readout against the running state, all activations
        y_inter = jnp.einsum("bln,bhpn,blh->blhp", cb, h, jnp.exp(csb))
        # intra: y_t += sum_{j<=t} exp(cs_t - cs_j) (C_t.B_j) x_j
        diff = csb[:, :, None] - csb[:, None, :, :]   # (B,t,j,H)
        tri = jnp.tril(jnp.ones((lc, lc), bool))
        diff = jnp.where(tri[None, :, :, None], diff, -1e30)
        # saralint: ok[dispatch-escape] intra-chunk C.B interaction, all activations
        G = jnp.einsum("btn,bjn->btj", cb, bb)        # (B,t,j)
        M = G[:, :, :, None] * jnp.exp(diff)          # (B,t,j,H)
        # saralint: ok[dispatch-escape] intra-chunk mix against inputs, all activations
        y_intra = jnp.einsum("btjh,bjhp->bthp", M, xb)
        # state: h' = exp(asum) h + sum_j exp(asum - cs_j) x_j B_j^T
        dec = jnp.exp(asum[:, None] - csb)            # (B,lc,H)
        # saralint: ok[dispatch-escape] SSD state update (input x B outer product), all activations
        xb_outer = jnp.einsum("blhp,bln,blh->bhpn", xb, bb, dec)
        h_new = jnp.exp(asum)[:, :, None, None] * h + xb_outer
        return h_new, y_inter + y_intra

    asum = cs[:, :, -1]
    inputs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(bc, 1, 0),
              jnp.moveaxis(cc, 1, 0), jnp.moveaxis(cs, 1, 0),
              jnp.moveaxis(cs_prev, 1, 0), jnp.moveaxis(asum, 1, 0))
    state, y = jax.lax.scan(jax.checkpoint(step), state0, inputs)
    y = jnp.moveaxis(y, 0, 1).reshape(B, n * lc, H, P)[:, :S0]
    return y, state


def mamba_block_apply(params: Params, x: jnp.ndarray, cfg: ArchConfig,
                      state: MambaState) -> Tuple[jnp.ndarray, MambaState]:
    """x: (B,S,d) normalized input.  Returns (y, new_state)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    d_inner, H, P, N = mamba_dims(cfg)
    W = cfg.ssm.conv_width
    x = x.astype(cdt)

    zxbcdt = hint(dense(x, params["w_in"], None, cdt, site="ssm.in_proj"), "B", None, None)
    z, xBC, dt_raw = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)

    # causal depthwise conv over (x ++ B ++ C)
    conv_in = jnp.concatenate([state.conv.astype(cdt), xBC], axis=1)
    new_conv = conv_in[:, -(W - 1):, :] if W > 1 else state.conv
    wts = params["conv_w"].astype(cdt)
    xBC = sum(conv_in[:, i:i + S, :] * wts[i][None, None, :] for i in range(W))
    xBC = jax.nn.silu(xBC + params["conv_b"].astype(cdt))

    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    dt_h = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                           params["dt_bias"].astype(jnp.float32))   # (B,S,H)
    loga = -jnp.exp(params["A_log"].astype(jnp.float32))[None, None, :] * dt_h
    xh = xs.astype(jnp.float32) * dt_h[..., None]

    xh = hint(xh, "B", None, "M", None)
    y, new_ssm = _ssd_chunked(xh, Bm.astype(jnp.float32),
                              Cm.astype(jnp.float32), loga,
                              hint(state.ssm.astype(jnp.float32),
                                   "B", "M", None, None), cfg.ssm.chunk)
    y = hint(y, "B", None, "M", None)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * \
        xs.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(cdt)

    # normalized gating (mamba2): rmsnorm(y) * silu(z)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + cfg.norm_eps) *
         (1.0 + params["norm_scale"].astype(jnp.float32))).astype(cdt)
    y = y * jax.nn.silu(z)
    out = dense(y, params["w_out"], None, cdt, site="ssm.out_proj")

    new_state = MambaState(ssm=new_ssm.astype(state.ssm.dtype),
                           conv=new_conv.astype(state.conv.dtype))
    return out.astype(x.dtype), new_state


def init_mamba_state(cfg: ArchConfig, batch: int, dtype) -> MambaState:
    d_inner, H, P, N = mamba_dims(cfg)
    conv_ch = d_inner + 2 * N
    return MambaState(
        ssm=jnp.zeros((batch, H, P, N), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_ch), dtype))
