"""Functional parameter/module primitives.

Params are plain nested dicts of jnp arrays; every module is an ``init``
function (rng, shapes -> pytree) plus a pure ``apply`` function.  No framework
dependency (flax is not available offline) — this keeps pjit/shard_map
integration and checkpointing trivial: a checkpoint IS the pytree.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype="float32", *, scale: Optional[float] = None):
    """Truncated-normal (fan-in) init, matching common LM practice."""
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32) * std
    return w.astype(_dtype(dtype))


def embed_init(key, vocab: int, d: int, dtype="float32"):
    w = jax.random.normal(key, (vocab, d), jnp.float32) * (1.0 / math.sqrt(d))
    return w.astype(_dtype(dtype))


def zeros_init(shape, dtype="float32"):
    return jnp.zeros(shape, _dtype(dtype))


def ones_init(shape, dtype="float32"):
    return jnp.ones(shape, _dtype(dtype))


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def dense(x: jnp.ndarray, w: jnp.ndarray, b: Optional[jnp.ndarray] = None,
          compute_dtype=jnp.float32, *, site: str = "dense") -> jnp.ndarray:
    """Thin wrapper over the SARA dispatch layer: every dense GEMM site
    resolves its (M, K, N) -> tile config through the active dispatcher and
    executes via the RSA Pallas kernel or XLA (repro/dispatch).  ``site`` is
    the stable site name recorded in the per-trace site registry."""
    from repro import dispatch
    y = dispatch.gemm(x.astype(compute_dtype), w.astype(compute_dtype),
                      site=site)
    if b is not None:
        y = y + b.astype(compute_dtype)
    return y


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu
    raise ValueError(f"unknown activation {name}")


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0.0:
        return logits
    return jnp.tanh(logits / cap) * cap


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, heads, head_dim); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------

def param_count(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def split_keys(key, n: int) -> Sequence[jax.Array]:
    return jax.random.split(key, n)


def stack_layer_params(layer_params: Sequence[Params]) -> Params:
    """Stack per-layer pytrees along a leading axis for lax.scan."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)
