"""Mixture-of-Experts feed-forward with expert parallelism.

Design (GShard/Switch-style, adapted for pjit-global semantics):

- Routing is computed per *group* (= one batch row), so the top-k sort stays
  local to the data shard that owns the row — no global sort collective.
- Dispatch is sort-based (argsort of expert ids), not one-hot-einsum based:
  memory is O(S·k) per row instead of O(S·E·C).
- Expert buffers have shape (B, E, C, d): B sharded over `data`, E over
  `model` (expert parallelism).  XLA lowers the (B-sharded -> B,E-sharded)
  scatter into the all-to-all this dataflow implies.
- Routed experts are padded up to a multiple of the EP axis so every device
  owns the same number of experts; the router assigns padding experts -inf.
- Capacity per row C = ceil(S·k/E_real · capacity_factor); overflow tokens are
  dropped (their contribution is 0, residual carries them — standard).
- Shared experts (qwen2-moe, deepseek-v3) are an always-on dense GLU applied
  to every token and summed with the routed output.

Aux losses: load-balance (Switch) + router-z, returned for logging.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import dispatch
from repro.configs.base import ArchConfig
from repro.models.modules import activation, dense, dense_init
from repro.parallel.hints import hint

Params = Dict[str, Any]


def padded_num_experts(cfg: ArchConfig, ep_axis: int = 16) -> int:
    e = cfg.moe.num_experts
    return int(math.ceil(e / ep_axis) * ep_axis)


def row_capacity(seq: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    cap = int(math.ceil(seq * m.experts_per_token / m.num_experts
                        * m.capacity_factor))
    return max(cap, 4)


def init_moe(key, cfg: ArchConfig, ep_axis: int = 16) -> Params:
    m = cfg.moe
    dt = cfg.param_dtype
    E = padded_num_experts(cfg, ep_axis)
    d, f = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 5)

    def expert_bank(k, d_in, d_out, scale=None):
        keys = jax.random.split(k, E)
        w = jnp.stack([dense_init(kk, d_in, d_out, dt, scale=scale) for kk in keys])
        return w                                           # (E, d_in, d_out)

    p = {
        "router": dense_init(ks[0], d, E, "float32", scale=0.02),
        "w_gate": expert_bank(ks[1], d, f),
        "w_up": expert_bank(ks[2], d, f),
        "w_down": expert_bank(ks[3], f, d,
                              scale=1.0 / (f ** 0.5 * (2 * cfg.num_layers) ** 0.5)),
    }
    if m.num_shared_experts > 0:
        fs = f * m.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, d, fs, dt),
            "w_up": dense_init(k2, d, fs, dt),
            "w_down": dense_init(k3, fs, d,
                                 scale=1.0 / (fs ** 0.5 * (2 * cfg.num_layers) ** 0.5)),
        }
    return p


def moe_apply(params: Params, x: jnp.ndarray, cfg: ArchConfig
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, d) -> (B, S, d), aux metrics."""
    m = cfg.moe
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    E_pad = params["router"].shape[-1]
    E = m.num_experts
    k = m.experts_per_token
    C = row_capacity(S, cfg)

    # ---- routing (fp32 for stability; pinned to XLA so the top-k routing
    # decision is bit-stable across execution backends) --------------------
    logits = dispatch.gemm(x.astype(jnp.float32),
                           params["router"].astype(jnp.float32),
                           site="moe.router", backend="xla")
    logits = jnp.where(jnp.arange(E_pad)[None, None, :] < E, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_idx = jax.lax.top_k(probs, k)            # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)   # renormalize top-k

    # ---- sort-based dispatch, vmapped over rows ---------------------------
    def dispatch_row(xr, idxr, gater):
        # xr: (S,d); idxr: (S,k); gater: (S,k)
        flat_e = idxr.reshape(-1)                            # (S*k,)
        flat_g = gater.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(S), k)
        order = jnp.argsort(flat_e, stable=True)
        se, sg, st = flat_e[order], flat_g[order], flat_tok[order]
        # position within each expert's run
        pos = jnp.arange(S * k) - jnp.searchsorted(se, se, side="left")
        keep = pos < C
        dest = jnp.where(keep, se * C + pos, E_pad * C)      # overflow -> dropped
        buf = jnp.zeros((E_pad * C + 1, d), cdt)
        buf = buf.at[dest].add(xr[st].astype(cdt) * keep[:, None].astype(cdt))
        return buf[:-1].reshape(E_pad, C, d), dest, st, sg, keep

    buf, dest, st, sg, keep = jax.vmap(dispatch_row)(x, top_idx, gate_vals)
    buf = hint(buf, "B", "E", None, None)     # EP: experts over `model`
    # buf: (B, E_pad, C, d)

    # ---- expert computation (EP: E sharded over `model`); the expert-bank
    # GEMMs go through the dispatch layer (one RSA GEMM per expert) ---------
    act = activation(cfg.mlp_activation)
    g = dispatch.gemm(buf, params["w_gate"].astype(cdt),
                      site="moe.expert.gate")
    u = dispatch.gemm(buf, params["w_up"].astype(cdt), site="moe.expert.up")
    h = act(g) * u
    out_buf = hint(dispatch.gemm(h, params["w_down"].astype(cdt),
                                 site="moe.expert.down"),
                   "B", "E", None, None)

    # ---- combine back ------------------------------------------------------
    def combine_row(out_b, dest_r, st_r, sg_r, keep_r):
        flat = out_b.reshape(E_pad * C, d)
        gathered = flat[jnp.minimum(dest_r, E_pad * C - 1)]
        contrib = gathered * (sg_r * keep_r)[:, None].astype(cdt)
        y = jnp.zeros((S, d), cdt).at[st_r].add(contrib)
        return y

    y = hint(jax.vmap(combine_row)(out_buf, dest, st, sg, keep),
             "B", None, None)

    # ---- shared experts ----------------------------------------------------
    if "shared" in params:
        sp = params["shared"]
        gs = act(dense(x, sp["w_gate"], None, cdt, site="moe.shared.gate"))
        us = dense(x, sp["w_up"], None, cdt, site="moe.shared.up")
        y = y + dense(gs * us, sp["w_down"], None, cdt,
                      site="moe.shared.down")

    # ---- aux losses --------------------------------------------------------
    # load-balance: E * sum_e f_e * p_e   (Switch), over real experts
    me = jnp.mean(probs[..., :E].reshape(-1, E), axis=0)
    one_hot_top1 = jax.nn.one_hot(top_idx[..., 0], E_pad)[..., :E]
    fe = jnp.mean(one_hot_top1.reshape(-1, E), axis=0)
    lb_loss = E * jnp.sum(me * fe)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_drop_frac": drop_frac}
    return y.astype(x.dtype), aux
