"""Attention: GQA/MQA, MLA (DeepSeek), RoPE, KV caches, blockwise (flash) attn.

All attention paths use a memory-bounded blockwise ("flash-style") computation
(nested scan over query/kv chunks with running max/sum accumulators) so that
32K-token prefill never materializes an S×S score matrix.  Decode paths attend
over a fixed-capacity KV cache with a length mask.

MLA implements the real DeepSeek-V3 structure: low-rank q projection, compressed
KV latent + decoupled shared RoPE key; decode uses the *absorbed* formulation and
caches only (c_kv, k_rope) — this is what makes deepseek-v3-671b's decode_32k
cell fit (≈70 KB/token instead of ≈8 MB/token).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.modules import apply_rope, dense, dense_init, rmsnorm, softcap
from repro.parallel.hints import hint

Params = Dict[str, Any]
NEG_INF = -2.0e38


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, S_max, KVH, hd)   [GQA]  or c_kv (B,S_max,r) [MLA]
    v: jnp.ndarray          # (B, S_max, KVH, hd)   [GQA]  or k_rope (B,S_max,rd) [MLA]
    length: jnp.ndarray     # () int32 — tokens currently valid


# ---------------------------------------------------------------------------
# blockwise attention core
# ---------------------------------------------------------------------------

def _chunked_attn(q, k, v, *, causal: bool, q_offset, kv_len, chunk: int,
                  logit_cap: float = 0.0, causal_skip: bool = False):
    """Blockwise attention.

    q: (B, Sq, KVH, G, hd) grouped queries
    k, v: (B, Skv, KVH, hd)
    q_offset: scalar — absolute position of q[0] (for causal masking)
    kv_len: scalar — number of valid kv positions (rest masked)
    causal_skip: iterate only lower-triangular (q,kv) chunk pairs — valid
      when q_offset is statically 0 (training/prefill-from-scratch); halves
      the attention work vs. the masked full grid (§Perf).
    Returns (B, Sq, KVH, G, hd).
    """
    B, Sq, KVH, G, hd = q.shape
    Skv = k.shape[1]
    hd_v = v.shape[-1]          # may differ from hd (MLA: v_dim != qk_dim)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    qc = min(chunk, Sq)
    kc = min(chunk, Skv)
    n_q = -(-Sq // qc)
    n_k = -(-Skv // kc)
    # pad to multiples
    q = jnp.pad(q, ((0, 0), (0, n_q * qc - Sq), (0, 0), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, n_k * kc - Skv), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, n_k * kc - Skv), (0, 0), (0, 0)))

    # keep streams in their compute dtype (bf16 on TPU); fp32 lives only in
    # the per-block softmax + accumulators (flash-attention numerics).
    q = q.reshape(B, n_q, qc, KVH, G, hd)
    k = k.reshape(B, n_k, kc, KVH, hd)
    v = v.reshape(B, n_k, kc, KVH, hd_v)

    q_pos = q_offset + jnp.arange(n_q * qc).reshape(n_q, qc)
    k_pos = jnp.arange(n_k * kc).reshape(n_k, kc)
    kv_valid = k_pos < kv_len                               # (n_k, kc)

    if causal and causal_skip and isinstance(q_offset, int) and q_offset == 0:
        return _chunked_attn_tri(q, k, v, q_pos, k_pos, kv_valid, scale,
                                 logit_cap, B, n_q, qc, n_k, kc, KVH, G,
                                 hd_v)[:, :Sq]

    def q_step(_, qi):
        q_blk = q[:, qi]                                    # (B,qc,KVH,G,hd)
        qp = q_pos[qi]                                      # (qc,)

        def kv_step(carry, kj):
            m, l, acc = carry
            k_blk = k[:, kj]                                # (B,kc,KVH,hd)
            v_blk = v[:, kj]
            # saralint: ok[dispatch-escape] activation-activation attention score; no weight shape for ADAPTNET to tile
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if logit_cap > 0.0:
                s = softcap(s, logit_cap)
            mask = kv_valid[kj][None, :]                    # (1,kc)
            if causal:
                mask = mask & (k_pos[kj][None, :] <= qp[:, None])
            s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            # saralint: ok[dispatch-escape] softmax-weights x values mix, both activations
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, qc, hd_v), jnp.float32)
        # checkpoint the kv step: the (qc, kc) softmax block is recomputed in
        # backward instead of saved per (q-chunk, kv-chunk) pair — this is
        # what keeps 32K-token training inside HBM (flash-attention-style
        # memory, paid for with one extra forward).
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      jnp.arange(n_k))
        out = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,KVH,G,qc,hd)
        return None, jnp.transpose(out, (0, 3, 1, 2, 4))    # (B,qc,KVH,G,hd)

    _, blocks = jax.lax.scan(jax.checkpoint(q_step), None,
                             jnp.arange(n_q))  # (n_q,B,qc,KVH,G,hd_v)
    out = jnp.transpose(blocks, (1, 0, 2, 3, 4, 5)).reshape(
        B, n_q * qc, KVH, G, hd_v)
    return out[:, :Sq]


def _chunked_attn_tri(q, k, v, q_pos, k_pos, kv_valid, scale, logit_cap,
                      B, n_q, qc, n_k, kc, KVH, G, hd_v):
    """Causal flash attention over the lower-triangular chunk pairs only.

    One scan over the n_q*(n_q+1)/2 (i, j<=i) pairs ordered by i then j;
    the (m, l, acc) accumulator resets at each pair with j==0 and the
    normalized output is emitted on the diagonal (j == i).  Off-diagonal
    pairs need no causal mask at all (every key precedes every query).
    """
    pairs = [(i, j) for i in range(n_q) for j in range(i + 1)]
    I = jnp.array([p[0] for p in pairs])
    J = jnp.array([p[1] for p in pairs])
    is_first = jnp.array([p[1] == 0 for p in pairs])
    last_pos = [i * (i + 1) // 2 + i for i in range(n_q)]

    def pair_step(carry, pij):
        m, l, acc = carry
        qi, kj, first = pij
        m = jnp.where(first, NEG_INF, m)
        l = jnp.where(first, 0.0, l)
        acc = jnp.where(first, 0.0, acc)
        q_blk = q[:, qi]
        k_blk = k[:, kj]
        v_blk = v[:, kj]
        # saralint: ok[dispatch-escape] activation-activation attention score; no weight shape for ADAPTNET to tile
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        if logit_cap > 0.0:
            s = softcap(s, logit_cap)
        diag = qi == kj
        mask = kv_valid[kj][None, :] & \
            jnp.where(diag, k_pos[kj][None, :] <= q_pos[qi][:, None], True)
        s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        # saralint: ok[dispatch-escape] softmax-weights x values mix, both activations
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32)
        out = acc_new / jnp.maximum(l_new, 1e-30)[..., None]
        return (m_new, l_new, acc_new), out

    m0 = jnp.full((B, KVH, G, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, qc), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, qc, hd_v), jnp.float32)
    _, outs = jax.lax.scan(jax.checkpoint(pair_step), (m0, l0, a0),
                           (I, J, is_first))
    blocks = outs[jnp.array(last_pos)]          # (n_q, B, KVH, G, qc, hd_v)
    out = jnp.transpose(blocks, (1, 0, 4, 2, 3, 5)).reshape(
        B, n_q * qc, KVH, G, hd_v)
    return out


def _flash_pallas_sharded(q, k, v, *, causal: bool, chunk: int):
    """Route to the Pallas flash kernel, per-shard under shard_map.

    Without shard_map, GSPMD would partition the kernel's emulated grid
    loop poorly (all-gathering the sliced operands); with it, each device
    runs the kernel on its local (batch x head) slab.  Batch shards over
    the data axes; heads shard over `model` when both H and KVH divide it
    (falls back to replicated heads — same as the XLA path's behaviour).
    """
    from repro.kernels import ops as kops
    from repro.parallel.hints import current_layout, current_mesh

    kw = dict(causal=causal, block_q=min(chunk, 512), block_k=min(chunk, 512))
    mesh = current_mesh()
    if mesh is None:
        return kops.flash_attention(q, k, v, **kw)

    from jax.sharding import PartitionSpec as P

    def asize(names):
        n = 1
        for a in names:
            n *= mesh.devices.shape[mesh.axis_names.index(a)]
        return n

    B, _, H, _ = q.shape
    KVH = k.shape[2]
    b_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp_all = current_layout().startswith("dp_all")
    if dp_all:
        b_axes = b_axes + ("model",)
    b_ax = b_axes if B % asize(b_axes) == 0 else None
    m_sz = asize(("model",)) if ("model" in mesh.axis_names
                                 and not dp_all) else 0
    h_ax = "model" if (m_sz and H % m_sz == 0 and KVH % m_sz == 0) else None
    qs = P(b_ax, None, h_ax, None)
    ks = P(b_ax, None, h_ax, None)
    f = jax.shard_map(lambda a, b, c: kops.flash_attention(a, b, c, **kw),
                      mesh=mesh, in_specs=(qs, ks, ks), out_specs=qs,
                      check_vma=False)
    return f(q, k, v)


def multihead_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None,
                        chunk: int = 1024, logit_cap: float = 0.0,
                        causal_skip: bool = False, impl: str = "xla"):
    """q: (B,Sq,H,hd); k: (B,Skv,KVH,hd); v: (B,Skv,KVH,hd_v).
    H must be a multiple of KVH; hd_v may differ from hd (MLA).

    impl="pallas" uses the flash-attention Pallas kernel when the call is
    compatible (full-sequence self/cross attention from position 0, no
    logit softcap); decode and softcapped paths fall back to the XLA
    blockwise scan."""
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    Skv = k.shape[1]
    if kv_len is None:
        kv_len = Skv
    if (impl == "pallas" and logit_cap == 0.0
            and isinstance(q_offset, int) and q_offset == 0
            and isinstance(kv_len, int) and kv_len == Skv and Sq > 1):
        return _flash_pallas_sharded(q, k, v, causal=causal, chunk=chunk)
    qg = q.reshape(B, Sq, KVH, G, hd)
    out = _chunked_attn(qg, k, v, causal=causal, q_offset=q_offset,
                        kv_len=kv_len, chunk=chunk, logit_cap=logit_cap,
                        causal_skip=causal_skip)
    return out.reshape(B, Sq, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ArchConfig, *, d_in: Optional[int] = None,
             cross: bool = False) -> Params:
    d = d_in if d_in is not None else cfg.d_model
    dt = cfg.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.q_dim, dt),
        "wk": dense_init(k2, d, cfg.kv_dim, dt),
        "wv": dense_init(k3, d, cfg.kv_dim, dt),
        "wo": dense_init(k4, cfg.q_dim, cfg.d_model, dt,
                         scale=1.0 / (cfg.q_dim ** 0.5 * (2 * cfg.num_layers) ** 0.5)),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    return p


def _proj_qkv(params, x, kv_x, cfg: ArchConfig, compute_dtype,
              site: str = "layer.attn"):
    B = x.shape[0]
    q = dense(x, params["wq"], params.get("bq"), compute_dtype,
              site=f"{site}.q")
    k = dense(kv_x, params["wk"], params.get("bk"), compute_dtype,
              site=f"{site}.k")
    v = dense(kv_x, params["wv"], params.get("bv"), compute_dtype,
              site=f"{site}.v")
    q = hint(q.reshape(B, x.shape[1], cfg.num_heads, cfg.head_dim),
             "B", None, "M", None)
    k = hint(k.reshape(B, kv_x.shape[1], cfg.num_kv_heads, cfg.head_dim),
             "B", None, "M", None)
    v = hint(v.reshape(B, kv_x.shape[1], cfg.num_kv_heads, cfg.head_dim),
             "B", None, "M", None)
    return q, k, v


def gqa_self_attention(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                       cfg: ArchConfig, *, cache: Optional[KVCache] = None,
                       update_cache: bool = False, causal: bool = True
                       ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Self-attention for train (cache=None), prefill (update_cache=True with a
    fresh cache) and decode (cache holds history; x is the new token(s)).
    ``causal=False`` gives bidirectional attention (encoder stacks)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    q, k, v = _proj_qkv(params, x, x, cfg, cdt)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        start = cache.length
        k_all = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), start, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), start, axis=1)
        kv_len = start + x.shape[1]
        new_cache = KVCache(k_all, v_all, kv_len)
        out = multihead_attention(
            q, k_all.astype(cdt), v_all.astype(cdt), causal=causal,
            q_offset=start, kv_len=kv_len, chunk=cfg.attn_chunk,
            logit_cap=cfg.attn_logit_softcap)
    else:
        out = multihead_attention(q, k, v, causal=causal, q_offset=0,
                                  chunk=cfg.attn_chunk,
                                  logit_cap=cfg.attn_logit_softcap,
                                  causal_skip=cfg.flash_causal_skip,
                                  impl=cfg.attn_impl)
    B, S = x.shape[0], x.shape[1]
    out = hint(out.reshape(B, S, cfg.q_dim), "B", None, "M")
    out = hint(dense(out, params["wo"], None, cdt, site="layer.attn.out"),
               "B", None, None)
    return out, (new_cache if (update_cache or cache is not None) else None)


def gqa_cross_attention(params: Params, x: jnp.ndarray, enc_kv: Tuple[jnp.ndarray, jnp.ndarray],
                        cfg: ArchConfig) -> jnp.ndarray:
    """Cross-attention: K/V precomputed from encoder output (no RoPE)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S = x.shape[0], x.shape[1]
    q = dense(x, params["wq"], params.get("bq"), cdt, site="layer.cross.q")
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k, v = enc_kv
    out = multihead_attention(q, k.astype(cdt), v.astype(cdt), causal=False,
                              chunk=cfg.attn_chunk, impl=cfg.attn_impl)
    out = out.reshape(B, S, cfg.q_dim)
    return dense(out, params["wo"], None, cdt, site="layer.cross.out")


def cross_attention_kv(params: Params, enc_out: jnp.ndarray, cfg: ArchConfig):
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S = enc_out.shape[0], enc_out.shape[1]
    k = dense(enc_out, params["wk"], params.get("bk"), cdt,
              site="layer.cross.k")
    v = dense(enc_out, params["wv"], params.get("bv"), cdt,
              site="layer.cross.v")
    return (k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim),
            v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim))


def init_gqa_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> KVCache:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# paged decode (physically paged KV arena; kernels/paged_attn.py)
# ---------------------------------------------------------------------------

def gqa_paged_decode(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                     cfg: ArchConfig, *, k_arena, v_arena, block_tables,
                     kv_lens, write_mask):
    """One-token batched decode through the paged KV arena.

    x: (S, 1, d) — one pending token per lane; positions: (S, 1);
    k_arena/v_arena: (NB, bs, KVH, hd) physical pages (trailing block is the
    write-discard scratch); block_tables: (S, W) int32 pages in logical
    order; kv_lens: (S,) tokens already in the arena; write_mask: (S,) int32
    — 1 writes the new token's KV and attends over kv_len+1 tokens, 0
    leaves the arena unchanged (the lane's output is discarded by the
    engine).  Returns (out (S, 1, d), new_k_arena, new_v_arena).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    q, k, v = _proj_qkv(params, x, x, cfg, cdt)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    from repro.kernels import ops as kops
    NB, bs = k_arena.shape[0], k_arena.shape[1]
    # decode is the C=1 case of the chunk write: write_mask doubles as the
    # 0/1 chunk length (masked lanes land in the trash block)
    wm = (write_mask > 0).astype(kv_lens.dtype)
    rows = _paged_chunk_rows(block_tables, kv_lens, wm, 1, bs, NB)
    # saralint: ok[cow-gate] decode appends at row kv_len of the lane's exclusively-owned tail page (or the trash block when masked); shared prefix pages cover only rows < kv_len
    k_arena = _arena_write_chunk(k_arena, rows, k[:, :1])
    v_arena = _arena_write_chunk(v_arena, rows, v[:, :1])
    attn_len = kv_lens + wm
    o = kops.paged_attention(q[:, 0], k_arena, v_arena, block_tables,
                             attn_len, logit_cap=cfg.attn_logit_softcap)
    S = x.shape[0]
    out = hint(o.reshape(S, 1, cfg.q_dim), "B", None, "M")
    out = hint(dense(out, params["wo"], None, cdt, site="layer.attn.out"),
               "B", None, None)
    return out, k_arena, v_arena


def gqa_paged_shared_decode(params: Params, x: jnp.ndarray,
                            positions: jnp.ndarray, cfg: ArchConfig, *,
                            k_arena, v_arena, block_tables, kv_lens,
                            write_mask, prefix_pages, prefix_lens,
                            unique_tables, unique_lens):
    """Cascade-decode twin of :func:`gqa_paged_decode`: the KV *write* goes
    through the full per-lane ``block_tables`` exactly as before (the
    pending token's row lands in the lane's own — never shared — tail
    page), while attention splits into a shared-prefix phase over
    ``prefix_pages`` (streamed once for every sharing lane) and a per-lane
    unique phase over ``unique_tables``, merged by online-softmax state
    (kernels/ops.py::shared_paged_attention).  Rope is applied before the
    arena write, so attention over the cached rows is position-free and
    the split changes no lane's math — only how often the hot pages move.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    q, k, v = _proj_qkv(params, x, x, cfg, cdt)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    from repro.kernels import ops as kops
    NB, bs = k_arena.shape[0], k_arena.shape[1]
    wm = (write_mask > 0).astype(kv_lens.dtype)
    rows = _paged_chunk_rows(block_tables, kv_lens, wm, 1, bs, NB)
    # saralint: ok[cow-gate] decode appends at row kv_len of the lane's exclusively-owned tail page (or the trash block when masked); shared prefix pages cover only rows < kv_len
    k_arena = _arena_write_chunk(k_arena, rows, k[:, :1])
    v_arena = _arena_write_chunk(v_arena, rows, v[:, :1])
    o = kops.shared_paged_attention(
        q[:, 0], k_arena, v_arena, unique_tables, unique_lens,
        prefix_pages, prefix_lens, logit_cap=cfg.attn_logit_softcap)
    S = x.shape[0]
    out = hint(o.reshape(S, 1, cfg.q_dim), "B", None, "M")
    out = hint(dense(out, params["wo"], None, cdt, site="layer.attn.out"),
               "B", None, None)
    return out, k_arena, v_arena


def _paged_chunk_rows(tables, kv_lens, chunk_lens, num_rows: int,
                      block_size: int, num_blocks: int):
    """Flat arena row for each of a lane's ``num_rows`` chunk positions
    ((S, C) int32).  Chunk row r lands at logical position
    ``kv_lens[lane] + r``; rows at or past a lane's ``chunk_lens`` land in
    row 0 of the trash block — the arena's trailing block, never
    pool-allocated — so ragged lanes (and lanes with no chunk this step)
    cannot corrupt live pages (clamped gather keeps masked lanes' table
    lookups in bounds)."""
    S, W = tables.shape
    pos = kv_lens[:, None] + jnp.arange(num_rows)[None, :]      # (S, C)
    blk = jnp.take_along_axis(tables, jnp.clip(pos // block_size, 0, W - 1),
                              axis=1)
    rows = blk * block_size + pos % block_size
    valid = jnp.arange(num_rows)[None, :] < chunk_lens[:, None]
    return jnp.where(valid, rows, (num_blocks - 1) * block_size)


def _arena_write_chunk(arena: jnp.ndarray, rows: jnp.ndarray,
                       new: jnp.ndarray):
    """Scatter C new rows per lane into the flattened (NB*bs) arena.
    rows: (S, C); new: (S, C, *feat).  Masked rows all target the trash
    block's row 0 — colliding writes there are fine, it is discard space."""
    NB, bs = arena.shape[0], arena.shape[1]
    flat = arena.reshape((NB * bs,) + arena.shape[2:])
    flat = flat.at[rows.reshape(-1)].set(
        new.reshape((-1,) + new.shape[2:]).astype(arena.dtype))
    return flat.reshape(arena.shape)


def gqa_paged_prefill(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                      cfg: ArchConfig, *, k_arena, v_arena, block_tables,
                      kv_lens, chunk_lens):
    """Chunked-prefill attention through the paged KV arena.

    x: (S, C, d) — one prompt chunk per lane; positions: (S, C) absolute;
    k_arena/v_arena: (NB, bs, KVH, hd) physical pages (trailing block is
    the write-discard scratch); block_tables: (S, W) int32 pages in logical
    order; kv_lens: (S,) rows already committed per lane (the chunk's
    absolute start); chunk_lens: (S,) valid new rows — rows at or past a
    lane's chunk length write to the trash block and their outputs are
    garbage the caller discards (ragged batch: one call serves
    heterogeneous prompt lengths).  The chunk's K/V rows are written into
    the arena *before* attention, so chunk queries see their own keys
    causally.  Returns (out (S, C, d), new_k_arena, new_v_arena).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    q, k, v = _proj_qkv(params, x, x, cfg, cdt)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    from repro.kernels import ops as kops
    NB, bs = k_arena.shape[0], k_arena.shape[1]
    S, C = x.shape[0], x.shape[1]
    rows = _paged_chunk_rows(block_tables, kv_lens, chunk_lens, C, bs, NB)
    # saralint: ok[cow-gate] chunk rows target pages the engine COW-forked via _cow_chunk_pages before this jitted body runs
    k_arena = _arena_write_chunk(k_arena, rows, k)
    v_arena = _arena_write_chunk(v_arena, rows, v)
    attn_len = kv_lens + chunk_lens
    o = kops.paged_prefill_attention(q, k_arena, v_arena, block_tables,
                                     kv_lens, attn_len,
                                     logit_cap=cfg.attn_logit_softcap)
    out = hint(o.reshape(S, C, cfg.q_dim), "B", None, "M")
    out = hint(dense(out, params["wo"], None, cdt, site="layer.attn.out"),
               "B", None, None)
    return out, k_arena, v_arena


def _mla_absorb_q(q_nope, w_uk, cdt, *, site: str):
    """Absorb W_UK into the queries through the dispatch layer.

    q_nope: (..., S, H, d); w_uk: (r, H, d).  Equivalent to
    ``einsum("...shd,rhd->...shr")`` but expressed as the per-head
    expert-bank GEMM x (..., H, S, d) @ w (H, d, r) so ADAPTNET observes
    the shape and the RSA executes the contraction."""
    from repro import dispatch
    wk = jnp.transpose(w_uk.astype(cdt), (1, 2, 0))        # (H, d, r)
    xq = jnp.moveaxis(q_nope.astype(cdt), -2, -3)          # (..., H, S, d)
    out = dispatch.gemm(xq, wk, site=site)                 # (..., H, S, r)
    return jnp.moveaxis(out, -3, -2)                       # (..., S, H, r)


def _mla_mix_latent(o_lat, w_uv, cdt, *, site: str):
    """Mix attention's latent output up through W_UV via the dispatch
    layer.  o_lat: (..., S, H, r); w_uv: (r, H, d).  Equivalent to
    ``einsum("...shr,rhd->...shd")`` as the per-head expert-bank GEMM."""
    from repro import dispatch
    wv = jnp.transpose(w_uv.astype(cdt), (1, 0, 2))        # (H, r, d)
    xo = jnp.moveaxis(o_lat.astype(cdt), -2, -3)           # (..., H, S, r)
    out = dispatch.gemm(xo, wv, site=site)                 # (..., H, S, d)
    return jnp.moveaxis(out, -3, -2)                       # (..., S, H, d)


def mla_paged_prefill(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                      cfg: ArchConfig, *, ckv_arena, krope_arena,
                      block_tables, kv_lens, chunk_lens):
    """Absorbed-MLA chunked prefill through the paged latent arena.

    The arena stores the compressed (c_kv, k_rope) rows only; chunk queries
    are absorbed through W_UK before the kernel and the latent mix goes
    through W_UV/W_O after — the same formulation as
    :func:`mla_paged_decode`, widened to C causal rows per lane.  Shapes as
    in :func:`gqa_paged_prefill` with ckv_arena (NB, bs, kv_lora_rank) and
    krope_arena (NB, bs, qk_rope_head_dim).
    """
    m = cfg.mla
    cdt = jnp.dtype(cfg.compute_dtype)
    S, C = x.shape[0], x.shape[1]
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(params, x, positions, cfg, cdt)    # (S,C,H,*)
    c_kv, k_rope = _mla_ckv(params, x, positions, cfg, cdt)    # (S,C,r/rd)

    from repro.kernels import ops as kops
    NB, bs = ckv_arena.shape[0], ckv_arena.shape[1]
    rows = _paged_chunk_rows(block_tables, kv_lens, chunk_lens, C, bs, NB)
    # saralint: ok[cow-gate] chunk rows target pages the engine COW-forked via _cow_chunk_pages before this jitted body runs
    ckv_arena = _arena_write_chunk(ckv_arena, rows, c_kv)
    krope_arena = _arena_write_chunk(krope_arena, rows, k_rope)

    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_abs = _mla_absorb_q(q_nope, w_uk, cdt, site="layer.mla.q_absorb")
    attn_len = kv_lens + chunk_lens
    o_lat = kops.mla_paged_prefill_attention(
        q_abs, q_rope, ckv_arena, krope_arena, block_tables, kv_lens,
        attn_len, qk_dim=m.qk_nope_head_dim + m.qk_rope_head_dim)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = _mla_mix_latent(o_lat, w_uv, cdt, site="layer.mla.v_mix")
    out = out.reshape(S, C, H * m.v_head_dim)
    out = dense(out, params["wo"], None, cdt, site="layer.mla.out")
    return out, ckv_arena, krope_arena


def mla_paged_decode(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                     cfg: ArchConfig, *, ckv_arena, krope_arena, block_tables,
                     kv_lens, write_mask):
    """Absorbed-MLA batched decode through the paged latent arena.

    The arena stores the compressed (c_kv, k_rope) rows only (the same
    ~70 KB/token layout as the dense absorbed path); queries are absorbed
    through W_UK before the kernel and the latent mix goes through W_UV/W_O
    after.  Shapes as in :func:`gqa_paged_decode` with ckv_arena
    (NB, bs, kv_lora_rank) and krope_arena (NB, bs, qk_rope_head_dim).
    """
    m = cfg.mla
    cdt = jnp.dtype(cfg.compute_dtype)
    S = x.shape[0]
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(params, x, positions, cfg, cdt)    # (S,1,H,*)
    c_kv, k_rope = _mla_ckv(params, x, positions, cfg, cdt)    # (S,1,r/rd)

    from repro.kernels import ops as kops
    NB, bs = ckv_arena.shape[0], ckv_arena.shape[1]
    wm = (write_mask > 0).astype(kv_lens.dtype)
    rows = _paged_chunk_rows(block_tables, kv_lens, wm, 1, bs, NB)
    # saralint: ok[cow-gate] decode appends at row kv_len of the lane's exclusively-owned tail page (or the trash block when masked); shared prefix pages cover only rows < kv_len
    ckv_arena = _arena_write_chunk(ckv_arena, rows, c_kv[:, :1])
    krope_arena = _arena_write_chunk(krope_arena, rows, k_rope[:, :1])

    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_abs = _mla_absorb_q(q_nope, w_uk, cdt,
                          site="layer.mla.q_absorb")[:, 0]
    attn_len = kv_lens + wm
    o_lat = kops.mla_paged_attention(
        q_abs, q_rope[:, 0], ckv_arena, krope_arena, block_tables, attn_len,
        qk_dim=m.qk_nope_head_dim + m.qk_rope_head_dim)       # (S, H, r)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = _mla_mix_latent(o_lat[:, None], w_uv, cdt,
                          site="layer.mla.v_mix")[:, 0]
    out = out.reshape(S, 1, H * m.v_head_dim)
    out = dense(out, params["wo"], None, cdt, site="layer.mla.out")
    return out, ckv_arena, krope_arena


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig) -> Params:
    m = cfg.mla
    dt = cfg.param_dtype
    ks = jax.random.split(key, 6)
    H = cfg.num_heads
    return {
        "w_dq": dense_init(ks[0], cfg.d_model, m.q_lora_rank, dt),
        "q_norm": jnp.zeros((m.q_lora_rank,), dt),
        "w_uq": dense_init(ks[1], m.q_lora_rank,
                           H * (m.qk_nope_head_dim + m.qk_rope_head_dim), dt),
        "w_dkv": dense_init(ks[2], cfg.d_model,
                            m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dt),
        "w_uk": dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_head_dim, dt),
        "w_uv": dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim, dt),
        "wo": dense_init(ks[5], H * m.v_head_dim, cfg.d_model, dt,
                         scale=1.0 / ((H * m.v_head_dim) ** 0.5
                                      * (2 * cfg.num_layers) ** 0.5)),
    }


def _mla_q(params, x, positions, cfg: ArchConfig, cdt):
    m = cfg.mla
    B, S = x.shape[0], x.shape[1]
    H = cfg.num_heads
    cq = rmsnorm(dense(x, params["w_dq"], None, cdt,
                       site="layer.mla.q_down"), params["q_norm"],
                 cfg.norm_eps)
    q = dense(cq, params["w_uq"], None, cdt,
              site="layer.mla.q_up").reshape(
        B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(params, x, positions, cfg: ArchConfig, cdt):
    m = cfg.mla
    dkv = dense(x, params["w_dkv"], None, cdt, site="layer.mla.kv_down")
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_self_attention(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                       cfg: ArchConfig, *, cache: Optional[KVCache] = None,
                       update_cache: bool = False
                       ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    m = cfg.mla
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S = x.shape[0], x.shape[1]
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(params, x, positions, cfg, cdt)
    c_kv, k_rope = _mla_ckv(params, x, positions, cfg, cdt)

    if cache is None:
        # expanded (train/prefill-without-cache) path: standard flash attention
        # over per-head keys (nope ++ shared rope) and values.
        k_nope = dense(c_kv, params["w_uk"], None, cdt,
                       site="layer.mla.k_up").reshape(
            B, S, H, m.qk_nope_head_dim)
        v = dense(c_kv, params["w_uv"], None, cdt,
                  site="layer.mla.v_up").reshape(B, S, H, m.v_head_dim)
        k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                    (B, S, H, m.qk_rope_head_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        # scale by full qk dim to match the absorbed path
        out = multihead_attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                                  causal_skip=cfg.flash_causal_skip,
                                  impl=cfg.attn_impl)
        out = out.reshape(B, S, H * m.v_head_dim)
        out = dense(out, params["wo"], None, cdt, site="layer.mla.out")
        new_cache = None
        if update_cache:
            raise ValueError("prefill with cache must pass an initialized cache")
        return out, new_cache

    # absorbed path — attend in the compressed latent space; cache stores
    # (c_kv, k_rope) only.
    start = cache.length
    ckv_all = jax.lax.dynamic_update_slice_in_dim(
        cache.k, c_kv.astype(cache.k.dtype), start, axis=1)
    krope_all = jax.lax.dynamic_update_slice_in_dim(
        cache.v, k_rope.astype(cache.v.dtype), start, axis=1)
    kv_len = start + S
    new_cache = KVCache(ckv_all, krope_all, kv_len)

    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    # absorb W_UK into q:  q_abs[b,s,h,r] = sum_d q_nope[b,s,h,d] * w_uk[r,h,d]
    q_abs = _mla_absorb_q(q_nope, w_uk, cdt, site="layer.mla.q_absorb")
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
    # saralint: ok[dispatch-escape] latent attention scores against the cached activations, not a weight
    s_nope = jnp.einsum("bshr,btr->bhst", q_abs, ckv_all.astype(cdt))
    # saralint: ok[dispatch-escape] decoupled-rope scores against the cached activations, not a weight
    s_rope = jnp.einsum("bshd,btd->bhst", q_rope, krope_all.astype(cdt))
    s = (s_nope + s_rope) * scale
    t_pos = jnp.arange(ckv_all.shape[1])
    mask = (t_pos[None, :] <= (start + jnp.arange(S))[:, None]) & \
           (t_pos[None, :] < kv_len)
    s = jnp.where(mask[None, None, :, :], s.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(cdt)
    # saralint: ok[dispatch-escape] softmax-weights x cached latent rows, both activations
    o_lat = jnp.einsum("bhst,btr->bshr", p, ckv_all.astype(cdt))
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = _mla_mix_latent(o_lat, w_uv, cdt, site="layer.mla.v_mix")
    out = out.reshape(B, S, H * m.v_head_dim)
    out = dense(out, params["wo"], None, cdt, site="layer.mla.out")
    return out, new_cache


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> KVCache:
    m = cfg.mla
    return KVCache(
        jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        jnp.zeros((), jnp.int32))
