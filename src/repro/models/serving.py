"""Prefill / decode with per-family caches.

Cache layout (a plain pytree, so it shards/checkpoints like params):

  dense/moe/vlm : {"pos", "layers": KVCache stacked (L, B, S_max, KVH, hd)}
  ssm (rwkv6)   : {"pos", "layers": RWKVState stacked (L, ...)}   — O(1) in S
  hybrid        : {"pos", "layers": MambaState stacked (n_mamba, ...),
                   "attn": KVCache stacked (n_groups, B, S_max, KVH, hd)}
  encdec        : {"pos", "layers": self-attn KVCache stacked,
                   "cross_k"/"cross_v": (L, B, S_src, KVH, hd)}

MLA caches store (c_kv, k_rope) — the compressed latent — via the absorbed
decode path in attention.py.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import (KVCache, cross_attention_kv,
                                    init_gqa_cache, init_mla_cache)
from repro.models.transformer import (_embed, _frontend_embed, _maybe_remat,
                                      _scan_mamba_span, _unembed_weight,
                                      decoder_layer_apply, hybrid_layout,
                                      paged_decoder_layer_apply,
                                      paged_prefill_layer_apply,
                                      paged_shared_decoder_layer_apply,
                                      Params)
from repro.models.modules import dense, rmsnorm

Cache = Dict[str, Any]

# Families whose decode KV can live in the physically paged arena: a single
# homogeneous self-attention stack per step.  encdec pages its self-attn KV
# only (the fixed-length cross K/V stays dense per slot); ssm/hybrid keep
# the dense slot layout — their recurrent state is O(1) in sequence length,
# so there is nothing to page.
PAGED_FAMILIES = ("dense", "moe", "vlm", "encdec")

# Families whose *prefill* can stream through the arena in chunks
# (paged_prefill_step): pure text-token causal self-attention stacks.  vlm
# prepends frontend rows that are not tokens and encdec needs the encoder
# pass + cross K/V capture, so both keep the single-shot bucketed prefill
# whose scratch dense cache is scattered into pages (paged_prefill_write).
CHUNKED_PREFILL_FAMILIES = ("dense", "moe")


def _stack_cache(proto, n: int):
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy()
        if hasattr(a, "shape") else a, proto)


def _layer_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    if cfg.attention_type == "mla":
        return init_mla_cache(cfg, batch, max_len, dtype)
    return init_gqa_cache(cfg, batch, max_len, dtype)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               src_len: int = 0) -> Cache:
    dt = jnp.dtype(cfg.compute_dtype)
    fam = cfg.family
    cache: Cache = {"pos": jnp.zeros((), jnp.int32)}
    if fam in ("dense", "moe", "vlm", "encdec"):
        proto = _layer_kv_cache(cfg, batch, max_len, dt)
        cache["layers"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(),
            proto)
        if fam == "encdec":
            kv_shape = (cfg.num_layers, batch, src_len, cfg.num_kv_heads,
                        cfg.head_dim)
            cache["cross_k"] = jnp.zeros(kv_shape, dt)
            cache["cross_v"] = jnp.zeros(kv_shape, dt)
    elif fam == "ssm":
        proto = ssm_mod.init_rwkv_state(cfg, batch, dt)
        cache["layers"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(),
            proto)
    elif fam == "hybrid":
        n_m, n_groups, _, _ = hybrid_layout(cfg)
        proto = ssm_mod.init_mamba_state(cfg, batch, dt)
        cache["layers"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n_m,) + a.shape).copy(), proto)
        a_proto = _layer_kv_cache(cfg, batch, max_len, dt)
        cache["attn"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy(),
            a_proto)
    else:
        raise ValueError(fam)
    return cache


# ---------------------------------------------------------------------------
# paged KV arena (physical pages; consumed by paged_decode_step)
# ---------------------------------------------------------------------------

def init_paged_arena(cfg: ArchConfig, num_blocks: int,
                     block_size: int) -> Dict[str, Any]:
    """Per-layer physical KV pages for the attention stack.

    Leaves are ``(num_layers, num_blocks, block_size, *feat)``: ``k``/``v``
    rows for GQA, the compressed ``(c_kv, k_rope)`` latent rows for MLA
    (mirroring the dense KVCache's k/v slots).  The caller decides how many
    blocks to allocate; the serving engine passes pool blocks + 1 and uses
    the trailing block as write-discard scratch for masked lanes.
    """
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(f"family {cfg.family!r} has no paged KV arena")
    dt = jnp.dtype(cfg.compute_dtype)
    L = cfg.num_layers
    if cfg.attention_type == "mla":
        m = cfg.mla
        return {"k": jnp.zeros((L, num_blocks, block_size, m.kv_lora_rank),
                               dt),
                "v": jnp.zeros((L, num_blocks, block_size,
                                m.qk_rope_head_dim), dt)}
    shape = (L, num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def init_paged_state(cfg: ArchConfig, num_slots: int,
                     src_len: int = 0) -> Dict[str, Any]:
    """Slot-stacked per-lane state that stays dense under the paged layout
    (currently only the encdec cross-attention K/V; positions are implied
    by the per-lane kv_lens the engine tracks)."""
    st: Dict[str, Any] = {}
    if cfg.family == "encdec":
        dt = jnp.dtype(cfg.compute_dtype)
        shape = (cfg.num_layers, num_slots, src_len, cfg.num_kv_heads,
                 cfg.head_dim)
        st["cross_k"] = jnp.zeros(shape, dt)
        st["cross_v"] = jnp.zeros(shape, dt)
    return st


def paged_prefill_write(arena: Dict[str, Any], layers_cache: KVCache,
                        block_ids: jnp.ndarray) -> Dict[str, Any]:
    """Commit a freshly prefilled batch=1 dense cache into arena pages.

    ``block_ids``: (nblk,) int32 physical pages in logical order.  The copy
    happens at bucket granularity — the first ``nblk * block_size`` rows of
    the dense cache are reshaped into pages and scattered, so the padded-
    bucket prefill itself is untouched; rows past the true length are
    bucket padding that decode masks (and overwrites as tokens arrive).
    """
    nblk = block_ids.shape[0]

    def put(leaf, dense_leaf):
        bs = leaf.shape[2]
        rows = dense_leaf[:, 0, :nblk * bs]
        rows = rows.reshape((dense_leaf.shape[0], nblk, bs) +
                            dense_leaf.shape[3:])
        return leaf.at[:, block_ids].set(rows.astype(leaf.dtype))

    return {"k": put(arena["k"], layers_cache.k),
            "v": put(arena["v"], layers_cache.v)}


def _scan_paged_layers(body, x, params: Params, arena: Dict[str, Any]):
    """Scan a decoder stack's layer body over per-layer arena pages,
    splitting the layer axis for MoE models with a leading dense stack
    (deepseek-v3).  ``body(h, (layer_p, k_pages, v_pages)) -> (h, (nk,
    nv))``; returns (x, {"k": nk, "v": nv})."""
    if "dense_layers" in params:
        nd = jax.tree_util.tree_leaves(params["dense_layers"])[0].shape[0]
        x, (hk, hv) = jax.lax.scan(
            body, x, (params["dense_layers"], arena["k"][:nd],
                      arena["v"][:nd]))
        x, (tk, tv) = jax.lax.scan(
            body, x, (params["layers"], arena["k"][nd:], arena["v"][nd:]))
        return x, {"k": jnp.concatenate([hk, tk], axis=0),
                   "v": jnp.concatenate([hv, tv], axis=0)}
    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], arena["k"],
                                         arena["v"]))
    return x, {"k": nk, "v": nv}


def paged_decode_step(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
                      state: Dict[str, Any], arena: Dict[str, Any],
                      block_tables: jnp.ndarray, kv_lens: jnp.ndarray,
                      write_mask: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One batched decode step over every lane through the paged KV arena.

    tokens: (S, 1) int32 — one pending token per lane; block_tables:
    (S, W) int32; kv_lens: (S,) rows already committed per lane (this IS
    each lane's position — vlm frontend rows included); write_mask: (S,)
    int32 — lanes with 0 (stalled / empty slots) leave the arena untouched
    and their logits are discarded by the caller, so there is nothing to
    snapshot or roll back.  Returns ((S, V) logits, new arena).
    """
    fam = cfg.family
    if fam not in PAGED_FAMILIES:
        raise ValueError(f"family {fam!r} cannot decode through the paged "
                         "arena (recurrent state keeps the dense layout)")
    x = _embed(params, tokens, cfg)
    positions = kv_lens[:, None]
    wm = write_mask.astype(jnp.int32)

    def body(h, xs):
        if fam == "encdec":
            layer_p, ak, av, ck, cv = xs
            enc_kv = (ck, cv)
        else:
            layer_p, ak, av = xs
            enc_kv = None
        h, nk, nv = paged_decoder_layer_apply(
            layer_p, h, positions, cfg, k_arena=ak, v_arena=av,
            block_tables=block_tables, kv_lens=kv_lens, write_mask=wm,
            enc_kv=enc_kv)
        return h, (nk, nv)

    body = _maybe_remat(body, cfg)
    if fam == "encdec":
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], arena["k"], arena["v"],
                      state["cross_k"], state["cross_v"]))
        new_arena = {"k": nk, "v": nv}
    else:
        x, new_arena = _scan_paged_layers(body, x, params, arena)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x[:, -1, :], cfg), new_arena


def paged_shared_decode_step(params: Params, tokens: jnp.ndarray,
                             cfg: ArchConfig, state: Dict[str, Any],
                             arena: Dict[str, Any],
                             block_tables: jnp.ndarray, kv_lens: jnp.ndarray,
                             write_mask: jnp.ndarray,
                             prefix_pages: jnp.ndarray,
                             prefix_lens: jnp.ndarray,
                             unique_tables: jnp.ndarray,
                             unique_lens: jnp.ndarray
                             ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Cascade decode: like :func:`paged_decode_step`, but each lane's
    attention splits into a shared-prefix phase (the hot ``prefix_pages``
    are streamed ONCE per step for every lane in the sharing group) and a
    per-lane unique phase over ``unique_tables``/``unique_lens``, merged by
    online-softmax state.  The KV write still goes through the full
    ``block_tables``.  GQA text families only (absorbed MLA and the
    frontend families keep the plain paged path).  Returns ((S, V) logits,
    new arena)."""
    fam = cfg.family
    if fam not in CHUNKED_PREFILL_FAMILIES or cfg.attention_type == "mla":
        raise ValueError(f"family {fam!r}/{cfg.attention_type} cannot run "
                         "shared-prefix cascade decode (GQA text families "
                         "only)")
    x = _embed(params, tokens, cfg)
    positions = kv_lens[:, None]
    wm = write_mask.astype(jnp.int32)

    def body(h, xs):
        layer_p, ak, av = xs
        h, nk, nv = paged_shared_decoder_layer_apply(
            layer_p, h, positions, cfg, k_arena=ak, v_arena=av,
            block_tables=block_tables, kv_lens=kv_lens, write_mask=wm,
            prefix_pages=prefix_pages, prefix_lens=prefix_lens,
            unique_tables=unique_tables, unique_lens=unique_lens)
        return h, (nk, nv)

    body = _maybe_remat(body, cfg)
    x, new_arena = _scan_paged_layers(body, x, params, arena)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x[:, -1, :], cfg), new_arena


def paged_prefill_step(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
                       arena: Dict[str, Any], block_tables: jnp.ndarray,
                       kv_lens: jnp.ndarray, chunk_lens: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One chunked-prefill step over every lane through the paged KV arena.

    tokens: (S, C) int32 — one prompt chunk per lane, right-padded;
    block_tables: (S, W) int32; kv_lens: (S,) rows already committed per
    lane (the chunk's absolute start position); chunk_lens: (S,) valid
    tokens in each lane's chunk — 0 skips the lane entirely (its padded
    rows write to the trash block and its logits row is garbage the caller
    ignores).  Each layer writes the chunk's K/V rows directly into the
    lane's pages, then attends causally over everything written so far —
    no dense scratch cache, no bucket-granularity copy, so prefill KV
    traffic is exactly the chunk's real tokens.  Returns ((S, V) logits at
    each lane's last valid chunk row, new arena).
    """
    fam = cfg.family
    if fam not in CHUNKED_PREFILL_FAMILIES:
        raise ValueError(f"family {fam!r} cannot prefill through the paged "
                         f"arena in chunks (supported: "
                         f"{CHUNKED_PREFILL_FAMILIES})")
    S, C = tokens.shape
    x = _embed(params, tokens, cfg)
    positions = kv_lens[:, None] + jnp.arange(C)[None, :]

    def body(h, xs):
        layer_p, ak, av = xs
        h, nk, nv = paged_prefill_layer_apply(
            layer_p, h, positions, cfg, k_arena=ak, v_arena=av,
            block_tables=block_tables, kv_lens=kv_lens,
            chunk_lens=chunk_lens)
        return h, (nk, nv)

    body = _maybe_remat(body, cfg)
    x, new_arena = _scan_paged_layers(body, x, params, arena)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    last = jnp.clip(chunk_lens - 1, 0, C - 1)
    h_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0, :]
    return _lm_head(params, h_last, cfg), new_arena


def paged_verify_step(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
                      arena: Dict[str, Any], block_tables: jnp.ndarray,
                      kv_lens: jnp.ndarray, chunk_lens: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Speculative-decode verifier: the ragged chunked-prefill pass with
    logits at EVERY chunk row.

    Identical to :func:`paged_prefill_step` — tokens (S, C) carry each
    lane's pending token followed by its draft proposals, KV rows land in
    the lane's pages before attention, row r attends causally through the
    block table — except the LM head runs over all C rows, because row i's
    logits are what accepts or corrects draft token i+1.  Returns
    ((S, C, V) logits, new arena); rows past ``chunk_lens`` are garbage
    the caller ignores (their KV went to the trash page).
    """
    fam = cfg.family
    if fam not in CHUNKED_PREFILL_FAMILIES:
        raise ValueError(f"family {fam!r} cannot verify through the paged "
                         f"arena (chunked prefill supports "
                         f"{CHUNKED_PREFILL_FAMILIES})")
    S, C = tokens.shape
    x = _embed(params, tokens, cfg)
    positions = kv_lens[:, None] + jnp.arange(C)[None, :]

    def body(h, xs):
        layer_p, ak, av = xs
        h, nk, nv = paged_prefill_layer_apply(
            layer_p, h, positions, cfg, k_arena=ak, v_arena=av,
            block_tables=block_tables, kv_lens=kv_lens,
            chunk_lens=chunk_lens)
        return h, (nk, nv)

    body = _maybe_remat(body, cfg)
    x, new_arena = _scan_paged_layers(body, x, params, arena)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x, cfg), new_arena


# ---------------------------------------------------------------------------
# decoder-stack step (shared by prefill and decode; S is the step width)
# ---------------------------------------------------------------------------

def _run_decoder_stack(params: Params, x, positions, cfg: ArchConfig, cache,
                       cross=False):
    """Scan decoder layers threading per-layer KV caches."""

    def body(h, xs):
        layer_p, layer_c = xs
        if cross:
            enc_kv = (layer_c["ck"], layer_c["cv"])
            h, new_c, _ = decoder_layer_apply(
                layer_p, h, positions, cfg, cache=layer_c["kv"], enc_kv=enc_kv)
            return h, {"kv": new_c}
        h, new_c, _ = decoder_layer_apply(layer_p, h, positions, cfg,
                                          cache=layer_c)
        return h, new_c

    body = _maybe_remat(body, cfg)
    if cross:
        xs = (params["layers"], {"kv": cache["layers"],
                                 "ck": cache["cross_k"],
                                 "cv": cache["cross_v"]})
        x, new = jax.lax.scan(body, x, xs)
        return x, new["kv"]
    layer_caches = cache["layers"]
    if "dense_layers" in params:
        # leading dense stack (deepseek-v3): split the homogeneous cache
        nd = jax.tree_util.tree_leaves(params["dense_layers"])[0].shape[0]
        head = jax.tree_util.tree_map(lambda a: a[:nd], layer_caches)
        tail = jax.tree_util.tree_map(lambda a: a[nd:], layer_caches)
        x, new_head = jax.lax.scan(body, x, (params["dense_layers"], head))
        x, new_tail = jax.lax.scan(body, x, (params["layers"], tail))
        new_layers = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), new_head, new_tail)
        return x, new_layers
    x, new_layers = jax.lax.scan(body, x, (params["layers"], layer_caches))
    return x, new_layers


def _run_ssm_stack(params: Params, x, cfg: ArchConfig, states):
    def body(h, xs):
        layer_p, st = xs
        hn = rmsnorm(h, layer_p["ln1"], cfg.norm_eps)
        y, new_st = ssm_mod.rwkv_block_apply(layer_p["blk"], hn, cfg, st)
        return h + y.astype(h.dtype), new_st

    body = _maybe_remat(body, cfg)
    return jax.lax.scan(body, x, (params["layers"], states))


def _run_hybrid_stack(params: Params, x, positions, cfg: ArchConfig, cache):
    n_m, n_groups, per_group, rem = hybrid_layout(cfg)
    lp, states = params["layers"], cache["layers"]

    def reshape_groups(tree):
        return jax.tree_util.tree_map(
            lambda a: a[:n_groups * per_group].reshape(
                (n_groups, per_group) + a.shape[1:]), tree)

    grouped_p = reshape_groups(lp)
    grouped_s = reshape_groups(states)
    shared_p = params["shared_attn"]

    def body(h, xs):
        g_params, g_states, a_cache = xs
        h, new_g = _scan_mamba_span(g_params, h, cfg, g_states)
        h, new_a, _ = decoder_layer_apply(shared_p, h, positions, cfg,
                                          cache=a_cache)
        return h, (new_g, new_a)

    body = _maybe_remat(body, cfg)
    x, (new_grouped, new_attn) = jax.lax.scan(
        body, x, (grouped_p, grouped_s, cache["attn"]))
    new_states = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups * per_group,) + a.shape[2:]), new_grouped)
    if rem:
        rem_p = jax.tree_util.tree_map(lambda a: a[n_m - rem:], lp)
        rem_s = jax.tree_util.tree_map(lambda a: a[n_m - rem:], states)
        x, new_rem = _scan_mamba_span(rem_p, x, cfg, rem_s)
        new_states = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), new_states, new_rem)
    return x, new_states, new_attn


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def _lm_head(params, h_last, cfg: ArchConfig):
    w = _unembed_weight(params, cfg)
    return dense(h_last, w, None, jnp.float32, site="unembed")


def prefill(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            cache: Cache, length=None) -> Tuple[jnp.ndarray, Cache]:
    """Run the prompt through the model, filling `cache`.

    Returns (logits for the last position (B, V), updated cache).

    ``length`` (scalar int, may be traced) marks the number of valid prompt
    tokens when ``batch["tokens"]`` is right-padded to a bucket shape: logits
    come from position ``length - 1``, the cache position advances by
    ``length``, and every KV-cache length is corrected so later decode steps
    never attend to the padded keys (causal masking already hides them from
    the real prompt positions during prefill).  One compilation per bucket
    shape serves every prompt length in the bucket.  Attention families only:
    ssm/hybrid recurrent state integrates every input token, so padded
    prefill would corrupt it — callers must pass exact-length prompts there.
    """
    fam = cfg.family
    tokens = batch["tokens"]
    pos0 = cache["pos"]
    if length is None:
        length = tokens.shape[1]
    pad = tokens.shape[1] - length

    if fam == "encdec":
        # encoder pass + cross-kv capture
        enc_in = _frontend_embed(params, batch["src_features"], cfg)
        enc_pos = jnp.arange(enc_in.shape[1])[None, :]
        from repro.models.attention import gqa_self_attention
        from repro.models.mlp import mlp_apply

        def enc_body(h, layer_p):
            hn = rmsnorm(h, layer_p["ln1"], cfg.norm_eps)
            a, _ = gqa_self_attention(layer_p["attn"], hn, enc_pos, cfg,
                                      causal=False)
            h = h + a.astype(h.dtype)
            h2 = rmsnorm(h, layer_p["ln2"], cfg.norm_eps)
            return h + mlp_apply(layer_p["mlp"], h2, cfg).astype(h.dtype), None

        enc_out, _ = jax.lax.scan(_maybe_remat(enc_body, cfg), enc_in,
                                  params["enc_layers"])
        enc_out = rmsnorm(enc_out, params["ln_enc"], cfg.norm_eps)

        def kv_body(_, layer_p):
            k, v = cross_attention_kv(layer_p["cross"], enc_out, cfg)
            return None, (k, v)

        _, (ck, cv) = jax.lax.scan(kv_body, None, params["layers"])
        cache = dict(cache)
        cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
        cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
        x = _embed(params, tokens, cfg)
        positions = pos0 + jnp.arange(tokens.shape[1])[None, :]
        x, new_layers = _run_decoder_stack(params, x, positions, cfg, cache,
                                           cross=True)
    elif fam == "vlm":
        img = _frontend_embed(params, batch["patch_embeds"], cfg)
        txt = _embed(params, tokens, cfg)
        x = jnp.concatenate([img, txt], axis=1)
        positions = pos0 + jnp.arange(x.shape[1])[None, :]
        x, new_layers = _run_decoder_stack(params, x, positions, cfg, cache)
    elif fam in ("dense", "moe"):
        x = _embed(params, tokens, cfg)
        positions = pos0 + jnp.arange(tokens.shape[1])[None, :]
        x, new_layers = _run_decoder_stack(params, x, positions, cfg, cache)
    elif fam == "ssm":
        x = _embed(params, tokens, cfg)
        x, new_layers = _run_ssm_stack(params, x, cfg, cache["layers"])
    elif fam == "hybrid":
        x = _embed(params, tokens, cfg)
        positions = pos0 + jnp.arange(tokens.shape[1])[None, :]
        x, new_layers, new_attn = _run_hybrid_stack(params, x, positions,
                                                    cfg, cache)
    else:
        raise ValueError(fam)

    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    # the stack counted the padded width into every KVCache length
    if fam in ("dense", "moe", "vlm", "encdec"):
        new_cache["layers"] = new_layers._replace(
            length=new_layers.length - pad)
    elif fam == "hybrid":
        new_cache["attn"] = new_attn._replace(length=new_attn.length - pad)
    step = length if fam != "vlm" else length + \
        batch["patch_embeds"].shape[1]
    new_cache["pos"] = pos0 + step
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    last = step - 1
    h_last = jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1)[:, 0, :]
    return _lm_head(params, h_last, cfg), new_cache


def decode_step(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
                cache: Cache) -> Tuple[jnp.ndarray, Cache]:
    """One decode step.  tokens: (B, 1) int32.  Returns ((B, V) logits, cache)."""
    fam = cfg.family
    pos0 = cache["pos"]
    x = _embed(params, tokens, cfg)
    positions = pos0 + jnp.arange(tokens.shape[1])[None, :]

    if fam in ("dense", "moe", "vlm"):
        x, new_layers = _run_decoder_stack(params, x, positions, cfg, cache)
        new_attn = None
    elif fam == "encdec":
        x, new_layers = _run_decoder_stack(params, x, positions, cfg, cache,
                                           cross=True)
        new_attn = None
    elif fam == "ssm":
        x, new_layers = _run_ssm_stack(params, x, cfg, cache["layers"])
        new_attn = None
    elif fam == "hybrid":
        x, new_layers, new_attn = _run_hybrid_stack(params, x, positions,
                                                    cfg, cache)
    else:
        raise ValueError(fam)

    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    if new_attn is not None:
        new_cache["attn"] = new_attn
    new_cache["pos"] = pos0 + tokens.shape[1]
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x[:, -1, :], cfg), new_cache
