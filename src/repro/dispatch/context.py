"""Ambient dispatch policy (dispatcher + execution backend + registry).

The policy is an explicit stack manipulated by the ``use`` context
manager; ``active()`` returns the top of the stack (or a lazily-built
default: oracle dispatcher, ``execute="auto"``, process-wide registry).
This replaces the old mutable ``_GLOBAL`` dispatcher singleton in
``core/sara.py`` — the policy is scoped, explicit, and restorable.

The policy is consulted at *trace* time: a jitted function bakes in
whatever policy was active when it first traced.  Enter ``use(...)``
around the call that triggers compilation (the serving engine and the
launchers do this for you).
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, replace
from typing import List, Optional

import jax

from repro.dispatch.registry import SiteRegistry

EXECUTE_MODES = ("auto", "pallas", "xla")


@dataclass(frozen=True)
class DispatchPolicy:
    dispatcher: "SaraDispatcher"       # noqa: F821 (resolved lazily)
    execute: str = "auto"              # "pallas" | "xla" | "auto"
    registry: SiteRegistry = None
    interpret: Optional[bool] = None   # None -> backend-aware (kernels/ops)
    shard_hints: bool = False          # apply ShardPlan hints on xla outputs

    def backend(self) -> str:
        """Resolve 'auto' at trace time: compiled Pallas on TPU, XLA off."""
        if self.execute == "auto":
            return "pallas" if jax.default_backend() == "tpu" else "xla"
        return self.execute


_DEFAULT_REGISTRY = SiteRegistry()
_STACK: List[DispatchPolicy] = []
_DEFAULT: Optional[DispatchPolicy] = None


def default_registry() -> SiteRegistry:
    return _DEFAULT_REGISTRY


def active() -> DispatchPolicy:
    """The innermost policy, or the lazily-built process default."""
    if _STACK:
        return _STACK[-1]
    global _DEFAULT
    if _DEFAULT is None:
        from repro.core.sara import SaraDispatcher
        _DEFAULT = DispatchPolicy(dispatcher=SaraDispatcher(),
                                  registry=_DEFAULT_REGISTRY)
    return _DEFAULT


@contextlib.contextmanager
def use(dispatcher=None, execute: Optional[str] = None,
        registry: Optional[SiteRegistry] = None,
        interpret: Optional[bool] = None,
        shard_hints: Optional[bool] = None):
    """Install a dispatch policy; unset fields inherit from the active one.

        with dispatch.use(my_dispatcher, execute="pallas"):
            engine.step()
    """
    if execute is not None and execute not in EXECUTE_MODES:
        raise ValueError(f"execute must be one of {EXECUTE_MODES}, "
                         f"got {execute!r}")
    base = active()
    pol = replace(
        base,
        dispatcher=dispatcher if dispatcher is not None else base.dispatcher,
        execute=execute if execute is not None else base.execute,
        registry=registry if registry is not None else base.registry,
        interpret=interpret if interpret is not None else base.interpret,
        shard_hints=(shard_hints if shard_hints is not None
                     else base.shard_hints))
    _STACK.append(pol)
    try:
        yield pol
    finally:
        _STACK.pop()
