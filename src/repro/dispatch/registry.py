"""Per-trace site registry: which GEMM sites executed with which config.

``dispatch.gemm`` records one ``SiteRecord`` per site at *trace* time —
the moment the tile configuration is baked into the executable.  Records
are grouped into named *scopes* (one scope per traced entry point, e.g.
``prefill:m16`` or ``decode``), so a caller can read back the plan that a
given compiled function actually executes.  Because jit caches traces,
a scope is populated exactly once per compilation: re-reading it on later
steps is how the serving engine derives its executed ``gemm_plan``
without re-running any recommendation sweep.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.hw import DATAFLOW_NAMES
from repro.core.tpu_costmodel import TPUTileConfig


@dataclass(frozen=True)
class SiteRecord:
    site: str
    m: int
    k: int
    n: int
    cfg: TPUTileConfig         # the dispatcher's recommendation
    block_m: int               # executed blocks (clamped to the padded shape)
    block_n: int
    block_k: int
    mode: int
    backend: str               # "pallas" | "xla"
    shard_plan: str = ""       # mesh-level plan name ("" when meshless)
    source: str = "oracle"     # "oracle" | "adaptnet" | "oracle_fallback"

    def executed(self) -> Tuple[int, int, int, int]:
        """The tile configuration this site actually ran with (clamped
        blocks + residency mode) — the thing plan-agreement compares."""
        return (self.block_m, self.block_n, self.block_k, self.mode)

    def describe(self) -> str:
        s = (f"bm={self.block_m} bn={self.block_n} bk={self.block_k} "
             f"{DATAFLOW_NAMES[self.mode]} @{self.backend}")
        if self.source != "oracle":
            s += f" src={self.source}"
        if self.shard_plan:
            s += f" shard={self.shard_plan}"
        return s


class SiteRegistry:
    """Scope -> site-name -> SiteRecord, insertion-ordered.

    When a ``recorder`` (:class:`repro.obs.trace.TraceRecorder`) is
    attached, every ``record()`` also emits one ``dispatch`` trace event
    — site, (M, K, N), the executed tile, recommendation provenance and
    the analytic cost of the chosen vs best config — so the trace shows
    *which* GEMM site a plan change or a bad recommendation came from.
    """

    def __init__(self, recorder=None) -> None:
        self._scopes: Dict[str, Dict[str, SiteRecord]] = {}
        self._stack: List[str] = []
        self.records: int = 0          # total record() calls (trace events)
        self.recorder = recorder

    # -- scoping -------------------------------------------------------------
    @contextlib.contextmanager
    def scope(self, name: str):
        self._stack.append(name)
        try:
            yield self
        finally:
            self._stack.pop()

    def current_scope(self) -> str:
        return self._stack[-1] if self._stack else "_"

    # -- recording (called by dispatch.gemm at trace time) -------------------
    def record(self, site: str, m: int, k: int, n: int, cfg: TPUTileConfig,
               block_m: int, block_n: int, block_k: int, mode: int,
               backend: str, shard_plan: str = "",
               source: str = "oracle") -> SiteRecord:
        rec = SiteRecord(site, m, k, n, cfg, block_m, block_n, block_k,
                         mode, backend, shard_plan, source)
        scope = self._scopes.setdefault(self.current_scope(), {})
        key = site
        if key in scope and (scope[key].m, scope[key].k, scope[key].n) != \
                (m, k, n):
            # same site traced at a second shape inside one scope (e.g. the
            # encoder and decoder MLP stacks sharing "layer.mlp.*" names)
            key = f"{site}[{m}x{k}x{n}]"
        scope[key] = rec
        self.records += 1
        if self.recorder is not None:
            self._emit(rec)
        return rec

    def _emit(self, rec: SiteRecord) -> None:
        """One ``dispatch`` trace event per recorded site (trace time)."""
        self.recorder.count("dispatch_records")
        if not self.recorder.spans:
            return
        from repro.core.tpu_costmodel import tile_cost_seconds
        costs = tile_cost_seconds(rec.m, rec.k, rec.n)
        self.recorder.instant(
            "dispatch", f"{self.current_scope()}/{rec.site}",
            track="dispatch", site=rec.site, scope=self.current_scope(),
            m=rec.m, k=rec.k, n=rec.n,
            block_m=rec.block_m, block_n=rec.block_n, block_k=rec.block_k,
            mode=DATAFLOW_NAMES[rec.mode], backend=rec.backend,
            source=rec.source,
            cost_s=float(costs[rec.cfg.class_id]),
            cost_best_s=float(costs.min()))

    # -- read-back -----------------------------------------------------------
    def scopes(self) -> Tuple[str, ...]:
        return tuple(self._scopes)

    def sites(self, scope: Optional[str] = None) -> Dict[str, SiteRecord]:
        return dict(self._scopes.get(scope or self.current_scope(), {}))

    def plan(self, scope: Optional[str] = None) -> Dict[str, str]:
        """The executed plan of a traced scope: site -> config description."""
        return {name: rec.describe()
                for name, rec in self._scopes.get(scope or
                                                  self.current_scope(),
                                                  {}).items()}

    def backends(self, scope: Optional[str] = None) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for rec in self._scopes.get(scope or self.current_scope(),
                                    {}).values():
            out[rec.backend] = out.get(rec.backend, 0) + 1
        return out

    def sources(self, scope: Optional[str] = None) -> Dict[str, int]:
        """Recommendation provenance per executed site of a scope."""
        out: Dict[str, int] = {}
        for rec in self._scopes.get(scope or self.current_scope(),
                                    {}).values():
            out[rec.source] = out.get(rec.source, 0) + 1
        return out

    def clear(self) -> None:
        self._scopes.clear()
        self.records = 0
