"""Unified SARA dispatch layer: recommendation -> executed GEMM.

Every dense GEMM site in the model stack calls ``dispatch.gemm(x, w,
site=...)``.  At trace time (shapes are static under jit/vmap) the call:

  1. resolves (M, K, N) -> ``TPUTileConfig`` through the *active*
     ``SaraDispatcher`` (oracle or ADAPTNET mode),
  2. records the site -> executed configuration in the active
     ``SiteRegistry`` (per-trace scope), and
  3. executes through the Pallas RSA kernel (``kernels/ops.rsa_gemm``)
     with the recommended ``block_m/block_n/block_k`` + residency mode,
     or through ``jnp.einsum`` when XLA execution is selected.

Policy is ambient state installed with the ``dispatch.use`` context
manager (this replaces the old mutable ``_GLOBAL`` singleton in
``core/sara.py``)::

    with dispatch.use(dispatcher, execute="pallas"):
        logits = model.logits(params, batch)     # every GEMM -> RSA kernel

``execute="auto"`` (the default policy) compiles the Pallas kernel on TPU
and falls back to XLA elsewhere, so the same call sites run the real
kernel on TPU with no flag plumbing.
"""

from repro.dispatch.context import (DispatchPolicy, active, default_registry,
                                    use)
from repro.dispatch.executor import gemm
from repro.dispatch.registry import SiteRecord, SiteRegistry

__all__ = ["DispatchPolicy", "SiteRecord", "SiteRegistry", "active",
           "default_registry", "gemm", "use"]
