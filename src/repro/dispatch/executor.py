"""The GEMM executor: recommendation -> executed kernel.

``gemm(x, w, site=...)`` is the single seam every dense GEMM in the
model stack goes through.  Shapes are static at trace time, so the
recommendation (a Python-side ``SaraDispatcher.recommend``) and the
backend choice are resolved while tracing and baked into the compiled
executable; the executed configuration is recorded in the active
``SiteRegistry`` under the current scope.

Backends:
  pallas — ``kernels/ops.rsa_gemm`` with the recommended
           block_m/block_n/block_k + residency mode (OS/WS/IS).  Blocks
           are clamped to the 128-aligned operand extent so a 64-wide K
           never pads to a 2048-wide block.  A custom VJP expresses both
           gradient GEMMs (dx = dy @ w^T, dw = x^T @ dy) through the
           same RSA kernel with their own recommended configs, so the
           dispatch layer is load-bearing for training too.
  xla    — ``jnp.einsum`` (+ the recommended mesh-level sharding hint
           when a mesh is active and the policy enables shard_hints).

Expert banks (w of shape (E, K, N) against x (..., E, C, K)) execute as
a vmap of the 2D path over E — the MoE expert GEMMs see the same
recommendation machinery as every other site.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ALIGN = 128                    # MXU tile edge: block clamp granularity


def _round_up(n: int, mult: int) -> int:
    return max(mult, ((int(n) + mult - 1) // mult) * mult)


def _clamped_blocks(cfg, m: int, k: int, n: int) -> Tuple[int, int, int]:
    """Shrink recommended blocks that exceed the 128-aligned operand extent
    (pure padding waste); never grows a block past the recommendation."""
    return (min(cfg.block_m, _round_up(m, ALIGN)),
            min(cfg.block_n, _round_up(n, ALIGN)),
            min(cfg.block_k, _round_up(k, ALIGN)))


def _run_rsa(a, b, tile: Tuple[int, int, int, int],
             interpret: Optional[bool]):
    """tile = (block_m, block_n, block_k, mode)."""
    from repro.kernels import ops
    return ops.rsa_gemm(a, b, block_m=tile[0], block_n=tile[1],
                        block_k=tile[2], mode=tile[3], interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _pallas_gemm2d(x2, w, tile, dx_tile, dw_tile, interpret):
    """(M, K) @ (K, N) through the RSA Pallas kernel, differentiable.
    Each of tile/dx_tile/dw_tile is that GEMM's own recommended
    (block_m, block_n, block_k, mode)."""
    return _run_rsa(x2, w, tile, interpret)


def _pallas_gemm2d_fwd(x2, w, tile, dx_tile, dw_tile, interpret):
    return _run_rsa(x2, w, tile, interpret), (x2, w)


def _pallas_gemm2d_bwd(tile, dx_tile, dw_tile, interpret, res, dy):
    x2, w = res
    dx = _run_rsa(dy, w.T, dx_tile, interpret)
    dw = _run_rsa(x2.T, dy, dw_tile, interpret)
    return dx.astype(x2.dtype), dw.astype(w.dtype)


_pallas_gemm2d.defvjp(_pallas_gemm2d_fwd, _pallas_gemm2d_bwd)


def _resolved_tile(policy, m: int, k: int, n: int):
    """(recommended cfg, executed (bm, bn, bk, mode)) for an (m,k,n) GEMM."""
    cfg = policy.dispatcher.recommend(m, k, n)
    return cfg, _clamped_blocks(cfg, m, k, n) + (cfg.mode,)


def _rec_source(policy, m: int, k: int, n: int) -> str:
    """Provenance of the recommendation just resolved ("oracle" for
    dispatchers that don't track sources, e.g. test fixtures)."""
    src = getattr(policy.dispatcher, "source_of", None)
    return src(m, k, n) if src is not None else "oracle"


def _shard_plan_name(policy, M: int, K: int, N: int
                     ) -> Tuple[str, Optional[object]]:
    """Mesh-level recommendation: ("", None) when meshless, else
    (plan name, ShardPlan).  Recorded always; applied under shard_hints."""
    from repro.parallel.hints import current_mesh
    mesh = current_mesh()
    if mesh is None:
        return "", None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data = sizes.get("data", 1) * sizes.get("pod", 1)
    model = sizes.get("model", 1)
    plan = policy.dispatcher.recommend_sharding(M, K, N, data=data,
                                                model=model)
    return plan.name, plan


def gemm(x: jnp.ndarray, w: jnp.ndarray, *, site: str = "dense",
         backend: Optional[str] = None) -> jnp.ndarray:
    """Self-adaptive GEMM.

    w 2D:  (..., M', K) @ (K, N) -> (..., M', N), M = prod of leading dims.
    w 3D:  expert bank — x (..., E, C, K) @ w (E, K, N) -> (..., E, C, N),
           one GEMM per expert, recommended at M = rows-per-expert.

    ``backend`` pins this site regardless of policy ("xla" for sites whose
    downstream decisions must be bit-stable across backends, e.g. the MoE
    router top-k).
    """
    from repro.dispatch.context import active
    policy = active()
    exec_backend = backend or policy.backend()

    if w.ndim == 3:
        return _gemm_experts(x, w, site, exec_backend, policy)
    if w.ndim != 2:
        raise ValueError(f"gemm weight must be 2D or 3D (expert bank), "
                         f"got {w.shape}")

    M = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
    K, N = int(x.shape[-1]), int(w.shape[-1])
    cfg, tile = _resolved_tile(policy, M, K, N)
    shard_name, shard_plan = _shard_plan_name(policy, M, K, N)
    if policy.registry is not None:
        policy.registry.record(site, M, K, N, cfg, *tile, exec_backend,
                               shard_name, _rec_source(policy, M, K, N))

    if exec_backend == "pallas":
        # the gradient GEMMs carry their own recommendations: dx is an
        # (M,N)x(N,K) GEMM, dw a (K,M)x(M,N) one
        _, dx_tile = _resolved_tile(policy, M, N, K)
        _, dw_tile = _resolved_tile(policy, K, M, N)
        x2 = x.reshape(M, K)
        out = _pallas_gemm2d(x2, w, tile, dx_tile, dw_tile,
                             policy.interpret)
        return out.reshape(x.shape[:-1] + (N,))

    y = jnp.einsum("...k,kn->...n", x, w)
    if policy.shard_hints and shard_plan is not None:
        from repro.parallel.hints import hint
        axes = [None] * y.ndim
        if y.ndim >= 2:
            axes[0] = shard_plan.out_spec[0]
        axes[-1] = shard_plan.out_spec[1]
        y = hint(y, *axes)
    return y


def _gemm_experts(x, w, site: str, exec_backend: str, policy):
    """x: (..., E, C, K) @ w: (E, K, N) -> (..., E, C, N)."""
    E, K, N = (int(s) for s in w.shape)
    if x.ndim < 3 or x.shape[-3] != E or int(x.shape[-1]) != K:
        raise ValueError(f"expert gemm shape mismatch: x {x.shape} vs "
                         f"w {w.shape}")
    C = int(x.shape[-2])
    lead = x.shape[:-3]
    B = int(np.prod(lead)) if lead else 1
    M = B * C                                # rows per expert GEMM
    cfg, tile = _resolved_tile(policy, M, K, N)
    shard_name, _ = _shard_plan_name(policy, M, K, N)
    if policy.registry is not None:
        policy.registry.record(site, M, K, N, cfg, *tile, exec_backend,
                               shard_name, _rec_source(policy, M, K, N))

    if exec_backend == "pallas":
        _, dx_tile = _resolved_tile(policy, M, N, K)
        _, dw_tile = _resolved_tile(policy, K, M, N)
        xe = jnp.moveaxis(x.reshape((B,) + x.shape[-3:]), 1, 0)  # (E,B,C,K)
        xe = xe.reshape(E, M, K)
        out = jax.vmap(lambda a, b: _pallas_gemm2d(
            a, b, tile, dx_tile, dw_tile,
            policy.interpret))(xe, w)                            # (E,M,N)
        out = jnp.moveaxis(out.reshape(E, B, C, N), 0, 1)        # (B,E,C,N)
        return out.reshape(lead + (E, C, N))
    return jnp.einsum("...eck,ekn->...ecn", x, w)
