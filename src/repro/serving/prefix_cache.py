"""Cross-request prefix cache: a page-granular radix trie over token ids.

Most serving traffic at scale shares prompt *prefixes* — system prompts,
few-shot preambles, conversation history.  This index remembers, per
completed prefill, which physical KV pages hold which token-id page
(``block_size`` tokens), keyed by the exact token bytes, so a later
request whose prompt starts with the same tokens can ``share`` those
pages instead of recomputing and rewriting them (the vLLM/SGLang
radix-cache move on top of this repo's refcounted block pool).

Granularity is one pool page: a trie node holds the physical page id for
one ``block_size``-token span, children keyed by the *next* span's token
bytes.  ``match`` walks the longest cached prefix of a prompt;
``insert`` pins a finished request's fully-covered prompt pages into the
trie (pin = cache reference in :class:`~repro.serving.kv_pool.KVBlockPool`
— the page survives table frees and never moves in defrag); ``evict``
drops least-recently-used *leaf* entries whose page no live table still
references, walking leaves-first so an interior page is never orphaned
while a longer cached prefix still needs it.

Correctness leans on one immutability argument: a cached page covers only
rows ``< floor(prompt_len / block_size) * block_size``, and its donor
only ever writes rows ``>= prompt_len`` after insertion (decode appends),
so a pinned page's content is frozen by construction; writers that *do*
touch a shared page (the suffix chunk of a whole-prompt hit) go through
the pool's copy-on-write gate first.
"""

from __future__ import annotations

import heapq
from typing import Dict, Iterable, List, Optional

import numpy as np

from .kv_pool import KVBlockPool


class _Node:
    __slots__ = ("key", "page", "parent", "children", "stamp")

    def __init__(self, key: Optional[bytes], page: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[bytes, "_Node"] = {}
        self.stamp = 0


class PrefixCache:
    """Radix/trie index from token-id prefixes to pinned pool pages."""

    def __init__(self, pool: KVBlockPool, recorder=None):
        self.pool = pool
        self.block_size = pool.block_size
        self.recorder = recorder
        self._root = _Node(None, -1, None)
        self._clock = 0                 # monotone LRU stamp
        self.hits = 0                   # submits that matched >= 1 page
        self.misses = 0
        self.reused_pages = 0           # lifetime pages returned by match
        self.inserted_pages = 0
        self.evicted_pages = 0

    # -- lookup --------------------------------------------------------------
    def _page_keys(self, tokens: np.ndarray, limit: Optional[int] = None):
        n_full = len(tokens) // self.block_size
        if limit is not None:
            n_full = min(n_full, limit)
        for i in range(n_full):
            yield tokens[i * self.block_size:(i + 1) * self.block_size] \
                .astype(np.int32, copy=False).tobytes()

    def match(self, tokens: np.ndarray) -> List[int]:
        """Longest cached prefix of ``tokens``, full pages only.  Returns
        the physical page ids in logical order (possibly empty) and
        touches every node on the path for LRU.  Pure lookup — the
        scheduler calls :meth:`record_lookup` once per *admission*, so a
        request re-tried across steps is not double-counted."""
        node, pages = self._root, []
        for key in self._page_keys(tokens):
            child = node.children.get(key)
            if child is None:
                break
            self._clock += 1
            child.stamp = self._clock
            pages.append(child.page)
            node = child
        return pages

    def record_lookup(self, matched_pages: int) -> None:
        """Account one admission-time lookup in the hit/miss counters."""
        if matched_pages > 0:
            self.hits += 1
            self.reused_pages += matched_pages
        else:
            self.misses += 1

    # -- insert --------------------------------------------------------------
    def insert(self, tokens: np.ndarray, blocks: List[int]) -> int:
        """Index a finished prefill: pin ``blocks[i]`` as the page for the
        i-th full token page of ``tokens``.  Spans already cached keep
        their existing page (the donor's copy — possibly a COW divergence
        of the cached one — is simply not indexed).  Returns the number of
        newly pinned pages."""
        node, added = self._root, 0
        n_full = min(len(tokens) // self.block_size, len(blocks))
        for i, key in enumerate(self._page_keys(tokens, n_full)):
            child = node.children.get(key)
            if child is None:
                page = blocks[i]
                self.pool.pin(page)
                child = _Node(key, page, node)
                node.children[key] = child
                added += 1
            self._clock += 1
            child.stamp = self._clock
            node = child
        self.inserted_pages += added
        if added and self.recorder is not None:
            self.recorder.count("prefix_cache_inserted_pages", added)
        return added

    # -- eviction ------------------------------------------------------------
    def _leaves(self) -> List[_Node]:
        out, stack = [], list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def evict(self, need_pages: int,
              exclude: Optional[Iterable[int]] = None) -> int:
        """Unpin least-recently-used cached prefixes until ``need_pages``
        pool pages have actually been reclaimed (only pages no live table
        references free immediately).  Leaves evict first so interior
        pages are never orphaned.  ``exclude`` names physical pages that
        must survive this call even when otherwise evictable — the
        scheduler passes the pages a just-matched prefix is about to
        ``share``, which no table references yet.  Returns the number of
        pages freed.

        One trie walk collects the candidate leaves into a min-stamp
        heap; evicting a node pushes its parent when that exposes a new
        leaf, so the cost is O(trie + freed * log leaves) per call rather
        than a full rescan per freed page.  Refcounts cannot change while
        this runs (nothing here touches tables), so a candidate skipped
        as referenced or excluded stays skipped."""
        skip = frozenset(exclude) if exclude is not None else frozenset()
        freed = 0
        heap, tie = [], 0
        for leaf in self._leaves():
            heap.append((leaf.stamp, tie, leaf))
            tie += 1
        heapq.heapify(heap)
        while freed < need_pages and heap:
            _, _, node = heapq.heappop(heap)
            if node.page in skip or self.pool.refcount(node.page) != 0:
                continue
            parent = node.parent
            del parent.children[node.key]
            self.pool.unpin(node.page)
            freed += 1
            self.evicted_pages += 1
            if parent is not self._root and not parent.children:
                tie += 1
                heapq.heappush(heap, (parent.stamp, tie, parent))
        if freed and self.recorder is not None:
            self.recorder.count("prefix_cache_evicted_pages", freed)
        return freed

    def clear(self) -> int:
        """Drop every cache entry (unpinning all pages); returns the
        number of entries removed.  Tests and shutdown paths use this to
        return the pool to the fully-free state."""
        dropped = 0
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            self.pool.unpin(n.page)
            dropped += 1
        self._root.children.clear()
        return dropped

    def pages(self) -> List[int]:
        """Every physical page the trie currently pins (one per node).
        The sanitizer's teardown audit compares this against the pool's
        pinned set — they must agree exactly."""
        out, stack = [], list(self._root.children.values())
        while stack:
            n = stack.pop()
            out.append(n.page)
            stack.extend(n.children.values())
        return out

    # -- snapshot / restore ---------------------------------------------------
    def export_state(self) -> Dict:
        """JSON-serializable trie dump for engine snapshots: the nodes in
        parent-before-child order (``parent`` indexes the same list, -1 =
        root), each with its physical page, LRU stamp, and the key span's
        token ids; plus the LRU clock and lifetime counters."""
        nodes: List[Dict] = []
        stack = [(c, -1) for c in self._root.children.values()]
        while stack:
            node, pidx = stack.pop()
            idx = len(nodes)
            nodes.append({
                "parent": pidx,
                "page": int(node.page),
                "stamp": int(node.stamp),
                "key": np.frombuffer(node.key, np.int32).tolist(),
            })
            stack.extend((c, idx) for c in node.children.values())
        return {"nodes": nodes, "clock": int(self._clock),
                "hits": int(self.hits), "misses": int(self.misses),
                "reused_pages": int(self.reused_pages),
                "inserted_pages": int(self.inserted_pages),
                "evicted_pages": int(self.evicted_pages)}

    def restore_state(self, state: Dict) -> int:
        """Rebuild the trie from :meth:`export_state`.  Does NOT touch
        pool pin counts: snapshot restore rebuilds the pool (pins
        included) wholesale from the same checkpoint, so re-pinning here
        would double-count every cached page.  Only valid on an empty
        cache over that restored pool.  Returns the node count."""
        if self._root.children:
            raise ValueError("restore_state needs an empty prefix cache")
        built: List[_Node] = []
        for spec in state["nodes"]:
            parent = (self._root if spec["parent"] < 0
                      else built[spec["parent"]])
            key = np.asarray(spec["key"], np.int32).tobytes()
            node = _Node(key, int(spec["page"]), parent)
            node.stamp = int(spec["stamp"])
            parent.children[key] = node
            built.append(node)
        self._clock = int(state["clock"])
        self.hits = int(state["hits"])
        self.misses = int(state["misses"])
        self.reused_pages = int(state["reused_pages"])
        self.inserted_pages = int(state["inserted_pages"])
        self.evicted_pages = int(state["evicted_pages"])
        return len(built)

    # -- stats ---------------------------------------------------------------
    @property
    def num_entries(self) -> int:
        count, stack = 0, list(self._root.children.values())
        while stack:
            n = stack.pop()
            count += 1
            stack.extend(n.children.values())
        return count

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "prefix_cache_hits": self.hits,
            "prefix_cache_misses": self.misses,
            "prefix_cache_hit_rate": round(self.hit_rate(), 4),
            "prefix_cache_reused_pages": self.reused_pages,
            "prefix_cache_inserted_pages": self.inserted_pages,
            "prefix_cache_evicted_pages": self.evicted_pages,
            "prefix_cache_entries": self.num_entries,
        }
