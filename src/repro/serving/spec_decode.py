"""Speculative decoding over the paged KV arena.

The engine's spec path (``ServingEngine._spec_decode_step``) splits one
decode step into three moves:

1. **Draft** — a cheap draft model proposes up to ``spec_k`` tokens per
   lane.  The draft keeps its own KV in a *second* page arena managed by
   a second :class:`KVBlockPool` (same page economics as the target:
   per-request block tables, alloc/extend/free, preemption when dry).
2. **Verify** — ONE target-model pass checks every lane's pending token
   plus all its drafts through the ragged chunked-prefill kernel
   (``models/serving.paged_verify_step``: C = spec_k + 1 rows per lane,
   logits at every row).  Row ``i`` answers "what would greedy decode
   emit after draft ``i`` tokens?".
3. **Accept** — :func:`accept_tokens` commits the longest draft prefix
   the verify argmax agrees with, plus one corrected token from the
   first disagreeing row (or a bonus extension when all drafts match).

Every committed token is a target verify argmax, so the generated
sequence is bitwise-identical to plain greedy decode — speculation only
changes how many tokens commit per step.  Rejected drafts need no
physical rollback on either arena: per-lane lengths simply don't advance
over the rejected rows, and the next step's writes land at the same kv
positions (the stale-row contract stalled lanes already rely on).
Where target pages are shared with the prefix cache the engine COW-gates
the verify rows first; draft pages are never shared with anything.

Draft-KV bookkeeping
--------------------
``_rows[rid]`` counts the draft-arena rows that hold the request's real
context (``req.context()`` tokens).  Drafting "catches up" any gap
below ``L - 1`` by streaming ``context[rows:L-1]`` through draft
prefill chunks, then ONE fused ``lax.scan`` kernel feeds every lane's
pending token and greedily feeds each round's argmax back for ``k``
rounds — all k draft tokens come out of a single dispatch instead of k
host round-trips (per-lane round counts are masked inside the scan, so
the kernel compiles once at ``spec_k`` rounds).  After a commit of
``c`` tokens the engine calls :meth:`SpecDecoder.commit` with the new
target row count ``L + c - 1``: accepted draft rows are already correct
in the draft arena, so the steady-state catch-up is empty and a spec
step costs exactly two dispatches (draft scan + verify).  This one
mechanism uniformly covers fresh admissions (full-context catch-up),
post-rejection divergence, aborted steps (the engine's fault boundary
re-runs the step; :meth:`draft` re-derives the pending row), and
readmission after preemption (:meth:`release` drops the rows).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.obs import JitWatch
from repro.serving.kv_pool import KVArena, KVBlockPool, PoolError


def accept_tokens(drafts: Sequence[int],
                  verify_argmax: Sequence[int]) -> Tuple[int, List[int]]:
    """The accept rule: longest matching draft prefix plus one corrected
    token.

    ``drafts`` are the k proposed tokens; ``verify_argmax`` are the
    target's greedy picks at the k+1 verify rows (row ``i`` conditions
    on the pending token plus drafts ``< i``).  Returns ``(a,
    committed)`` where ``a`` is the number of accepted draft tokens and
    ``committed == verify_argmax[:a + 1]`` — in the accepted region the
    argmax equals the draft by construction, and entry ``a`` is the
    target's correction (all-accept: the free "bonus" extension token).
    The caller commits ``committed`` in order, stopping early on EOS.
    """
    a = 0
    while a < len(drafts) and int(drafts[a]) == int(verify_argmax[a]):
        a += 1
    return a, [int(t) for t in verify_argmax[:a + 1]]


def resolve_draft(cfg: ArchConfig, params, name: str, seed: int):
    """Resolve ``EngineConfig.spec_draft`` to ``(draft_cfg,
    draft_params)``.

    ``"self"`` shares the target's config AND params (self-speculation:
    the draft always agrees with the verifier, so acceptance is ~100% —
    the upper bound, used by the benchmark to isolate engine overheads).
    Any other value names a registry arch; it is reduced when the target
    is a reduced config so both sides stay CPU-test sized, shares params
    when it resolves to the target's exact config, and otherwise
    initializes fresh draft params from a different seed (a genuinely
    disagreeing draft — what the partial-accept tests use)."""
    from repro.models.api import build_model
    from repro.models.serving import CHUNKED_PREFILL_FAMILIES

    if name == "self":
        return cfg, params
    from repro.configs.registry import get_arch
    draft = get_arch(name)
    if cfg.name.endswith("-reduced"):
        draft = draft.reduced()
    if draft.family not in CHUNKED_PREFILL_FAMILIES:
        raise ValueError(
            f"spec_draft {name!r} has family {draft.family!r}; the draft "
            f"runs the chunked paged path, which supports "
            f"{CHUNKED_PREFILL_FAMILIES}")
    if draft.vocab_size != cfg.vocab_size:
        raise ValueError(
            f"spec_draft {name!r} vocab {draft.vocab_size} != target "
            f"vocab {cfg.vocab_size}: draft tokens must be target tokens")
    if draft == cfg:
        return draft, params
    return draft, build_model(draft).init(jax.random.PRNGKey(seed + 2))


class SpecDecoder:
    """Owns the draft side of speculative decoding: the draft model, its
    page pool + arena, and the per-request draft row counts.

    The engine drives it with :meth:`draft` (inside its ``spec_draft``
    dispatch scope), then :meth:`commit` per lane after acceptance, and
    :meth:`release` whenever a request leaves its slot (retire, terminal
    failure, preemption) so draft pages never outlive target pages."""

    def __init__(self, draft_cfg: ArchConfig, draft_params, *,
                 num_slots: int, block_size: int, num_blocks: int,
                 max_blocks_per_slot: int, chunk: int, spec_k: int,
                 recorder=None):
        from repro.models.api import build_model

        self.cfg = draft_cfg
        self.model = build_model(draft_cfg)
        self.params = draft_params
        self.num_slots = num_slots
        self.chunk = max(1, int(chunk))
        self.spec_k = max(1, int(spec_k))
        self._max_blocks = max_blocks_per_slot
        # same pool economics as the target arena: per-request tables,
        # +1 trailing write-discard page for masked rows.  The sanitizer
        # stays off — draft pages are private (never shared, pinned, or
        # reachable from the prefix cache) and draft logits never become
        # output tokens, only proposals the verify pass re-derives.
        self.pool = KVBlockPool(num_blocks, block_size)
        self.arena = KVArena(
            self.model.init_paged_arena(num_blocks + 1, block_size),
            block_size)
        self.pool.bind_arena(self.arena)
        if recorder is not None:
            self.pool.attach_recorder(recorder)
        self._state = self.model.init_paged_state(num_slots)
        self._rows: Dict[str, int] = {}
        self._draft_prefill = JitWatch(
            jax.jit(self.model.paged_prefill_step), "spec_draft_prefill",
            recorder)

        # all k draft rounds fused into one dispatch: feed each round's
        # greedy argmax back inside a lax.scan, so drafting costs one
        # host round-trip regardless of k.  Round i writes a lane's KV
        # only while i < nwrites[lane] (per-lane k, masked like stalled
        # lanes in plain decode), which keeps the compiled shape fixed
        # at spec_k rounds.
        def _loop(params, first, state, leaves, tables, kv, nwrites):
            def body(carry, i):
                feed, lv, pos = carry
                wm = (i < nwrites).astype(jnp.int32)
                logits, lv = self.model.paged_decode_step(
                    params, feed, state, lv, tables, pos, wm)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (nxt[:, None], lv, pos + wm), nxt
            (_, leaves, _), toks = jax.lax.scan(
                body, (first, leaves, kv), jnp.arange(self.spec_k))
            return toks, leaves      # toks: (spec_k, S)

        self._draft_loop = JitWatch(jax.jit(_loop), "spec_draft_loop",
                                    recorder)

    # -- lifecycle ------------------------------------------------------------
    def rows(self, rid: str) -> int:
        """Draft-arena rows currently holding ``rid``'s real context."""
        return self._rows.get(rid, 0)

    def commit(self, rid: str, rows: int) -> None:
        """Record the post-accept draft row count (== the target's new kv
        rows: context minus the new pending token).  Accepted draft rows
        already hold the committed tokens; everything past ``rows`` is
        rejected garbage the next catch-up overwrites in place."""
        self._rows[rid] = int(rows)

    def release(self, rid: str) -> None:
        """Drop ``rid``'s draft pages and row count (request retired /
        failed / preempted, or draft-lane preemption under pool
        pressure).  Safe to call for requests that never drafted."""
        if rid in self.pool.live_requests():
            self.pool.free(rid)
        self._rows.pop(rid, None)

    def live_pages(self) -> int:
        return self.pool.num_in_use

    def check(self) -> None:
        self.pool.check()

    # -- drafting -------------------------------------------------------------
    def _reserve(self, rid: str, num_tokens: int) -> None:
        if rid in self.pool.live_requests():
            table = self.pool.table(rid)
            if table.capacity(self.pool.block_size) >= num_tokens:
                table.num_tokens = max(table.num_tokens, num_tokens)
                return
            self.pool.extend(rid, num_tokens)
        else:
            self.pool.alloc(rid, num_tokens)

    def draft(self, lanes: Dict[int, Tuple[object, int]]
              ) -> Tuple[Dict[int, List[int]], int]:
        """Propose draft tokens for ``lanes`` (slot -> (request, k)).

        Returns ``(drafts, preempts)``: ``drafts[slot]`` is the lane's k
        proposed tokens; a lane whose draft-page reservation failed is
        *draft-preempted* — its pages free immediately (making room for
        the other lanes), it is absent from ``drafts`` (the engine runs
        it as a plain C=1 verify this step), and it re-catches-up in
        full once the draft pool can hold it again.

        Catch-up chunks and the fused draft scan are both batched across
        all drafting lanes at fixed shapes (chunk width, table width,
        spec_k rounds), so the draft side compiles once like the
        target's chunked prefill — and a steady-state step (no catch-up
        gap) is a single draft dispatch.
        """
        S, C = self.num_slots, self.chunk
        preempts = 0
        jobs: Dict[int, List] = {}     # slot -> [req, k, pos]
        for slot, (req, k) in sorted(lanes.items()):
            if k <= 0:
                continue
            L = req.context_len
            # rows beyond L-1 may hold rejected drafts from an earlier
            # (possibly aborted) step; the scan re-feeds the pending row
            # so round one always yields this step's d_1
            pos = min(self._rows.get(req.rid, 0), L - 1)
            try:
                self._reserve(req.rid, L + k - 1)
            except PoolError:
                self.release(req.rid)
                preempts += 1
                continue
            jobs[slot] = [req, k, pos]
        if not jobs:
            return {}, preempts

        # catch-up: stream context[pos:L-1] through ragged prefill
        # chunks.  Steady-state lanes (pos == L-1 after a commit) skip
        # this entirely — their only unwritten row is the pending token,
        # which the scan's first round writes.
        while any(j[2] < j[0].context_len - 1 for j in jobs.values()):
            toks = np.zeros((S, C), np.int32)
            chunk = np.zeros((S,), np.int32)
            kv = np.zeros((S,), np.int32)
            for slot, (req, k, pos) in sorted(jobs.items()):
                n = min(C, req.context_len - 1 - pos)
                if n <= 0:
                    continue
                toks[slot, :n] = req.context()[pos:pos + n]
                chunk[slot] = n
                kv[slot] = pos
            rids = [jobs[s][0].rid if s in jobs and chunk[s] > 0 else None
                    for s in range(S)]
            tables = self.pool.dense_block_table(rids, self._max_blocks)
            # saralint: ok[cow-gate] draft arena pages are private per request (never shared, pinned, or reachable from the prefix cache)
            _, leaves = self._draft_prefill(
                self.params, jnp.asarray(toks), self.arena.leaves,
                jnp.asarray(tables), jnp.asarray(kv), jnp.asarray(chunk))
            self.arena.leaves = jax.block_until_ready(leaves)
            for slot in sorted(jobs):
                jobs[slot][2] += int(chunk[slot])

        # fused draft rounds: every lane feeds its pending token at row
        # L-1 and the scan greedily extends k rounds in one dispatch;
        # lanes needing fewer rounds stop writing via nwrites masking
        # (their later outputs are garbage the slicing below drops)
        first = np.zeros((S, 1), np.int32)
        kv = np.zeros((S,), np.int32)
        nwrites = np.zeros((S,), np.int32)
        for slot, (req, k, pos) in sorted(jobs.items()):
            first[slot, 0] = req.context()[req.context_len - 1]
            kv[slot] = req.context_len - 1
            nwrites[slot] = k
        rids = [jobs[s][0].rid if s in jobs else None for s in range(S)]
        tables = self.pool.dense_block_table(rids, self._max_blocks)
        # saralint: ok[cow-gate] draft arena pages are private per request (never shared, pinned, or reachable from the prefix cache)
        toks, leaves = self._draft_loop(
            self.params, jnp.asarray(first), self._state,
            self.arena.leaves, jnp.asarray(tables), jnp.asarray(kv),
            jnp.asarray(nwrites))
        toks, leaves = jax.block_until_ready((toks, leaves))
        self.arena.leaves = leaves
        toks = np.asarray(toks)
        drafts = {slot: [int(t) for t in toks[:jobs[slot][1], slot]]
                  for slot in jobs}
        for slot, (req, k, pos) in jobs.items():
            self._rows[req.rid] = req.context_len - 1 + k
        return drafts, preempts
