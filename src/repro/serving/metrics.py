"""Serving telemetry: TTFT, per-request latency percentiles, decode
throughput, slot utilization, SARA recommendation-cache hit rate, and
executed-GEMM dispatch stats (plan reconfigurations, sites per backend).

All timestamps are whatever clock the engine passes in (wall seconds for
live serving, virtual step time for simulated traces) — the math only needs
them to be consistent.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


def percentile(xs, q: float) -> Optional[float]:
    """Percentile of a sample list, or ``None`` when there are no samples.

    ``None`` (not 0.0) is load-bearing: a run where no request ever
    completed must not report a perfect p99 — "no measurement" and "a
    measured zero" are different facts, and the old 0.0 silently
    conflated them."""
    if not len(xs):
        return None
    return float(np.percentile(np.asarray(xs, np.float64), q))


@dataclass
class ServingMetrics:
    ttft: List[float] = field(default_factory=list)         # first token - arrival
    latency: List[float] = field(default_factory=list)      # done - arrival
    queue_delay: List[float] = field(default_factory=list)  # admit - arrival
    decode_steps: int = 0
    decode_tokens: int = 0
    decode_s: float = 0.0
    prefill_tokens: int = 0
    prefill_s: float = 0.0
    slot_occupancy: List[float] = field(default_factory=list)  # active/slots per step
    completed: int = 0
    stalls: int = 0
    preemptions: int = 0
    # terminal failure outcomes (serving/faults.py): requests that left
    # the system without completing, by cause — plus the goodput twin of
    # ``completed``: completions that also met their deadline (what the
    # chaos benchmark reports as in-deadline completions/s)
    failed: int = 0
    expired: int = 0
    shed: int = 0
    cancelled: int = 0
    rejected: int = 0
    completed_in_deadline: int = 0
    # scheduler.plan() gave up a matched prefix under pool pressure and
    # re-admitted as a cache miss — a silent-fallback storm signal
    prefix_cache_fallbacks: int = 0
    # KV rows actually streamed by decode vs what a masked-dense decode
    # over full slot capacity would stream (the paged-arena win)
    kv_read_tokens: int = 0
    kv_read_tokens_dense: int = 0
    # KV rows prefill actually wrote into pages vs the padded-bucket
    # equivalent (the chunked-prefill win: writes scale with real prompt
    # tokens, not bucket shapes)
    prefill_kv_write_rows: int = 0
    prefill_kv_write_rows_padded: int = 0
    # Cross-request prefix cache (serving/prefix_cache.py): prompt tokens /
    # pages an admission mapped from cached pages instead of recomputing,
    # and the analytic prefill FLOPs that avoided (per-token GEMM cost
    # summed over the model's sites at M=1)
    cache_hit_tokens: int = 0
    cache_hit_pages: int = 0
    prefill_flops_saved: float = 0.0
    # Speculative decoding (serving/spec_decode.py): verify steps taken,
    # draft tokens proposed/accepted, bonus tokens committed from the
    # verify argmax, and draft-pool preemptions (draft arena dry -> the
    # lane fell back to a plain C=1 verify that step)
    spec_steps: int = 0
    spec_drafted: int = 0
    spec_accepted: int = 0
    spec_bonus: int = 0
    spec_draft_preempts: int = 0
    # Rolling windows (last ``rolling_window`` samples) so a long run's
    # summary reports live behaviour, not lifetime averages: a regression
    # an hour in is invisible in a lifetime p99 but jumps out of a
    # 64-sample one.
    rolling_window: int = 64
    _ttft_win: deque = field(default_factory=lambda: deque(maxlen=64))
    _latency_win: deque = field(default_factory=lambda: deque(maxlen=64))
    _decode_win: deque = field(default_factory=lambda: deque(maxlen=64))

    def __post_init__(self) -> None:
        if self.rolling_window != 64:
            self._ttft_win = deque(maxlen=self.rolling_window)
            self._latency_win = deque(maxlen=self.rolling_window)
            self._decode_win = deque(maxlen=self.rolling_window)

    # -- recording ------------------------------------------------------------
    def on_first_token(self, arrival: float, t: float) -> None:
        self.ttft.append(t - arrival)
        self._ttft_win.append(t - arrival)

    def on_retire(self, arrival: float, admit: float, t: float,
                  in_deadline: bool = True) -> None:
        self.latency.append(t - arrival)
        self._latency_win.append(t - arrival)
        self.queue_delay.append(admit - arrival)
        self.completed += 1
        if in_deadline:
            self.completed_in_deadline += 1

    def on_finish(self, outcome: str) -> None:
        """One request left the system on a terminal failure outcome
        (``failed`` / ``expired`` / ``shed`` / ``cancelled`` /
        ``rejected`` — see ``serving/faults.py``)."""
        if outcome == "failed":
            self.failed += 1
        elif outcome == "expired":
            self.expired += 1
        elif outcome == "shed":
            self.shed += 1
        elif outcome == "cancelled":
            self.cancelled += 1
        elif outcome == "rejected":
            self.rejected += 1
        else:
            raise ValueError(f"unknown terminal outcome {outcome!r}")

    def ttft_estimate(self) -> Optional[float]:
        """Estimated queue-to-first-token delay for an arriving request:
        the rolling-window TTFT median (live behaviour, not lifetime).
        ``None`` until a first token has been produced — admission
        control must not shed on a guess."""
        return percentile(self._ttft_win, 50)

    def on_prefill(self, tokens: int, seconds: float,
                   kv_write_rows: int = 0,
                   kv_write_rows_padded: int = 0) -> None:
        """One prefill call (a whole padded bucket, or one chunk batch).
        ``kv_write_rows`` counts KV rows committed to the paged arena;
        ``kv_write_rows_padded`` is what the padded-bucket path streams for
        the same work (bucket-shape rows per request)."""
        self.prefill_tokens += tokens
        self.prefill_s += seconds
        self.prefill_kv_write_rows += kv_write_rows
        self.prefill_kv_write_rows_padded += kv_write_rows_padded

    def on_cache_hit(self, tokens: int, pages: int,
                     flops_per_token: float = 0.0) -> None:
        """One admission that matched a cached prefix: ``tokens`` context
        tokens arrived pre-written in ``pages`` shared pages."""
        self.cache_hit_tokens += tokens
        self.cache_hit_pages += pages
        self.prefill_flops_saved += tokens * flops_per_token

    def on_spec_step(self, lanes: int, drafted: int, accepted: int,
                     bonus: int, preempts: int = 0) -> None:
        """One engine step that went through the speculative verify path.
        ``drafted`` counts draft tokens proposed across all ``lanes``,
        ``accepted`` the subset the target's verify pass kept, ``bonus``
        the corrected/extension tokens committed from the verify argmax
        (one per non-stalled lane).  Committed tokens are reported
        separately through :meth:`on_decode_step` so ``decode_tok_s``
        stays comparable with plain decode."""
        self.spec_steps += 1
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_bonus += bonus
        self.spec_draft_preempts += preempts

    def on_decode_step(self, active: int, slots: int, tokens: int,
                       seconds: float, kv_read_tokens: int = 0,
                       kv_read_tokens_dense: int = 0) -> None:
        self.decode_steps += 1
        self.decode_tokens += tokens
        self.decode_s += seconds
        self._decode_win.append((tokens, seconds))
        self.slot_occupancy.append(active / slots if slots else 0.0)
        self.kv_read_tokens += kv_read_tokens
        self.kv_read_tokens_dense += kv_read_tokens_dense

    # -- summary --------------------------------------------------------------
    def summary(self, sara_cache: Dict = None,
                dispatch: Dict = None) -> Dict[str, float]:
        """Lifetime aggregates + ``*_roll`` rolling-window twins.

        Percentile keys are ``None`` when no sample exists (e.g. a run
        where nothing completed) — callers that format or compare must
        treat ``None`` as "not measured", never as zero."""
        win_tok = sum(t for t, _ in self._decode_win)
        win_s = sum(s for _, s in self._decode_win)
        out = {
            "completed": self.completed,
            "completed_in_deadline": self.completed_in_deadline,
            "requests_failed": self.failed,
            "requests_expired": self.expired,
            "requests_shed": self.shed,
            "requests_cancelled": self.cancelled,
            "requests_rejected": self.rejected,
            "prefix_cache_fallbacks": self.prefix_cache_fallbacks,
            "decode_steps": self.decode_steps,
            "ttft_p50_s": percentile(self.ttft, 50),
            "ttft_p99_s": percentile(self.ttft, 99),
            "latency_p50_s": percentile(self.latency, 50),
            "latency_p99_s": percentile(self.latency, 99),
            "queue_delay_p50_s": percentile(self.queue_delay, 50),
            # rolling-window (last rolling_window samples) live behaviour
            "ttft_p50_s_roll": percentile(self._ttft_win, 50),
            "ttft_p99_s_roll": percentile(self._ttft_win, 99),
            "latency_p99_s_roll": percentile(self._latency_win, 99),
            "decode_tok_s_roll": (win_tok / max(win_s, 1e-9)
                                  if self._decode_win else None),
            "decode_tok_s": self.decode_tokens / max(self.decode_s, 1e-9),
            "prefill_tok_s": self.prefill_tokens / max(self.prefill_s, 1e-9),
            "slot_utilization": (float(np.mean(self.slot_occupancy))
                                 if self.slot_occupancy else 0.0),
            "stalls": self.stalls,
            "preemptions": self.preemptions,
            "kv_read_tokens_per_step": (self.kv_read_tokens
                                        / max(self.decode_steps, 1)),
            "kv_read_tokens_dense_per_step": (self.kv_read_tokens_dense
                                              / max(self.decode_steps, 1)),
            # neutral 1.0 when no KV rows were measured (recurrent-state
            # families) instead of a misleading 0x "reduction"
            "kv_read_reduction_x": (self.kv_read_tokens_dense
                                    / max(self.kv_read_tokens, 1)
                                    if self.kv_read_tokens_dense else 1.0),
            "prefill_kv_write_rows": self.prefill_kv_write_rows,
            "prefill_kv_write_rows_padded": self.prefill_kv_write_rows_padded,
            "prefill_kv_write_reduction_x": (
                self.prefill_kv_write_rows_padded
                / max(self.prefill_kv_write_rows, 1)
                if self.prefill_kv_write_rows_padded else 1.0),
            "cache_hit_tokens": self.cache_hit_tokens,
            "cache_hit_pages": self.cache_hit_pages,
            "prefill_flops_saved": self.prefill_flops_saved,
            "spec_steps": self.spec_steps,
            "spec_drafted_tokens": self.spec_drafted,
            "spec_accepted_tokens": self.spec_accepted,
            "spec_bonus_tokens": self.spec_bonus,
            "spec_draft_preempts": self.spec_draft_preempts,
            "spec_accept_rate": (self.spec_accepted / self.spec_drafted
                                 if self.spec_drafted else None),
            "spec_accepted_per_step": ((self.spec_accepted + self.spec_bonus)
                                       / self.spec_steps
                                       if self.spec_steps else None),
        }
        if sara_cache:
            hits = sara_cache.get("hits", 0)
            total = hits + sara_cache.get("misses", 0)
            out["sara_cache_hit_rate"] = hits / total if total else 0.0
            out["sara_cache_size"] = sara_cache.get("size", 0)
        if dispatch:
            out.update(dispatch)        # executed-plan stats from the engine
        return out

    def report(self, sara_cache: Dict = None, dispatch: Dict = None) -> str:
        s = self.summary(sara_cache, dispatch)
        def fmt(v):
            if v is None:
                return "n/a (no samples)"
            return f"{v:.4g}" if isinstance(v, float) else str(v)
        return "\n".join(f"  {k:<22} {fmt(v)}" for k, v in s.items())
