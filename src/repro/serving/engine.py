"""ServingEngine: continuous-batching inference over the repro model stack.

Execution model
---------------
* ``num_slots`` fixed decode lanes.  Each slot owns a batch=1 cache pytree
  (``models/serving.py``); the engine stacks them on a leading slot axis and
  decodes every step with one ``jit(vmap(decode_step))`` — per-slot scalar
  positions/lengths become per-lane under vmap, so heterogeneous sequence
  lengths coexist in one batched step with no model changes.
* Prefill runs per admitted request at a small set of padded *bucket*
  shapes (one XLA compilation per bucket): the prompt is right-padded and
  the true ``length`` is passed as a traced scalar, which
  ``serving.prefill`` uses to pick the real last-token logits and correct
  the cache lengths.  SSM/hybrid families use exact-length prefill (their
  recurrent state integrates every input token).
* Every GEMM the model runs goes through the SARA dispatch layer
  (``repro.dispatch``): each prefill/decode entry point traces under a
  named registry scope with this engine's dispatcher active, so the tile
  configuration every site *executes* with (RSA Pallas blocks + residency
  mode under ``execute="pallas"``/on-TPU ``"auto"``; XLA otherwise) is
  recorded per trace.  ``gemm_plan`` is read back from that registry —
  the executed plan, not an advisory estimate — and ``plan_changes``
  counts real reconfigurations (steps whose executed plan differs from
  the previous step's).  ``SaraDispatcher.cache_info()`` feeds the
  recommendation-cache hit rate into the metrics.
* ``EngineConfig.dispatcher_mode`` selects the recommendation source:
  ``"oracle"`` (exhaustive analytic search) or ``"adaptnet"`` (a trained
  ADAPTNET-TPU loaded from ``adaptnet_dir`` — the paper's self-adaptive
  runtime path; shapes outside its trained range fall back to the
  oracle, and per-source site counts land in ``dispatch_stats()``).
* The ``KVBlockPool`` meters admission over *text* tokens (the vlm
  frontend adds a constant per-slot overhead outside the budget).
  ``reserve="full"`` can never stall; ``reserve="incremental"`` packs
  denser: a lane whose block-table extension fails is rolled back to its
  pre-step cache and stalls until blocks free up, and if every lane stalls
  the newest request is preempted (recompute-on-readmit: it re-enters the
  queue and re-prefills prompt+generated at its next admission).

The clock is either ``"wall"`` (live serving) or ``"steps"`` (virtual time
in engine-step units — deterministic, used by tests and trace benchmarks).
"""

from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import dispatch
from repro.configs.base import ArchConfig
from repro.core.sara import SaraDispatcher
from repro.dispatch import SiteRegistry
from repro.serving.kv_pool import KVBlockPool
from repro.serving.metrics import ServingMetrics
from repro.serving.scheduler import ContinuousScheduler, Request


def sample_logits(key, logits: jnp.ndarray, temperature: float = 1.0,
                  top_k: int = 0) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32.  temperature<=0 is greedy argmax;
    top_k>0 masks everything below the k-th logit before sampling."""
    if temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        thresh = vals[:, -1:]
        logits = jnp.where(logits < thresh, -1e30, logits)
    return jax.random.categorical(key, logits, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# GEMM-site enumeration (analytic estimate — benchmarks/capacity planning).
# The engine itself no longer consults this: its gemm_plan is read back
# from the dispatch registry, i.e. from the sites that actually traced.
# ---------------------------------------------------------------------------

def gemm_sites(cfg: ArchConfig, m_tokens: int) -> List[Tuple[str, int, int, int]]:
    """The (M, K, N) of each distinct GEMM the model runs on ``m_tokens``
    rows this step (MoE expert GEMMs use the expected routed-row count)."""
    m = max(int(m_tokens), 1)
    d = cfg.d_model
    sites: List[Tuple[str, int, int, int]] = []
    if cfg.attention_type == "gqa":
        sites += [("attn_qkv", m, d, cfg.q_dim + 2 * cfg.kv_dim),
                  ("attn_out", m, cfg.q_dim, d)]
    elif cfg.attention_type == "mla":
        a = cfg.mla
        sites += [("mla_down", m, d,
                   a.q_lora_rank + a.kv_lora_rank + a.qk_rope_head_dim),
                  ("mla_out", m, cfg.num_heads * a.v_head_dim, d)]
    if cfg.moe is not None:
        sites += [("moe_expert",
                   max(m * cfg.moe.experts_per_token, 1), d,
                   2 * cfg.moe.d_ff_expert),
                  ("moe_router", m, d, cfg.moe.num_experts)]
    else:
        sites += [("mlp_up", m, d, 2 * cfg.d_ff),
                  ("mlp_down", m, cfg.d_ff, d)]
    if cfg.ssm is not None:
        sites += [("ssm_proj", m, d, 2 * cfg.ssm.expand * d)]
    sites += [("lm_head", m, d, cfg.vocab_size)]
    return sites


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@dataclass
class EngineConfig:
    num_slots: int = 4
    max_len: int = 96                 # per-slot token capacity (prompt+gen+1)
    block_size: int = 16              # KV pool page size (tokens)
    num_blocks: Optional[int] = None  # KV budget; None = full slot capacity
    buckets: Optional[Sequence[int]] = None   # prefill shapes; None = pow2
    max_prefills_per_step: int = 1
    reserve: str = "full"             # "full" | "incremental"
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    eos_id: Optional[int] = None
    clock: str = "steps"              # "steps" | "wall"
    src_len: int = 0                  # encdec: shared encoder length
    execute: str = "auto"             # GEMM backend: "pallas"|"xla"|"auto"
    dispatcher_mode: str = "oracle"   # recommendation source: "oracle"|"adaptnet"
    adaptnet_dir: Optional[str] = None  # trained ADAPTNET-TPU checkpoint dir


class ServingEngine:
    def __init__(self, cfg: ArchConfig, engine: EngineConfig = None,
                 params=None, dispatcher: Optional[SaraDispatcher] = None):
        from repro.models.api import build_model

        self.cfg = cfg
        self.ecfg = engine or EngineConfig()
        self.model = build_model(cfg)
        self.params = params if params is not None \
            else self.model.init(jax.random.PRNGKey(self.ecfg.seed))
        self.dispatcher = dispatcher if dispatcher is not None \
            else self._build_dispatcher(self.ecfg)
        self.metrics = ServingMetrics()

        e = self.ecfg
        blocks_per_slot = -(-e.max_len // e.block_size)
        num_blocks = (e.num_blocks if e.num_blocks is not None
                      else e.num_slots * blocks_per_slot)
        self.pool = KVBlockPool(num_blocks, e.block_size)
        self.sched = ContinuousScheduler(
            e.num_slots, self.pool,
            max_prefills_per_step=e.max_prefills_per_step, reserve=e.reserve)

        # stacked per-slot caches: leading axis = slot, each lane batch=1
        self._cache_len = e.max_len + (cfg.frontend.num_tokens
                                       if cfg.family == "vlm" else 0)
        proto = self.model.init_cache(1, self._cache_len, src_len=e.src_len)
        self._cache = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a, (e.num_slots,) + a.shape).copy(), proto)
        self._last_tok = np.zeros((e.num_slots, 1), np.int32)

        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(jax.vmap(self.model.decode_step,
                                        in_axes=(None, 0, 0)))
        self._key = jax.random.PRNGKey(e.seed + 1)
        self._vtime = 0.0
        self._t0 = time.time()
        # registry-backed executed-plan bookkeeping: each traced entry point
        # (one per prefill bucket + one for the vmapped decode) records its
        # sites under a scope; _dispatch() reads the plan back (memoized per
        # scope) instead of re-running any recommendation sweep.
        self.registry = SiteRegistry()
        self.gemm_plan: Dict[str, str] = {}
        self.plan_changes = 0
        self._plan_memo: Dict[str, Dict[str, str]] = {}

    @staticmethod
    def _build_dispatcher(ecfg: EngineConfig) -> SaraDispatcher:
        if ecfg.dispatcher_mode == "adaptnet":
            if not ecfg.adaptnet_dir:
                raise ValueError(
                    "dispatcher_mode='adaptnet' needs adaptnet_dir: a "
                    "checkpoint saved by `python -m repro.launch."
                    "train_adaptnet --out <dir>`")
            return SaraDispatcher.from_checkpoint(ecfg.adaptnet_dir)
        if ecfg.dispatcher_mode != "oracle":
            raise ValueError(f"unknown dispatcher_mode "
                             f"{ecfg.dispatcher_mode!r}")
        return SaraDispatcher()

    # -- time -----------------------------------------------------------------
    def now(self) -> float:
        if self.ecfg.clock == "steps":
            return self._vtime
        return time.time() - self._t0

    # -- SARA dispatch --------------------------------------------------------
    @contextlib.contextmanager
    def _dispatch_scope(self, scope: str):
        """Install this engine's dispatch policy + registry scope around a
        jitted call: if the call traces (first time this shape is seen),
        every GEMM site records its executed configuration under ``scope``."""
        with dispatch.use(self.dispatcher, execute=self.ecfg.execute,
                          registry=self.registry), \
                self.registry.scope(scope):
            yield

    def _dispatch(self, scope: str) -> None:
        """Adopt the executed plan of ``scope`` (memoized per scope — the
        scope name encodes the token count, so an unchanged batch shape is
        a dict lookup, not a recommendation sweep)."""
        plan = self._plan_memo.get(scope)
        if plan is None:
            plan = self.registry.plan(scope)
            self._plan_memo[scope] = plan
        if plan != self.gemm_plan:
            self.plan_changes += 1       # a real reconfiguration
            self.gemm_plan = plan

    # -- buckets --------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        if self.cfg.family in ("ssm", "hybrid"):
            return n                   # recurrent state: no padded prefill
        b = None
        if self.ecfg.buckets:
            fits = [x for x in sorted(self.ecfg.buckets) if x >= n]
            if fits:
                b = fits[0]
        if b is None:
            b = 16
            while b < n:
                b *= 2
        # prefill writes `bucket` KV rows, so never pad past the slot arena
        # (submit() guarantees n itself fits)
        return max(n, min(b, self.ecfg.max_len))

    # -- request lifecycle ----------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be >= 1 "
                             "(prefill always yields the first token)")
        need = req.prompt_len + req.max_new_tokens + 1
        if need > self.ecfg.max_len:
            raise ValueError(f"request {req.rid} needs {need} tokens > "
                             f"max_len {self.ecfg.max_len}")
        if self.pool.blocks_for(need) > self.pool.num_blocks:
            raise ValueError(
                f"request {req.rid} needs {self.pool.blocks_for(need)} KV "
                f"blocks > pool total {self.pool.num_blocks}; it could never "
                "be admitted")
        if req.eos_id is None:
            req.eos_id = self.ecfg.eos_id
        self.sched.submit(req)

    def _slot_snapshot(self, slot: int):
        return jax.tree_util.tree_map(lambda a: a[slot], self._cache)

    def _slot_restore(self, slot: int, snap) -> None:
        self._cache = jax.tree_util.tree_map(
            lambda big, one: big.at[slot].set(one), self._cache, snap)

    def _do_prefill(self, req: Request) -> None:
        e, cfg = self.ecfg, self.cfg
        context = np.concatenate(
            [req.prompt, np.asarray(req.generated, np.int32)]) \
            if req.generated else req.prompt
        n = int(context.shape[0])
        bucket = self.bucket_for(n)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = context
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.asarray(
                (req.extras or {}).get(
                    "patch_embeds",
                    np.zeros((1, cfg.frontend.num_tokens,
                              cfg.frontend.feature_dim), np.float32)),
                jnp.dtype(cfg.compute_dtype))
        if cfg.family == "encdec":
            batch["src_features"] = jnp.asarray(
                (req.extras or {}).get(
                    "src_features",
                    np.zeros((1, e.src_len, cfg.frontend.feature_dim),
                             np.float32)),
                jnp.dtype(cfg.compute_dtype))

        scope = f"prefill:m{bucket}"
        fresh = self.model.init_cache(1, self._cache_len, src_len=e.src_len)
        t0 = time.time()
        with self._dispatch_scope(scope):
            logits, new_cache = jax.block_until_ready(self._prefill(
                self.params, batch, fresh, jnp.int32(n)))
        self.metrics.on_prefill(n, time.time() - t0)
        self._dispatch(scope)
        self._slot_restore(req.slot, new_cache)

        self._key, k = jax.random.split(self._key)
        tok = int(np.asarray(sample_logits(
            k, logits, e.temperature, e.top_k))[0])
        first = not req.generated
        req.generated.append(tok)
        self._last_tok[req.slot, 0] = tok
        if first and req.t_first_token < 0:
            req.t_first_token = self.now()
            self.metrics.on_first_token(req.arrival_time, req.t_first_token)

    def _retire(self, req: Request) -> None:
        self.sched.retire(req, self.now())
        self.metrics.on_retire(req.arrival_time, req.t_admit, req.t_done)

    def _preempt_newest(self) -> None:
        """Every lane is stalled: preempt the newest request so the rest can
        make progress.  Its blocks free immediately; it re-enters the queue
        head and re-prefills prompt+generated at the next admission.
        ``sched.preempt`` (not ``retire``) keeps the request's lifecycle
        fields clean: no ``t_done`` is stamped until it actually finishes."""
        victim = max(self.sched.active.values(), key=lambda r: r.t_admit)
        slot = victim.slot
        self.sched.preempt(victim)
        self.metrics.preemptions += 1
        self._last_tok[slot, 0] = 0

    # -- main loop ------------------------------------------------------------
    def step(self) -> bool:
        """One engine step: admissions+prefills, then one batched decode.
        Returns False when there is nothing left to do."""
        if self.sched.idle():
            return False
        plan = self.sched.plan(self.now())
        for req in plan.prefills:
            self._do_prefill(req)
            if req.done():
                self._retire(req)

        # a request can finish at prefill (first token == budget/EOS), so
        # re-check the planned decode slots against the live set
        active = {s: self.sched.active[s] for s in plan.decode_slots
                  if s in self.sched.active}
        if active:
            # decide stalls BEFORE decoding: the coming step writes the KV of
            # each lane's pending token, so its block table must cover
            # prompt + generated tokens
            snaps = {}
            for slot, req in active.items():
                if not self.sched.grow(req,
                                       req.prompt_len + len(req.generated)):
                    self.metrics.stalls += 1
                    snaps[slot] = self._slot_snapshot(slot)
            toks = jnp.asarray(self._last_tok)[:, :, None]   # (S, 1, 1)
            t0 = time.time()
            with self._dispatch_scope("decode"):
                logits, self._cache = jax.block_until_ready(self._decode(
                    self.params, toks, self._cache))
            dt = time.time() - t0
            self._dispatch("decode")
            self._key, k = jax.random.split(self._key)
            sampled = np.asarray(sample_logits(
                k, logits[:, 0, :], self.ecfg.temperature, self.ecfg.top_k))
            committed = 0
            for slot, req in sorted(active.items()):
                if req.stalled:
                    # roll the lane back; it replays this token once the
                    # pool can cover it
                    self._slot_restore(slot, snaps[slot])
                    continue
                req.generated.append(int(sampled[slot]))
                self._last_tok[slot, 0] = req.generated[-1]
                committed += 1
                if req.t_first_token < 0:
                    req.t_first_token = self.now()
                    self.metrics.on_first_token(req.arrival_time,
                                                req.t_first_token)
                if req.done():
                    self._retire(req)
            self.metrics.on_decode_step(len(active), self.ecfg.num_slots,
                                        committed, dt)
            if self.sched.active and \
                    all(r.stalled for r in self.sched.active.values()):
                self._preempt_newest()
        self._vtime += 1.0
        return True

    def run(self, requests: Sequence[Request]) -> Dict[str, np.ndarray]:
        """Serve a request set to completion; returns {rid: generated}."""
        for r in requests:
            self.submit(r)
        while self.step():
            pass
        return {r.rid: np.asarray(r.generated, np.int32) for r in requests}

    def dispatch_stats(self) -> Dict[str, int]:
        """Executed-GEMM dispatch telemetry (registry-backed)."""
        backends: Dict[str, int] = {}
        sources: Dict[str, int] = {}
        for scope in self.registry.scopes():
            for b, c in self.registry.backends(scope).items():
                backends[b] = backends.get(b, 0) + c
            for s, c in self.registry.sources(scope).items():
                sources[s] = sources.get(s, 0) + c
        return {"gemm_plan_changes": self.plan_changes,
                "gemm_sites_executed": len(self.gemm_plan),
                "gemm_traced_scopes": len(self.registry.scopes()),
                "gemm_pallas_sites": backends.get("pallas", 0),
                "gemm_xla_sites": backends.get("xla", 0),
                "rec_adaptnet_sites": sources.get("adaptnet", 0),
                "rec_oracle_sites": sources.get("oracle", 0),
                "rec_fallback_sites": sources.get("oracle_fallback", 0)}

    def summary(self) -> Dict[str, float]:
        s = self.metrics.summary(self.dispatcher.cache_info(),
                                 dispatch=self.dispatch_stats())
        s["kv_peak_blocks"] = self.pool.peak_in_use
        return s
