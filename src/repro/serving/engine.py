"""ServingEngine: continuous-batching inference over the repro model stack.

Execution model
---------------
* ``num_slots`` fixed decode lanes.  Each slot owns a batch=1 cache pytree
  (``models/serving.py``); the engine stacks them on a leading slot axis and
  decodes every step with one ``jit(vmap(decode_step))`` — per-slot scalar
  positions/lengths become per-lane under vmap, so heterogeneous sequence
  lengths coexist in one batched step with no model changes.
* Prefill has two paths.  The *bucketed* default runs per admitted request
  at a small set of padded bucket shapes (one XLA compilation per bucket):
  the prompt is right-padded and the true ``length`` is passed as a traced
  scalar, which ``serving.prefill`` uses to pick the real last-token
  logits and correct the cache lengths.  SSM/hybrid families use
  exact-length prefill (their recurrent state integrates every input
  token).  Under ``EngineConfig.prefill_chunk`` (paged layout, dense/moe
  families) prefill is instead *chunked and paged*: every step runs ONE
  ragged batch over all mid-prefill lanes, each contributing up to
  ``prefill_chunk`` of its remaining context (per-row lengths — one batch
  carries heterogeneous prompts; rows past a lane's length land in the
  write-discard page exactly like stalled decode lanes).  Chunk KV rows
  are written straight into the lane's pool pages — no dense scratch
  cache, no bucket-granularity copy — so prefill KV traffic scales with
  real prompt tokens, a long prompt streams over several steps instead of
  monopolizing one, and a short prompt admitted alongside gets its first
  token after one cheap chunk batch (TTFT is stamped per chunk
  completion).
* Every GEMM the model runs goes through the SARA dispatch layer
  (``repro.dispatch``): each prefill/decode entry point traces under a
  named registry scope with this engine's dispatcher active, so the tile
  configuration every site *executes* with (RSA Pallas blocks + residency
  mode under ``execute="pallas"``/on-TPU ``"auto"``; XLA otherwise) is
  recorded per trace.  ``gemm_plan`` is read back from that registry —
  the executed plan, not an advisory estimate — and ``plan_changes``
  counts real reconfigurations (steps whose executed plan differs from
  the previous step's).  ``SaraDispatcher.cache_info()`` feeds the
  recommendation-cache hit rate into the metrics.
* ``EngineConfig.dispatcher_mode`` selects the recommendation source:
  ``"oracle"`` (exhaustive analytic search) or ``"adaptnet"`` (a trained
  ADAPTNET-TPU loaded from ``adaptnet_dir`` — the paper's self-adaptive
  runtime path; shapes outside its trained range fall back to the
  oracle, and per-source site counts land in ``dispatch_stats()``).
* KV layout (``EngineConfig.kv_layout``): under ``"paged"`` (what
  ``"auto"`` picks for attention families on TPU) each layer's K/V rows
  live in a physical page arena ``(layers, num_blocks + 1, block_size,
  ...)`` bound to the ``KVBlockPool``; decode runs ONE batched ``paged_decode_step`` over all
  lanes that reads K/V through per-slot block tables
  (``kernels/paged_attn.py``), so per-step KV traffic is
  ``sum_lane ceil(kv_len / block_size)`` pages — it scales with live
  tokens, not ``num_slots * max_len``.  The table width shipped to the
  kernel each step is the max live page count rounded up to a power of
  two (one compilation per width bucket).  Bucketed prefill runs at
  padded bucket shapes into a scratch dense cache whose first pages are
  then scattered into the arena at bucket granularity; chunked prefill
  (``prefill_chunk``) skips the scratch cache entirely and writes chunk
  rows straight into pages.  Slot KV
  snapshot/restore disappears: stalled lanes simply don't commit (their
  new-token KV is routed to the arena's trailing write-discard page) and
  preemption frees pages without copying anything.  ``"dense"`` keeps the
  original stacked per-slot caches + ``jit(vmap(decode_step))`` and is
  what recurrent-state families (ssm, hybrid) always use; encdec pages
  its self-attention KV while its cross K/V stays dense per slot.
* The ``KVBlockPool`` meters admission over *text* tokens under the dense
  layout (the vlm frontend adds a constant per-slot overhead outside the
  budget); under the paged layout the vlm frontend's rows live in pool
  pages too, so reservations include them.  ``reserve="full"`` can never
  stall; ``reserve="incremental"`` packs denser: a lane whose block-table
  extension fails stalls (skips committing) until blocks free up, and if
  every lane stalls the newest request is preempted
  (recompute-on-readmit: it re-enters the queue and re-prefills
  prompt+generated at its next admission).

The clock is either ``"wall"`` (live serving) or ``"steps"`` (virtual time
in engine-step units — deterministic, used by tests and trace benchmarks).
"""

from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import dispatch
from repro.configs.base import ArchConfig
from repro.core.sara import SaraDispatcher
from repro.dispatch import SiteRegistry
from repro.models.serving import PAGED_FAMILIES
from repro.obs import (JitWatch, RequestTracker, StepTimeline, TraceRecorder,
                       write_chrome_trace, write_jsonl)
from repro.serving.faults import (OUTCOME_COUNTERS, ChaosConfig,
                                  FaultInjector, fault_rids)
from repro.serving.kv_pool import (KVArena, KVBlockPool, PoolError,
                                   SanitizerError)
from repro.serving.metrics import ServingMetrics
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import ContinuousScheduler, Request
from repro.serving.spec_decode import (SpecDecoder, accept_tokens,
                                       resolve_draft)


def sample_logits(key, logits: jnp.ndarray, temperature: float = 1.0,
                  top_k: int = 0) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32.  temperature<=0 is greedy argmax;
    top_k>0 masks everything below the k-th logit before sampling."""
    if temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        thresh = vals[:, -1:]
        logits = jnp.where(logits < thresh, -1e30, logits)
    return jax.random.categorical(key, logits, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# GEMM-site enumeration (analytic estimate — benchmarks/capacity planning).
# The engine itself no longer consults this: its gemm_plan is read back
# from the dispatch registry, i.e. from the sites that actually traced.
# ---------------------------------------------------------------------------

def gemm_sites(cfg: ArchConfig, m_tokens: int) -> List[Tuple[str, int, int, int]]:
    """The (M, K, N) of each distinct GEMM the model runs on ``m_tokens``
    rows this step (MoE expert GEMMs use the expected routed-row count)."""
    m = max(int(m_tokens), 1)
    d = cfg.d_model
    sites: List[Tuple[str, int, int, int]] = []
    if cfg.attention_type == "gqa":
        sites += [("attn_qkv", m, d, cfg.q_dim + 2 * cfg.kv_dim),
                  ("attn_out", m, cfg.q_dim, d)]
    elif cfg.attention_type == "mla":
        a = cfg.mla
        sites += [("mla_down", m, d,
                   a.q_lora_rank + a.kv_lora_rank + a.qk_rope_head_dim),
                  ("mla_out", m, cfg.num_heads * a.v_head_dim, d)]
    if cfg.moe is not None:
        sites += [("moe_expert",
                   max(m * cfg.moe.experts_per_token, 1), d,
                   2 * cfg.moe.d_ff_expert),
                  ("moe_router", m, d, cfg.moe.num_experts)]
    else:
        sites += [("mlp_up", m, d, 2 * cfg.d_ff),
                  ("mlp_down", m, cfg.d_ff, d)]
    if cfg.ssm is not None:
        sites += [("ssm_proj", m, d, 2 * cfg.ssm.expand * d)]
    sites += [("lm_head", m, d, cfg.vocab_size)]
    return sites


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@dataclass
class EngineConfig:
    """Serving-engine knobs (model-independent; the architecture comes from
    the ``ArchConfig`` the engine is built with).

    The defaults serve small CPU traces; production settings raise
    ``num_slots`` / ``max_len`` / ``num_blocks`` and leave the backend-aware
    ``"auto"`` selectors alone so the same config runs compiled Pallas +
    paged KV on TPU and XLA + dense KV elsewhere.  See ``docs/SERVING.md``
    for the request lifecycle each field participates in.
    """

    num_slots: int = 4
    max_len: int = 96                 # per-slot token capacity (prompt+gen+1)
    block_size: int = 16              # KV pool page size (tokens)
    num_blocks: Optional[int] = None  # KV budget; None = full slot capacity
    buckets: Optional[Sequence[int]] = None   # prefill shapes; None = pow2
    max_prefills_per_step: int = 1    # admissions per engine step
    reserve: str = "full"             # "full" | "incremental"
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    eos_id: Optional[int] = None
    clock: str = "steps"              # "steps" | "wall"
    src_len: int = 0                  # encdec: shared encoder length
    execute: str = "auto"             # GEMM backend: "pallas"|"xla"|"auto"
    dispatcher_mode: str = "oracle"   # recommendation source: "oracle"|"adaptnet"
    adaptnet_dir: Optional[str] = None  # trained ADAPTNET-TPU checkpoint dir
    # KV storage: "paged" = physical page arena + paged flash-decode kernel
    # (attention families); "dense" = stacked per-slot caches + vmapped
    # decode (always used by ssm/hybrid).  "auto" is backend-aware like
    # execute="auto": paged on TPU (where page-granular HBM traffic is the
    # win), dense elsewhere — at CPU-test capacities the paged path's
    # fixed per-step overheads outweigh the rows it skips.
    kv_layout: str = "auto"           # "auto" | "paged" | "dense"
    # Chunked paged prefill: stream each prompt into the arena
    # ``prefill_chunk`` tokens per engine step instead of one padded-bucket
    # call per request.  One ragged batch carries every mid-prefill lane
    # (per-row lengths; short prompts finish in one chunk while long ones
    # keep streaming), KV rows land directly in pages (no dense scratch
    # cache, no bucket-granularity copy), and a long prompt no longer
    # monopolizes a step.  Requires the paged layout and a
    # CHUNKED_PREFILL_FAMILIES family (dense/moe); None keeps the padded
    # bucketed prefill.
    prefill_chunk: Optional[int] = None
    # Cross-request prefix caching (serving/prefix_cache.py): admission
    # matches each prompt's longest cached page prefix, maps those pages
    # into the new request's table (refcounted, copy-on-write on first
    # write) and prefills only the suffix.  Requires prefill_chunk — cache
    # hits admit mid-prompt, and only the chunked path can resume a
    # prefill from a per-lane offset.
    prefix_cache: bool = False
    # Cascade decode: when >= 2 decode lanes' block tables start with the
    # same physical pages, stream that shared prefix ONCE per step for the
    # whole group instead of once per lane.  Opt-in on top of
    # prefix_cache.  The XLA reference rebuilds each lane's combined
    # table and runs one masked softmax, so greedy parity with cache-off
    # is bitwise; the Pallas kernel keeps the two-phase online-softmax
    # merge and matches numerically.  GQA text families only (absorbed
    # MLA keeps the plain paged decode).
    shared_prefix_decode: bool = False
    # Speculative decoding (serving/spec_decode.py): draft ``spec_k``
    # tokens per lane per step with a draft model, verify all of them
    # (plus the pending token) with ONE target pass through the ragged
    # chunked-prefill kernel, and commit the longest matching prefix +
    # one corrected token.  Every committed token is the target verify
    # argmax, so output is bitwise-identical to plain greedy decode.
    # ``spec_draft`` names a registry arch for the draft model, or
    # "self" for self-speculation (shares the target's params — the
    # acceptance-rate upper bound, what the benchmark uses to isolate
    # engine overheads).  Requires prefill_chunk (the verifier IS the
    # chunk kernel), greedy decoding (temperature <= 0), and is
    # incompatible with shared_prefix_decode (the verify chunk replaces
    # the decode step the cascade would group).
    spec_draft: Optional[str] = None
    spec_k: int = 4
    # Draft-arena page budget (None = same as the target pool).  Draft
    # KV lives under the same pool economics; a lane whose draft
    # reservation fails is draft-preempted for the step (plain C=1
    # verify, counted in spec_draft_preempts) — a small budget is the
    # test lever for that path.
    spec_draft_blocks: Optional[int] = None
    # Auto-defrag: compact the pool after any step that leaves
    # fragmentation() above this threshold (None = manual defrag() only).
    defrag_threshold: Optional[float] = None
    # Observability (repro.obs): counters/gauges are ALWAYS on (a dict
    # update per event); ``trace=True`` additionally records span/instant
    # events — request lifecycle, step phases, dispatch/compile/arena —
    # into a ring buffer of ``trace_capacity`` events, exportable via
    # ``export_trace()`` (serve.py --trace-out).
    trace: bool = False
    trace_capacity: int = 65536
    # KV-arena sanitizer (serving/kv_pool.py): poison freed pages with
    # NaN, stamp every page with a generation counter (bumped on each
    # re-allocation) and validate decode block tables against the stamps
    # captured at table-build time, run the pool invariant check every
    # step, and audit refcount/pin leaks when ``run()`` drains.  Traps
    # use-after-free through stale tables as :class:`SanitizerError`
    # instead of silent garbage logits.  Debug/test mode — poisoning
    # rewrites one arena page per freed block.
    sanitize: bool = False
    # Chaos harness (serving/faults.py): deterministic seed-driven fault
    # injection — simulated pool OOMs, poisoned pages (requires
    # ``sanitize``), forced lane stalls, forced mid-prefill preemptions.
    # None / all-zero probabilities = no injection.
    chaos: Optional[ChaosConfig] = None
    # Livelock guard: preempt/readmit cycles a request may consume before
    # the engine fails it (outcome "failed") instead of requeueing again.
    preempt_budget: int = 3
    # Step error boundary: an UNattributable PoolError/SanitizerError
    # (no ``rids`` — cannot be pinned on one request) is retried this
    # many times with exponential backoff (``retry_backoff_s`` doubling
    # per attempt, slept only under the wall clock) before surfacing.
    max_step_retries: int = 2
    retry_backoff_s: float = 0.05
    # Crash safety: when set, ``snapshot()`` / auto-snapshots (every
    # ``snapshot_every`` steps, 0 = manual only) write a restorable
    # engine checkpoint through checkpoint/manager; a fresh engine with
    # the same configs resumes mid-trace via ``restore()``.
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 0


class ServingEngine:
    """Continuous-batching inference engine over the repro model stack.

    Construct with an ``ArchConfig`` (what model) and an ``EngineConfig``
    (how to serve it); ``submit()`` requests and drive ``step()`` until it
    returns False, or use ``run()`` for a whole request set.  Telemetry
    comes out of ``summary()`` / ``metrics`` / ``dispatch_stats()`` and
    the executed per-site tile plan out of ``gemm_plan``.  See the module
    docstring for the execution model and ``docs/SERVING.md`` for the
    request lifecycle (admit -> [chunked] prefill -> paged decode ->
    retire/preempt) and the KV page accounting."""

    def __init__(self, cfg: ArchConfig, engine: EngineConfig = None,
                 params=None, dispatcher: Optional[SaraDispatcher] = None):
        from repro.models.api import build_model

        self.cfg = cfg
        self.ecfg = engine or EngineConfig()
        self.model = build_model(cfg)
        self.params = params if params is not None \
            else self.model.init(jax.random.PRNGKey(self.ecfg.seed))
        self.dispatcher = dispatcher if dispatcher is not None \
            else self._build_dispatcher(self.ecfg)
        self.metrics = ServingMetrics()
        # observability: one recorder for every layer (engine steps,
        # request spans, dispatch/compile/arena events); counters always
        # on, span recording behind EngineConfig.trace
        self.obs = TraceRecorder(capacity=self.ecfg.trace_capacity,
                                 spans=self.ecfg.trace)
        self.req_spans = RequestTracker(self.obs)
        self.timeline = StepTimeline(self.obs)

        e = self.ecfg
        layout = e.kv_layout
        if layout == "auto":
            layout = ("paged" if cfg.family in PAGED_FAMILIES
                      and jax.default_backend() == "tpu" else "dense")
        if layout not in ("paged", "dense"):
            raise ValueError(f"unknown kv_layout {e.kv_layout!r}")
        if layout == "paged" and cfg.family not in PAGED_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} keeps recurrent state in the dense "
                f"slot layout; kv_layout='paged' supports {PAGED_FAMILIES}")
        self.kv_layout = layout

        self.prefill_chunk = e.prefill_chunk
        if self.prefill_chunk is not None:
            from repro.models.serving import CHUNKED_PREFILL_FAMILIES
            if self.prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1")
            # no prompt exceeds max_len, so a larger chunk would only pad
            # the batch with dead query rows the kernel still computes
            self.prefill_chunk = min(self.prefill_chunk, e.max_len)
            if layout != "paged":
                raise ValueError(
                    "prefill_chunk streams prompts directly into KV pages; "
                    "it requires kv_layout='paged' (got "
                    f"{self.kv_layout!r})")
            if cfg.family not in CHUNKED_PREFILL_FAMILIES:
                raise ValueError(
                    f"family {cfg.family!r} keeps the bucketed prefill "
                    f"(chunked prefill supports {CHUNKED_PREFILL_FAMILIES})")

        # vlm frontend rows share the per-slot KV cache; under the paged
        # layout they live in pool pages, so reservations must cover them
        self._fe_rows = (cfg.frontend.num_tokens
                         if cfg.family == "vlm" else 0)
        self._cache_len = e.max_len + self._fe_rows
        row_overhead = self._fe_rows if layout == "paged" else 0
        blocks_per_slot = -(-(e.max_len + row_overhead) // e.block_size)
        num_blocks = (e.num_blocks if e.num_blocks is not None
                      else e.num_slots * blocks_per_slot)
        self.pool = KVBlockPool(num_blocks, e.block_size,
                                sanitize=e.sanitize)
        self.pool.attach_recorder(self.obs)
        self._leak_audit: Dict[str, int] = {}
        self.prefix_cache: Optional[PrefixCache] = None
        if e.prefix_cache:
            if self.prefill_chunk is None:
                raise ValueError(
                    "prefix_cache requires prefill_chunk: a cache hit "
                    "admits a request mid-prompt, and only the chunked "
                    "prefill path can resume from a per-lane offset")
            self.prefix_cache = PrefixCache(self.pool, recorder=self.obs)
        self.sched = ContinuousScheduler(
            e.num_slots, self.pool,
            max_prefills_per_step=e.max_prefills_per_step, reserve=e.reserve,
            token_overhead=row_overhead, prefill_chunk=self.prefill_chunk,
            tracker=self.req_spans, prefix_cache=self.prefix_cache,
            metrics=self.metrics)
        # every submitted request, live or terminal — how the step error
        # boundary maps a fault's rids back to Request objects
        self.requests: Dict[str, Request] = {}
        self.chaos: Optional[FaultInjector] = None
        if e.chaos is not None and e.chaos.any_enabled():
            if e.chaos.poison_p > 0 and not e.sanitize:
                raise ValueError(
                    "chaos.poison_p needs sanitize=True: the sanitizer's "
                    "poison scan is what detects (and contains) the "
                    "injected page — without it the fault surfaces as "
                    "silent garbage tokens")
            self.chaos = FaultInjector(e.chaos, recorder=self.obs)
        self._step_idx = 0               # monotonic, drives chaos schedules
        # analytic per-token prefill cost (2*M*K*N over every GEMM site at
        # M=1, layer sites times the stack depth) — what each cache-hit
        # token avoids recomputing; feeds metrics.prefill_flops_saved
        self._flops_per_token = float(sum(
            2 * m * k * n * (1 if name == "lm_head" else cfg.num_layers)
            for name, m, k, n in gemm_sites(cfg, 1)))
        self._last_tok = np.zeros((e.num_slots, 1), np.int32)
        self._prefill = JitWatch(jax.jit(self.model.prefill), "prefill",
                                 self.obs)

        if layout == "paged":
            # physical page arena (pool pages + one write-discard scratch
            # page for masked lanes), per-slot row counts, and the slot-
            # stacked residue that stays dense (encdec cross K/V).  The
            # scratch prefill cache is rounded up to whole pages so the
            # bucket-granularity arena scatter can always slice full blocks.
            # (under this layout row_overhead == self._fe_rows, so
            # blocks_per_slot already covers the full _cache_len rows)
            self._max_blocks_per_slot = blocks_per_slot
            self._prefill_rows = self._max_blocks_per_slot * e.block_size
            self.arena = KVArena(
                self.model.init_paged_arena(num_blocks + 1, e.block_size),
                e.block_size)
            self.pool.bind_arena(self.arena)
            self._state = self.model.init_paged_state(e.num_slots,
                                                      src_len=e.src_len)
            self._kv_rows = np.zeros((e.num_slots,), np.int32)
            self._paged_decode = JitWatch(
                jax.jit(self.model.paged_decode_step), "paged_decode",
                self.obs)
            self._paged_write = JitWatch(
                jax.jit(self.model.paged_prefill_write), "paged_write",
                self.obs)
            if self.prefill_chunk is not None:
                self._chunk_prefill = JitWatch(
                    jax.jit(self.model.paged_prefill_step), "chunk_prefill",
                    self.obs)
            self._paged_shared_decode = None
            if e.shared_prefix_decode:
                if self.prefix_cache is None:
                    raise ValueError(
                        "shared_prefix_decode needs prefix_cache: shared "
                        "page runs only arise from cache-hit admissions")
                if cfg.attention_type == "mla":
                    raise ValueError(
                        "shared_prefix_decode is GQA-only (absorbed MLA "
                        "keeps the plain paged decode)")
                self._paged_shared_decode = JitWatch(
                    jax.jit(self.model.paged_shared_decode_step),
                    "paged_shared_decode", self.obs)
            self._cache = None
        else:
            # stacked per-slot caches: leading axis = slot, lane batch=1
            self._prefill_rows = self._cache_len
            proto = self.model.init_cache(1, self._cache_len,
                                          src_len=e.src_len)
            self._cache = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(
                    a, (e.num_slots,) + a.shape).copy(), proto)
            self._decode = JitWatch(
                jax.jit(jax.vmap(self.model.decode_step,
                                 in_axes=(None, 0, 0))), "decode", self.obs)
        self.spec: Optional[SpecDecoder] = None
        if e.spec_draft is not None:
            if self.prefill_chunk is None:
                raise ValueError(
                    "spec_draft requires prefill_chunk: the verify pass IS "
                    "the ragged chunked-prefill kernel (spec_k + 1 rows "
                    "per lane through block tables)")
            if e.temperature > 0.0:
                raise ValueError(
                    "spec_draft requires greedy decoding (temperature <= "
                    "0): the accept rule compares drafts against the "
                    "verify argmax, which is only the sampling rule when "
                    "greedy")
            if e.shared_prefix_decode:
                raise ValueError(
                    "spec_draft is incompatible with shared_prefix_decode: "
                    "the verify chunk replaces the decode step the "
                    "cascade would group")
            if e.spec_k < 1:
                raise ValueError("spec_k must be >= 1")
            draft_cfg, draft_params = resolve_draft(
                cfg, self.params, e.spec_draft, e.seed)
            self.spec = SpecDecoder(
                draft_cfg, draft_params, num_slots=e.num_slots,
                block_size=e.block_size,
                num_blocks=(e.spec_draft_blocks
                            if e.spec_draft_blocks is not None
                            else num_blocks),
                max_blocks_per_slot=self._max_blocks_per_slot,
                chunk=self.prefill_chunk, spec_k=e.spec_k,
                recorder=self.obs)
            self._spec_verify = JitWatch(
                jax.jit(self.model.paged_verify_step), "spec_verify",
                self.obs)
        # what one masked-dense decode step would stream: every slot's full
        # capacity (recurrent-state families have no KV rows to speak of)
        self._dense_kv_rows = (e.num_slots * self._cache_len
                               if cfg.attention_type != "none" else 0)
        self._key = jax.random.PRNGKey(e.seed + 1)
        self._vtime = 0.0
        self._t0 = time.time()
        # registry-backed executed-plan bookkeeping: each traced entry point
        # (one per prefill bucket + one for the vmapped decode) records its
        # sites under a scope; _dispatch() reads the plan back (memoized per
        # scope) instead of re-running any recommendation sweep.  The
        # recorder hook turns each record into a "dispatch" trace event.
        self.registry = SiteRegistry(recorder=self.obs)
        self.gemm_plan: Dict[str, str] = {}
        self.plan_changes = 0
        self._plan_memo: Dict[str, Dict[str, str]] = {}

    @staticmethod
    def _build_dispatcher(ecfg: EngineConfig) -> SaraDispatcher:
        if ecfg.dispatcher_mode == "adaptnet":
            if not ecfg.adaptnet_dir:
                raise ValueError(
                    "dispatcher_mode='adaptnet' needs adaptnet_dir: a "
                    "checkpoint saved by `python -m repro.launch."
                    "train_adaptnet --out <dir>`")
            return SaraDispatcher.from_checkpoint(ecfg.adaptnet_dir)
        if ecfg.dispatcher_mode != "oracle":
            raise ValueError(f"unknown dispatcher_mode "
                             f"{ecfg.dispatcher_mode!r}")
        return SaraDispatcher()

    # -- time -----------------------------------------------------------------
    def now(self) -> float:
        if self.ecfg.clock == "steps":
            return self._vtime
        return time.time() - self._t0

    # -- SARA dispatch --------------------------------------------------------
    @contextlib.contextmanager
    def _dispatch_scope(self, scope: str):
        """Install this engine's dispatch policy + registry scope around a
        jitted call: if the call traces (first time this shape is seen),
        every GEMM site records its executed configuration under ``scope``."""
        with dispatch.use(self.dispatcher, execute=self.ecfg.execute,
                          registry=self.registry), \
                self.registry.scope(scope):
            yield

    def _dispatch(self, scope: str) -> None:
        """Adopt the executed plan of ``scope`` (memoized per scope — the
        scope name encodes the token count, so an unchanged batch shape is
        a dict lookup, not a recommendation sweep)."""
        plan = self._plan_memo.get(scope)
        if plan is None:
            plan = self.registry.plan(scope)
            self._plan_memo[scope] = plan
        if plan != self.gemm_plan:
            self.plan_changes += 1       # a real reconfiguration
            self.gemm_plan = plan

    # -- buckets --------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        if self.cfg.family in ("ssm", "hybrid"):
            return n                   # recurrent state: no padded prefill
        b = None
        if self.ecfg.buckets:
            fits = [x for x in sorted(self.ecfg.buckets) if x >= n]
            if fits:
                b = fits[0]
        if b is None:
            b = 16
            while b < n:
                b *= 2
        # prefill writes `bucket` KV rows, so never pad past the slot arena
        # (submit() guarantees n itself fits)
        return max(n, min(b, self.ecfg.max_len))

    # -- request lifecycle ----------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.prompt_len < 1:
            raise ValueError(f"request {req.rid}: prompt must be non-empty "
                             "(there is no last-token position to sample "
                             "the first token from)")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be >= 1 "
                             "(prefill always yields the first token)")
        need = req.prompt_len + req.max_new_tokens + 1
        if need > self.ecfg.max_len:
            raise ValueError(f"request {req.rid} needs {need} tokens > "
                             f"max_len {self.ecfg.max_len}")
        need_rows = need + self.sched.token_overhead
        if self.pool.blocks_for(need_rows) > self.pool.num_blocks:
            raise ValueError(
                f"request {req.rid} needs {self.pool.blocks_for(need_rows)} "
                f"KV blocks > pool total {self.pool.num_blocks}; it could "
                "never be admitted")
        if req.eos_id is None:
            req.eos_id = self.ecfg.eos_id
        self.sched.submit(req)
        self.requests[req.rid] = req

    def _slot_snapshot(self, slot: int):
        return jax.tree_util.tree_map(lambda a: a[slot], self._cache)

    def _slot_restore(self, slot: int, snap) -> None:
        self._cache = jax.tree_util.tree_map(
            lambda big, one: big.at[slot].set(one), self._cache, snap)

    def _do_prefill(self, req: Request) -> None:
        e, cfg = self.ecfg, self.cfg
        context = req.context()
        n = int(context.shape[0])
        bucket = self.bucket_for(n)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = context
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.asarray(
                (req.extras or {}).get(
                    "patch_embeds",
                    np.zeros((1, cfg.frontend.num_tokens,
                              cfg.frontend.feature_dim), np.float32)),
                jnp.dtype(cfg.compute_dtype))
        if cfg.family == "encdec":
            batch["src_features"] = jnp.asarray(
                (req.extras or {}).get(
                    "src_features",
                    np.zeros((1, e.src_len, cfg.frontend.feature_dim),
                             np.float32)),
                jnp.dtype(cfg.compute_dtype))

        scope = f"prefill:m{bucket}"
        fresh = self.model.init_cache(1, self._prefill_rows, src_len=e.src_len)
        t0 = time.time()
        with self._dispatch_scope(scope), \
                self.timeline.phase("prefill", rid=req.rid, bucket=bucket):
            logits, new_cache = self._prefill(
                self.params, batch, fresh, jnp.int32(n))
        with self.timeline.phase("sync"):
            logits, new_cache = jax.block_until_ready((logits, new_cache))
        dt = time.time() - t0
        self.obs.add_scope_wall(scope, dt)
        self.req_spans.on_prefill_chunk(req.rid, n, dt, bucket=bucket)
        self._dispatch(scope)
        if self.kv_layout == "paged":
            # commit the prefilled KV rows into this request's pool pages
            # (bucket-granularity scatter); the scratch dense cache is gone
            # after this — only pages + the row count persist per slot
            rows = n + self._fe_rows
            nblk = self.pool.blocks_for(rows)
            table = self.pool.table(req.rid).blocks
            # saralint: ok[cow-gate] bucketed prefill writes only freshly alloc'd pages; this path never coexists with prefix-cache sharing (cache requires prefill_chunk)
            self.arena.leaves = self._paged_write(
                self.arena.leaves, new_cache["layers"],
                jnp.asarray(table[:nblk], jnp.int32))
            self._kv_rows[req.slot] = rows
            self.metrics.on_prefill(
                n, dt, kv_write_rows=nblk * e.block_size,
                kv_write_rows_padded=bucket + self._fe_rows)
            if cfg.family == "encdec":
                self._state["cross_k"] = self._state["cross_k"].at[
                    :, req.slot].set(new_cache["cross_k"][:, 0])
                self._state["cross_v"] = self._state["cross_v"].at[
                    :, req.slot].set(new_cache["cross_v"][:, 0])
        else:
            self.metrics.on_prefill(n, dt)
            self._slot_restore(req.slot, new_cache)
        req.prefill_pos = n
        req.prefilling = False

        self._key, k = jax.random.split(self._key)
        tok = int(np.asarray(sample_logits(
            k, logits, e.temperature, e.top_k))[0])
        first = not req.generated
        req.generated.append(tok)
        self._last_tok[req.slot, 0] = tok
        if first and req.t_first_token < 0:
            req.t_first_token = self.now()
            self.metrics.on_first_token(req.arrival_time, req.t_first_token)
            self.req_spans.on_first_token(req.rid)

    def _do_chunk_prefills(self) -> None:
        """One chunked-prefill step over every mid-prefill lane.

        The batch is ragged: each lane contributes up to ``prefill_chunk``
        of its remaining context (per-row lengths), lanes with nothing to
        stream (or whose page extension stalled) ride along with a zero
        chunk — their rows write to the arena's trash page and their
        logits row is ignored.  KV rows land directly in the lane's pool
        pages; there is no dense scratch cache and no bucket-granularity
        copy, so prefill writes scale with real prompt tokens.  A lane
        whose final chunk lands here samples its first token (TTFT is
        stamped per chunk completion, so short prompts admitted alongside
        long ones stop waiting on the long prefill)."""
        e = self.ecfg
        C, S = self.prefill_chunk, e.num_slots
        lanes = {s: r for s, r in self.sched.active.items() if r.prefilling}
        if not lanes:
            return
        toks = np.zeros((S, C), np.int32)
        chunk = np.zeros((S,), np.int32)
        for slot, req in sorted(lanes.items()):
            n = min(C, req.context_len - req.prefill_pos)
            # the coming chunk writes n KV rows: the block table must cover
            # them (chunk-incremental reservation extends here; a failed
            # extension stalls the lane's prefill until pages free up)
            if not self.sched.grow(req, req.prefill_pos + n):
                self.metrics.stalls += 1
                continue
            if not self._cow_chunk_pages(req, req.prefill_pos, n):
                self.metrics.stalls += 1
                continue
            ctx = req.context()
            toks[slot, :n] = ctx[req.prefill_pos:req.prefill_pos + n]
            chunk[slot] = n
        if not chunk.any():
            return                       # every prefilling lane stalled
        kv = np.where(chunk > 0, self._kv_rows, 0).astype(np.int32)
        # fixed table width -> the chunk step compiles exactly once.  Unlike
        # decode (where narrow tables ARE the read-scaling win), a chunk
        # must attend over its lane's whole prefix anyway, and dead table
        # columns cost (almost) nothing in the kernel: the DMA is elided
        # for repeated trailing ids and `j*bs < kv_len` skips the compute.
        width = self._max_blocks_per_slot
        rids = [lanes[s].rid if chunk[s] > 0 else None for s in range(S)]
        tables = self.pool.dense_block_table(rids, width)

        scope = "prefill_chunk"
        t0 = time.time()
        with self._dispatch_scope(scope), \
                self.timeline.phase("prefill_chunk",
                                    lanes=int((chunk > 0).sum())):
            logits, leaves = self._chunk_prefill(
                self.params, jnp.asarray(toks), self.arena.leaves,
                jnp.asarray(tables), jnp.asarray(kv), jnp.asarray(chunk))
        with self.timeline.phase("sync"):
            logits, leaves = jax.block_until_ready((logits, leaves))
        dt = time.time() - t0
        self.obs.add_scope_wall(scope, dt)
        self.arena.leaves = leaves
        self._dispatch(scope)
        for slot, req in sorted(lanes.items()):
            if chunk[slot] > 0:
                self.req_spans.on_prefill_chunk(req.rid, int(chunk[slot]),
                                                dt, pos=req.prefill_pos)

        total = int(chunk.sum())
        # padded-bucket equivalent accrues proportionally per chunk
        # (telescoping integer shares that sum to bucket_for(ctx) over a
        # complete stream), so a request preempted mid-prefill has
        # contributed to both sides of the reduction ratio symmetrically —
        # and contributes again when it re-streams after readmission
        padded = 0
        for slot, req in lanes.items():
            n = int(chunk[slot])
            if n == 0:
                continue
            ctx, pos = req.context_len, req.prefill_pos
            b = self.bucket_for(ctx)
            padded += (b * (pos + n)) // ctx - (b * pos) // ctx
        self.metrics.on_prefill(total, dt, kv_write_rows=total,
                                kv_write_rows_padded=padded)
        # only a lane whose FINAL chunk landed this step consumes logits;
        # skip the key split + sampling entirely when none did (keeps the
        # hot loop lean and the RNG stream free of discarded draws)
        sampled = None
        if any(chunk[s] and r.prefill_pos + chunk[s] >= r.context_len
               for s, r in lanes.items()):
            self._key, k = jax.random.split(self._key)
            sampled = np.asarray(sample_logits(
                k, logits, e.temperature, e.top_k))
        for slot, req in sorted(lanes.items()):
            n = int(chunk[slot])
            if n == 0:
                continue
            req.prefill_pos += n
            self._kv_rows[slot] += n
            if req.prefill_pos < req.context_len:
                continue                 # more chunks to stream next step
            req.prefilling = False
            if self.prefix_cache is not None:
                # index the finished prompt's fully-covered pages; its
                # content is frozen by construction from here on (decode
                # only appends rows >= prompt_len) so pinning is safe.
                # Spans already cached keep their existing page.
                nfull = req.prompt_len // e.block_size
                if nfull:
                    self.prefix_cache.insert(
                        req.prompt, self.pool.table(req.rid).blocks[:nfull])
            tok = int(sampled[slot])
            first = not req.generated
            req.generated.append(tok)
            self._last_tok[slot, 0] = tok
            if first and req.t_first_token < 0:
                req.t_first_token = self.now()
                self.metrics.on_first_token(req.arrival_time,
                                            req.t_first_token)
                self.req_spans.on_first_token(req.rid)
            if req.done():
                self._retire(req)

    def _cow_chunk_pages(self, req: Request, pos: int, n: int) -> bool:
        """Copy-on-write gate for the pages the coming chunk writes (rows
        ``[pos, pos + n)``).  A cache-hit lane's first recomputed token can
        land inside a shared or pinned page (the minus-one resume offset,
        or a readmitted lane re-streaming over pages it donated to the
        cache), and writing through the arena would corrupt every other
        owner — so each target page is made private first.  A COW that
        cannot get a free page evicts cache entries; if the pool is still
        dry the lane stalls exactly like a failed ``grow()``."""
        if self.prefix_cache is None or n <= 0:
            return True
        bs = self.ecfg.block_size
        for pi in range(pos // bs, (pos + n - 1) // bs + 1):
            while True:
                try:
                    self.pool.ensure_writable(req.rid, pi)
                    break
                except PoolError:
                    # ensure_writable only raises when the free list is
                    # empty; any successful eviction guarantees progress
                    if self.prefix_cache.evict(1) == 0:
                        req.stalled = True
                        return False
        req.stalled = False
        return True

    def _retire(self, req: Request) -> None:
        slot = req.slot
        self.sched.retire(req, self.now())
        req.outcome = "done"
        self.metrics.on_retire(req.arrival_time, req.t_admit, req.t_done,
                               in_deadline=not req.expired_at(req.t_done))
        if self.spec is not None:
            self.spec.release(req.rid)
        if self.kv_layout == "paged":
            self._kv_rows[slot] = 0      # pages already back in the free list

    def _finish(self, req: Request, outcome: str, reason: str = "") -> None:
        """Terminal-failure bookkeeping shared by fault containment, the
        scheduler's deadline/cancel sweep, and preempt-budget exhaustion.
        When the scheduler already closed the request (``plan.finished``
        hands them over with ``outcome`` set and slot/pages/span gone)
        only the engine-side counters remain; otherwise the scheduler
        teardown runs here too."""
        slot = req.slot
        if not req.outcome:
            self.sched.finish(req, outcome, self.now(), reason=reason)
        self.metrics.on_finish(req.outcome)
        self.obs.count(OUTCOME_COUNTERS[req.outcome], 1)
        if self.spec is not None:
            self.spec.release(req.rid)
        if slot >= 0:
            self._last_tok[slot, 0] = 0
            if self.kv_layout == "paged":
                self._kv_rows[slot] = 0

    def _preempt(self, victim: Request) -> None:
        """Preempt one admitted request (recompute-on-readmit) — unless
        its preemption budget is spent, in which case it fails instead of
        requeueing: a victim the pool can never hold would otherwise
        cycle preempt->readmit->stall->preempt forever (livelock), and
        each cycle re-prefills its whole context."""
        victim.preempt_count += 1
        if victim.preempt_count > self.ecfg.preempt_budget:
            self.obs.count("preempt_budget_exhausted", 1)
            self._finish(victim, "failed",
                         reason=f"preemption budget "
                                f"({self.ecfg.preempt_budget}) exhausted")
            return
        slot = victim.slot
        self.sched.preempt(victim)
        self.metrics.preemptions += 1
        if self.spec is not None:
            self.spec.release(victim.rid)
        self._last_tok[slot, 0] = 0
        if self.kv_layout == "paged":
            self._kv_rows[slot] = 0

    def _preempt_newest(self) -> None:
        """Every lane is stalled: preempt the newest request so the rest can
        make progress.  Its pages free immediately — under the paged layout
        nothing is copied, the block table entries just return to the pool —
        and it re-enters the queue head to re-prefill prompt+generated at
        the next admission.  ``sched.preempt`` (not ``retire``) keeps the
        request's lifecycle fields clean: no ``t_done`` is stamped until it
        actually finishes."""
        self._preempt(max(self.sched.active.values(),
                          key=lambda r: r.t_admit))

    # -- chaos injection points -----------------------------------------------
    def _inject_admission_chaos(self) -> None:
        """Post-schedule chaos: force-preempt a mid-prefill lane
        (exercising recompute-on-readmit and the preemption budget), then
        possibly raise a simulated pool OOM attributed to one live lane —
        the containment path's bread and butter."""
        step = self._step_idx
        mid_prefill = [r for _, r in sorted(self.sched.active.items())
                       if r.prefilling]
        victim = self.chaos.preempt(step, mid_prefill)
        if victim is not None:
            self._preempt(victim)
        live = [r for _, r in sorted(self.sched.active.items())]
        victim = self.chaos.pool_oom(step, live)
        if victim is not None:
            raise self.chaos.oom_error(step, victim)

    def _inject_decode_chaos(self, active: Dict[int, Request],
                             snaps: Dict) -> None:
        """Pre-decode chaos: forced lane stalls (writes land in the trash
        page, the token replays — dense lanes get the rollback snapshot a
        real stall would have taken) and, under paged+sanitize,
        NaN-poisoning one fully-written exclusively-owned page of a lane
        so the post-decode poison scan must trap and attribute it."""
        step = self._step_idx
        lanes = [r for _, r in sorted(active.items())]
        for req in self.chaos.stall_lanes(step, lanes):
            if not req.stalled:
                req.stalled = True
                self.metrics.stalls += 1
                if self.kv_layout == "dense" and req.slot not in snaps:
                    snaps[req.slot] = self._slot_snapshot(req.slot)
        if self.kv_layout != "paged" or self.ecfg.chaos.poison_p <= 0:
            return
        bs = self.ecfg.block_size
        cands = []
        for slot, req in sorted(active.items()):
            if req.stalled:
                continue
            full = int(self._kv_rows[slot]) // bs
            pages = [b for b in self.pool.table(req.rid).blocks[:full]
                     if self.pool.refcount(b) == 1
                     and self.pool.pincount(b) == 0]
            cands.append((req, pages))
        hit = self.chaos.poison(step, cands)
        if hit is not None:
            _, page = hit
            self.arena.poison_page(page)

    # -- main loop ------------------------------------------------------------
    def step(self) -> bool:
        """One engine step: admissions + prefill work (one padded-bucket
        call per admitted request, or one ragged chunk batch over every
        mid-prefill lane under chunked prefill), then one batched decode
        over the fully-prefilled lanes.  Returns False when there is
        nothing left to do."""
        if self.sched.idle():
            return False
        retries = max(0, self.ecfg.max_step_retries)
        delay = self.ecfg.retry_backoff_s
        for attempt in range(retries + 1):
            fault = None
            self.timeline.begin()
            try:
                self._step_body()
                thr = self.ecfg.defrag_threshold
                if thr is not None and self.pool.fragmentation() > thr:
                    self.obs.count("kv_defrag_auto", 1)
                    self.defrag()
            except (PoolError, SanitizerError) as exc:
                # step error boundary: decide below whether this is one
                # request's fault or engine-level trouble — either way
                # the timeline closes cleanly first
                fault = exc
            finally:
                e = self.ecfg
                self.obs.gauge("kv_pages_in_use", self.pool.num_in_use)
                self.obs.gauge("kv_fragmentation", self.pool.fragmentation())
                self.obs.gauge("slot_occupancy",
                               len(self.sched.active) / e.num_slots)
                self.timeline.end(active=len(self.sched.active),
                                  waiting=self.sched.pending())
            if fault is None:
                break
            if self._contain_fault(fault):
                break                    # victims failed; engine lives on
            if attempt >= retries:
                raise fault              # unattributable and out of retries
            self.obs.count("engine_step_retries", 1)
            self.obs.instant("fault", "step_retry", track="faults",
                             attempt=attempt + 1,
                             error=type(fault).__name__)
            if self.ecfg.clock == "wall" and delay > 0:
                time.sleep(delay)        # virtual clocks retry immediately
            delay *= 2
        if self.ecfg.sanitize:
            # full invariant sweep every step: refcount drift and
            # free-list corruption surface at the step that caused them,
            # not at teardown
            self.pool.check()
            if self.spec is not None:
                self.spec.check()
            self.obs.count("kv_sanitize_checks", 1)
        self._vtime += 1.0
        self._step_idx += 1
        if self.ecfg.snapshot_dir and self.ecfg.snapshot_every > 0 \
                and self._step_idx % self.ecfg.snapshot_every == 0:
            self.snapshot()
        return True

    def _contain_fault(self, fault: Exception) -> bool:
        """Fail exactly the request(s) a step fault names instead of the
        whole engine.  Returns True when the fault was attributed to at
        least one live request — its pages free, its span closes with
        outcome ``failed``, and surviving lanes simply replay their
        pending token next step (the raise always precedes token commit,
        so no generated sequence observes the abandoned step)."""
        victims = [self.requests[rid] for rid in fault_rids(fault)
                   if rid in self.requests
                   and not self.requests[rid].outcome]
        if not victims:
            return False
        self.obs.count("faults_contained", len(victims))
        for req in victims:
            self.obs.instant("fault", "contained", track="faults",
                             rid=req.rid, error=type(fault).__name__)
            self._finish(req, "failed", reason=str(fault)[:200])
        return True

    def _step_body(self) -> None:
        with self.timeline.phase("schedule"):
            plan = self.sched.plan(self.now())
        # requests the scheduling pass terminated (expired/shed/cancelled):
        # the scheduler already tore down slot/pages/span, the counters and
        # lane arrays are the engine's side
        for req in plan.finished:
            self._finish(req, req.outcome)
        for req in plan.prefills:
            if self.kv_layout == "paged":
                # reset lane bookkeeping on EVERY admission — a lane whose
                # previous occupant left through containment or the
                # deadline sweep never zeroed its row count, and chunked
                # prefill extends with `+=` from whatever is here.  Cache
                # hits resume at the cached offset (prefill_pos), misses
                # at 0.
                self._kv_rows[req.slot] = req.prefill_pos
            if req.cached_prefix_tokens:
                self.metrics.on_cache_hit(req.cached_prefix_tokens,
                                          req.cached_pages,
                                          self._flops_per_token)
                self.req_spans.on_cache_hit(req.rid,
                                            tokens=req.cached_prefix_tokens,
                                            pages=req.cached_pages)
        if self.chaos is not None:
            self._inject_admission_chaos()
        if self.prefill_chunk is not None:
            self._do_chunk_prefills()
        else:
            # every still-prefilling active lane, not just this plan's
            # admissions: an aborted step (contained fault between
            # admission and prefill) leaves admitted-but-unprefilled
            # lanes behind, and they must prefill on the retry
            for req in [r for _, r in sorted(self.sched.active.items())
                        if r.prefilling]:
                self._do_prefill(req)
                if req.done():
                    self._retire(req)

        # a request can finish at prefill (first token == budget/EOS) and
        # chunked lanes may still be mid-prefill, so re-check the planned
        # decode slots against the live, fully-prefilled set
        active = {s: self.sched.active[s] for s in plan.decode_slots
                  if s in self.sched.active
                  and not self.sched.active[s].prefilling}
        if active and self.spec is not None:
            self._spec_decode_step(active)
        elif active:
            # decide stalls BEFORE decoding: the coming step writes the KV of
            # each lane's pending token, so its block table must cover
            # prompt + generated tokens
            snaps = {}
            for slot, req in active.items():
                if not self.sched.grow(req,
                                       req.prompt_len + len(req.generated)):
                    self.metrics.stalls += 1
                    if self.kv_layout == "dense":
                        snaps[slot] = self._slot_snapshot(slot)
            if self.chaos is not None:
                # after grow() (which clears stalled on success), so a
                # forced stall survives into this step's decode mask
                self._inject_decode_chaos(active, snaps)
            if self.kv_layout == "paged":
                logits, dt, kv_read = self._decode_paged(active)
            else:
                toks = jnp.asarray(self._last_tok)[:, :, None]  # (S, 1, 1)
                t0 = time.time()
                with self._dispatch_scope("decode"), \
                        self.timeline.phase("decode", lanes=len(active)):
                    logits, cache = self._decode(
                        self.params, toks, self._cache)
                with self.timeline.phase("sync"):
                    logits, self._cache = jax.block_until_ready(
                        (logits, cache))
                dt = time.time() - t0
                self.obs.add_scope_wall("decode", dt)
                logits = logits[:, 0, :]
                kv_read = self._dense_kv_rows
            self._dispatch("decode")
            with self.timeline.phase("sample"):
                self._key, k = jax.random.split(self._key)
                sampled = np.asarray(sample_logits(
                    k, logits, self.ecfg.temperature, self.ecfg.top_k))
                committed = 0
                for slot, req in sorted(active.items()):
                    if req.stalled:
                        # the lane replays this token once the pool can
                        # cover it; paged lanes wrote nothing (trash page),
                        # dense lanes roll back to the pre-step snapshot
                        if self.kv_layout == "dense":
                            self._slot_restore(slot, snaps[slot])
                        continue
                    req.generated.append(int(sampled[slot]))
                    self._last_tok[slot, 0] = req.generated[-1]
                    if self.kv_layout == "paged":
                        self._kv_rows[slot] += 1
                    committed += 1
                    if req.t_first_token < 0:
                        req.t_first_token = self.now()
                        self.metrics.on_first_token(req.arrival_time,
                                                    req.t_first_token)
                        self.req_spans.on_first_token(req.rid)
                    if req.done():
                        self._retire(req)
            self.metrics.on_decode_step(
                len(active), self.ecfg.num_slots, committed, dt,
                kv_read_tokens=kv_read,
                kv_read_tokens_dense=self._dense_kv_rows)
        # every live lane stalled — whether on a decode-step block-table
        # extension or a prefill-chunk one — preempt the newest request so
        # the rest can make progress
        if self.sched.active and \
                all(r.stalled for r in self.sched.active.values()):
            self._preempt_newest()

    def _decode_paged(self, active: Dict[int, Request]):
        """One batched decode over every lane through the page arena.
        Returns (logits (S, V), seconds, KV rows actually streamed)."""
        e = self.ecfg
        S = e.num_slots
        wm = np.zeros((S,), np.int32)
        for slot, req in active.items():
            wm[slot] = 0 if req.stalled else 1
        # lanes outside the decode set (empty slots, mid-prefill lanes
        # under chunked prefill) contribute no pages: length 0 masks them
        # in the kernel and their rows are never streamed
        kv = np.where([s in active for s in range(S)],
                      self._kv_rows, 0).astype(np.int32)
        # pages each lane touches this step (stalled lanes attend without
        # their pending token; empty lanes touch nothing)
        need = [self.pool.blocks_for(int(kv[s]) + int(wm[s]))
                for s in range(S)]
        # table width = max live pages rounded up to a power of two (one
        # compilation per width bucket) — the kernel grid walks only these
        # columns, which is what makes decode cost track live tokens
        width = KVBlockPool.table_width(max(need),
                                        self._max_blocks_per_slot)
        rids = [active[s].rid if s in active else None for s in range(S)]
        tables = self.pool.dense_block_table(rids, width)
        # snapshot the generation stamp of every page the table names;
        # replayed after the kernel to trap tables that outlived a free
        gens = (self.pool.table_generations(rids, width)
                if e.sanitize else None)
        toks = jnp.asarray(self._last_tok)                   # (S, 1)
        self.obs.gauge("decode_table_width", width)
        group = None
        if self._paged_shared_decode is not None:
            group = self._shared_prefix_group(active, kv, wm)
        t0 = time.time()
        if group is not None:
            prefix_pages, prefix_lens, utables, ulens, kv_read, npages = group
            self.obs.count("shared_prefix_steps", 1)
            self.obs.gauge("shared_prefix_lanes",
                           int((prefix_lens > 0).sum()))
            with self._dispatch_scope("decode"), \
                    self.timeline.phase("paged_decode", lanes=len(active),
                                        width=width, shared_pages=npages):
                # saralint: ok[cow-gate] decode appends one row into the lane's exclusively-owned tail page; shared prefix pages cover only rows < kv_len
                logits, leaves = self._paged_shared_decode(
                    self.params, toks, self._state, self.arena.leaves,
                    jnp.asarray(tables), jnp.asarray(kv), jnp.asarray(wm),
                    jnp.asarray(prefix_pages), jnp.asarray(prefix_lens),
                    jnp.asarray(utables), jnp.asarray(ulens))
        else:
            kv_read = e.block_size * sum(need)
            with self._dispatch_scope("decode"), \
                    self.timeline.phase("paged_decode", lanes=len(active),
                                        width=width):
                # saralint: ok[cow-gate] decode appends one row into the lane's exclusively-owned tail page; shared prefix pages cover only rows < kv_len
                logits, leaves = self._paged_decode(
                    self.params, toks, self._state, self.arena.leaves,
                    jnp.asarray(tables), jnp.asarray(kv), jnp.asarray(wm))
        with self.timeline.phase("sync"):
            logits, leaves = jax.block_until_ready((logits, leaves))
        dt = time.time() - t0
        self.obs.add_scope_wall("decode", dt)
        self.arena.leaves = leaves
        logits = np.asarray(logits)
        if e.sanitize:
            self._sanitize_decode(active, rids, tables, gens, logits)
        return logits, dt, kv_read

    def _sanitize_decode(self, active: Dict[int, Request],
                         rids, tables, gens, logits: np.ndarray) -> None:
        """Post-decode sanitizer traps.  (1) generation replay: every
        (page, generation) pair the step's block table named must still
        be current — a page freed and re-handed-out since table build is
        a use-after-free.  (2) poison scan: a non-finite logit row on a
        live lane means the kernel streamed a poisoned (freed) page."""
        try:
            self.pool.assert_generations(rids, tables, gens)
        except SanitizerError:
            self.obs.count("kv_generation_faults", 1)
            raise
        bad = [s for s, r in sorted(active.items())
               if not r.stalled and not np.isfinite(logits[s]).all()]
        if bad:
            self.obs.count("kv_poison_hits", len(bad))
            lanes = ", ".join(f"{s} ({active[s].rid})" for s in bad)
            err = SanitizerError(
                f"poisoned KV page read: decode produced non-finite "
                f"logits on lane(s) {lanes} — a freed (NaN-filled) arena "
                "page is still reachable through a live block table")
            # attributed: the step error boundary fails exactly these
            # lanes instead of crashing the engine
            err.rids = [active[s].rid for s in bad]
            raise err

    def _spec_decode_step(self, active: Dict[int, Request]) -> None:
        """One speculative step over the fully-prefilled lanes: draft up
        to ``spec_k`` tokens per lane (draft model, own page arena),
        verify every lane's pending token + drafts with ONE target pass
        through the ragged chunked-prefill kernel (C = spec_k + 1 rows
        per lane), and commit the longest draft prefix the verify argmax
        agrees with plus one corrected/bonus token.  Every committed
        token is a target verify argmax, so sequences are bitwise
        greedy-parity with plain decode — speculation only changes how
        many commit per step.  Rejected drafts roll back by NOT
        advancing per-lane lengths: the rows they wrote (target and
        draft arenas alike) sit past the new kv length inside
        already-reserved pages and are overwritten in place next step —
        COW-gated below where target pages are shared with the prefix
        cache, so the rewind can never scribble on another request."""
        e = self.ecfg
        S, K = e.num_slots, e.spec_k
        # per-lane draft quota: the bonus token always commits one, so
        # never draft past the remaining budget; reserve verify rows
        # [L-1, L-1+k] up front, degrading k -> 0 before stalling
        quota: Dict[int, int] = {}
        for slot, req in sorted(active.items()):
            L = req.prompt_len + len(req.generated)
            k = max(0, min(K, req.max_new_tokens - len(req.generated) - 1))
            if not self.sched.grow(req, L + k):
                k = 0
                if not self.sched.grow(req, L):
                    self.metrics.stalls += 1
            quota[slot] = k
        if self.chaos is not None:
            self._inject_decode_chaos(active, {})
        for slot, req in sorted(active.items()):
            if req.stalled:
                continue                 # writes nothing: no fork needed
            L = req.prompt_len + len(req.generated)
            if not self._cow_chunk_pages(req, L - 1, quota[slot] + 1):
                self.metrics.stalls += 1

        draft_lanes = {s: (r, quota[s]) for s, r in active.items()
                       if not r.stalled and quota[s] > 0}
        drafts: Dict[int, List[int]] = {}
        preempts = 0
        dt_draft = 0.0
        if draft_lanes:
            t0 = time.time()
            with self._dispatch_scope("spec_draft"), \
                    self.timeline.phase("spec_draft",
                                        lanes=len(draft_lanes)):
                drafts, preempts = self.spec.draft(draft_lanes)
            dt_draft = time.time() - t0
            self.obs.add_scope_wall("spec_draft", dt_draft)
            if preempts:
                self.obs.count("spec_draft_preempts", preempts)

        # verify: one ragged chunk batch at fixed width (compiles once).
        # Row 0 is the pending token (exactly what plain decode would
        # process), rows 1..k are the drafts; stalled lanes ride along
        # with chunk 0 (rows land in the trash page, logits ignored).
        C = K + 1
        toks = np.zeros((S, C), np.int32)
        clens = np.zeros((S,), np.int32)
        for slot, req in sorted(active.items()):
            if req.stalled:
                continue
            d = drafts.get(slot, [])
            toks[slot, 0] = self._last_tok[slot, 0]
            toks[slot, 1:1 + len(d)] = d
            clens[slot] = 1 + len(d)
        kv = np.where(clens > 0, self._kv_rows, 0).astype(np.int32)
        width = self._max_blocks_per_slot
        rids = [active[s].rid if s in active and clens[s] > 0 else None
                for s in range(S)]
        tables = self.pool.dense_block_table(rids, width)
        gens = (self.pool.table_generations(rids, width)
                if e.sanitize else None)
        kv_read = e.block_size * sum(
            self.pool.blocks_for(int(kv[s]) + int(clens[s]))
            for s in range(S))
        t0 = time.time()
        with self._dispatch_scope("spec_verify"), \
                self.timeline.phase("spec_verify",
                                    lanes=int((clens > 0).sum()),
                                    width=width):
            # saralint: ok[cow-gate] verify rows are COW-forked above (_cow_chunk_pages over [L-1, L-1+k]) before this write
            logits, leaves = self._spec_verify(
                self.params, jnp.asarray(toks), self.arena.leaves,
                jnp.asarray(tables), jnp.asarray(kv), jnp.asarray(clens))
        with self.timeline.phase("sync"):
            logits, leaves = jax.block_until_ready((logits, leaves))
        dt = time.time() - t0
        self.obs.add_scope_wall("spec_verify", dt)
        self.arena.leaves = leaves
        self._dispatch("spec_verify")
        logits = np.asarray(logits)          # (S, C, V)
        if e.sanitize:
            self._sanitize_spec(active, rids, tables, gens, logits, clens)

        with self.timeline.phase("sample"):
            argm = np.argmax(logits, -1)     # (S, C) greedy verify picks
            committed = accepted = bonus = drafted = live = 0
            for slot, req in sorted(active.items()):
                if req.stalled:
                    continue             # replays the pending token
                live += 1
                d = drafts.get(slot, [])
                drafted += len(d)
                a, commit = accept_tokens(d, argm[slot, :len(d) + 1])
                c = 0
                for t in commit:         # EOS can land mid-commit
                    req.generated.append(int(t))
                    c += 1
                    if req.done():
                        break
                accepted += min(a, c)
                bonus += c - min(a, c)
                committed += c
                self._kv_rows[slot] += c
                self._last_tok[slot, 0] = req.generated[-1]
                self.spec.commit(req.rid, int(self._kv_rows[slot]))
                if req.t_first_token < 0:
                    req.t_first_token = self.now()
                    self.metrics.on_first_token(req.arrival_time,
                                                req.t_first_token)
                    self.req_spans.on_first_token(req.rid)
                if req.done():
                    self._retire(req)
        self.obs.count("spec_steps", 1)
        self.obs.count("spec_drafted_tokens", drafted)
        self.obs.count("spec_accepted_tokens", accepted)
        self.obs.count("spec_bonus_tokens", bonus)
        self.obs.gauge("spec_accepted_per_step", committed / max(live, 1))
        self.metrics.on_spec_step(live, drafted, accepted, bonus, preempts)
        self.metrics.on_decode_step(
            len(active), e.num_slots, committed, dt + dt_draft,
            kv_read_tokens=kv_read,
            kv_read_tokens_dense=self._dense_kv_rows)

    def _sanitize_spec(self, active: Dict[int, Request], rids, tables,
                       gens, logits: np.ndarray, clens: np.ndarray) -> None:
        """Post-verify sanitizer traps — the spec twin of
        ``_sanitize_decode``, scanning only each lane's live chunk rows
        (rows past ``clens`` are trash-page garbage by construction)."""
        try:
            self.pool.assert_generations(rids, tables, gens)
        except SanitizerError:
            self.obs.count("kv_generation_faults", 1)
            raise
        bad = [s for s, r in sorted(active.items())
               if not r.stalled
               and not np.isfinite(logits[s, :int(clens[s])]).all()]
        if bad:
            self.obs.count("kv_poison_hits", len(bad))
            lanes = ", ".join(f"{s} ({active[s].rid})" for s in bad)
            err = SanitizerError(
                f"poisoned KV page read: spec verify produced non-finite "
                f"logits on lane(s) {lanes} — a freed (NaN-filled) arena "
                "page is still reachable through a live block table")
            err.rids = [active[s].rid for s in bad]
            raise err

    def _shared_prefix_group(self, active: Dict[int, Request],
                             kv: np.ndarray, wm: np.ndarray):
        """Detect the hottest shared page run among the decode lanes: the
        largest group (>= 2 lanes) whose block tables begin with the same
        physical pages, with >= 1 fully-written common page.  Returns the
        cascade-kernel operands ``(prefix_pages, prefix_lens,
        unique_tables, unique_lens, kv_read_rows, n_prefix_pages)`` —
        padded to power-of-two widths like the plain decode tables — or
        ``None`` when no group exists this step."""
        e = self.ecfg
        S, bs = e.num_slots, e.block_size
        blocks = {s: self.pool.table(r.rid).blocks
                  for s, r in active.items()}
        groups: Dict[int, List[int]] = {}
        for s, b in blocks.items():
            # only fully-written pages can sit in the shared phase (it
            # reads whole pages), so a lane needs >= bs committed rows
            if b and int(kv[s]) >= bs:
                groups.setdefault(b[0], []).append(s)
        if not groups:
            return None
        best = max(groups.values(), key=len)
        if len(best) < 2:
            return None
        # longest common physical prefix, capped at each member's fully
        # written pages — the pending token's row must stay in the unique
        # phase (it is written this very step)
        P = min(min(int(kv[s]) // bs for s in best),
                min(len(blocks[s]) for s in best))
        first = blocks[best[0]]
        i = 0
        while i < P and all(blocks[s][i] == first[i] for s in best[1:]):
            i += 1
        P = i
        if P < 1:
            return None
        members = set(best)
        prefix_lens = np.zeros((S,), np.int32)
        ulens = np.zeros((S,), np.int32)
        for s in active:
            attn = int(kv[s]) + int(wm[s])
            if s in members:
                prefix_lens[s] = P * bs
                ulens[s] = attn - P * bs
            else:
                ulens[s] = attn
        uneed = max(self.pool.blocks_for(int(n)) for n in ulens)
        uw = KVBlockPool.table_width(max(uneed, 1),
                                     self._max_blocks_per_slot)
        utables = np.zeros((S, uw), np.int32)
        for s in active:
            off = P if s in members else 0
            b = blocks[s][off:off + uw]
            if b:
                utables[s, :len(b)] = b
                utables[s, len(b):] = b[-1]
        pw = KVBlockPool.table_width(P, self._max_blocks_per_slot)
        prefix_pages = np.full((pw,), first[P - 1], np.int32)
        prefix_pages[:P] = first[:P]
        # the measured win: the P shared pages stream once for the whole
        # group instead of once per member lane
        kv_read = bs * (P + sum(self.pool.blocks_for(int(n))
                                for n in ulens))
        return prefix_pages, prefix_lens, utables, ulens, kv_read, P

    def run(self, requests: Sequence[Request]) -> Dict[str, np.ndarray]:
        """Serve a request set to completion; returns {rid: generated}.
        An invalid request (empty prompt, oversized, never-admittable) is
        recorded as ``rejected`` and skipped — one bad request in a batch
        must not take the server down with it."""
        for r in requests:
            try:
                self.submit(r)
            except (ValueError, PoolError) as exc:
                r.outcome = "rejected"
                self.requests[r.rid] = r
                self.metrics.on_finish("rejected")
                self.obs.count("requests_rejected", 1)
                self.obs.instant("request", "rejected", f"req:{r.rid}",
                                 rid=r.rid, reason=str(exc)[:200])
        while self.step():
            pass
        if self.ecfg.sanitize:
            # teardown audit: every request drained, so every page must be
            # reclaimed and the only surviving pins are the prefix trie's
            expected = (self.prefix_cache.pages()
                        if self.prefix_cache is not None else ())
            self._leak_audit = self.pool.audit_leaks(expected)
            if self.spec is not None:
                # draft pages are released with their target request, so
                # a drained engine must leave the draft pool empty too
                self.spec.check()
                self._leak_audit["kv_draft_leaked_blocks"] = \
                    self.spec.live_pages()
        return {r.rid: np.asarray(r.generated, np.int32) for r in requests}

    def dispatch_stats(self) -> Dict[str, int]:
        """Executed-GEMM dispatch telemetry (registry-backed)."""
        backends: Dict[str, int] = {}
        sources: Dict[str, int] = {}
        for scope in self.registry.scopes():
            for b, c in self.registry.backends(scope).items():
                backends[b] = backends.get(b, 0) + c
            for s, c in self.registry.sources(scope).items():
                sources[s] = sources.get(s, 0) + c
        return {"gemm_plan_changes": self.plan_changes,
                "gemm_sites_executed": len(self.gemm_plan),
                "gemm_traced_scopes": len(self.registry.scopes()),
                "gemm_pallas_sites": backends.get("pallas", 0),
                "gemm_xla_sites": backends.get("xla", 0),
                "rec_adaptnet_sites": sources.get("adaptnet", 0),
                "rec_oracle_sites": sources.get("oracle", 0),
                "rec_fallback_sites": sources.get("oracle_fallback", 0),
                # retraces, from the compile-event counter: the signal a
                # shape-diversity regression shows up in directly, instead
                # of having to be inferred from wall time
                "jit_compiles": int(self.obs.counters.get("jit_compiles",
                                                          0))}

    def defrag(self) -> int:
        """Compact live KV pages to the front of the arena between steps:
        the pool rewrites every block table and (paged layout) mirrors the
        move map into page storage as one batched gather.  Returns the
        number of pages moved.  The next decode step picks the remapped
        tables up automatically."""
        return len(self.pool.defrag())

    def summary(self) -> Dict[str, float]:
        s = self.metrics.summary(self.dispatcher.cache_info(),
                                 dispatch=self.dispatch_stats())
        s["kv_layout"] = self.kv_layout
        s["kv_peak_blocks"] = self.pool.peak_in_use
        s["kv_fragmentation"] = self.pool.fragmentation()
        s["kv_defrag_block_moves"] = self.pool.defrag_moves
        s["kv_defrag_auto"] = int(self.obs.counters.get("kv_defrag_auto", 0))
        s["kv_shared_pages"] = self.pool.shared_pages
        s["kv_cow_copies"] = self.pool.cow_copies
        if self.prefix_cache is not None:
            s.update(self.prefix_cache.stats())
        s["faults_contained"] = int(
            self.obs.counters.get("faults_contained", 0))
        s["engine_step_retries"] = int(
            self.obs.counters.get("engine_step_retries", 0))
        s["preempt_budget_exhausted"] = int(
            self.obs.counters.get("preempt_budget_exhausted", 0))
        s["engine_snapshots"] = int(
            self.obs.counters.get("engine_snapshots", 0))
        s["engine_restores"] = int(
            self.obs.counters.get("engine_restores", 0))
        if self.chaos is not None:
            s["faults_injected"] = self.chaos.total_injected()
            s.update(self.chaos.summary())
        if self.ecfg.sanitize:
            s["kv_sanitize_checks"] = self.pool.sanitize_checks
            s["kv_poison_fills"] = self.pool.poison_fills
            s["kv_poison_hits"] = int(
                self.obs.counters.get("kv_poison_hits", 0))
            s["kv_generation_faults"] = self.pool.generation_faults
            s.update(self._leak_audit)
        return s

    # -- crash safety ---------------------------------------------------------
    def snapshot(self, directory: Optional[str] = None,
                 blocking: bool = True) -> int:
        """Write a restorable engine snapshot (KV storage, scheduler
        queue/slots, live requests, pool ownership, prefix-cache trie,
        metrics, PRNG) through ``checkpoint/manager`` — atomic rename,
        so a crash mid-save never corrupts the latest snapshot.  Returns
        the snapshot's step index."""
        from repro.serving.snapshot import save_engine
        d = directory or self.ecfg.snapshot_dir
        if not d:
            raise ValueError("snapshot needs a directory: pass one or set "
                             "EngineConfig.snapshot_dir")
        step = save_engine(self, d, blocking=blocking)
        self.obs.count("engine_snapshots", 1)
        return step

    def restore(self, directory: Optional[str] = None,
                step: Optional[int] = None) -> int:
        """Resume from a snapshot into this freshly-built engine (same
        configs, nothing submitted yet).  Surviving requests continue
        token-for-token under greedy decoding.  Returns the restored
        step index."""
        from repro.serving.snapshot import restore_engine
        d = directory or self.ecfg.snapshot_dir
        if not d:
            raise ValueError("restore needs a directory: pass one or set "
                             "EngineConfig.snapshot_dir")
        step = restore_engine(self, d, step=step)
        self.obs.count("engine_restores", 1)
        return step

    # -- observability export -------------------------------------------------
    def site_timings(self) -> Dict[str, Dict]:
        """Measured wall time per traced scope joined with the sites that
        scope executes — the raw material for profile-calibrated dispatch
        (the ROADMAP item this subsystem feeds): every (site, M, K, N,
        tile) gets the wall-clock of the compiled call it ran inside."""
        out: Dict[str, Dict] = {}
        for scope, (calls, secs) in self.obs.scope_wall.items():
            sites = {name: {"m": r.m, "k": r.k, "n": r.n,
                            "tile": r.describe(), "source": r.source}
                     for name, r in self.registry.sites(scope).items()}
            out[scope] = {"calls": calls, "seconds": secs, "sites": sites}
        return out

    def export_trace(self, path: str) -> str:
        """Write the trace as Chrome/Perfetto trace-event JSON at ``path``
        plus a structured JSONL sibling (``.jsonl``).  Loadable in
        https://ui.perfetto.dev or chrome://tracing; see
        docs/OBSERVABILITY.md.  Returns the JSONL path."""
        meta = {"arch": self.cfg.name, "kv_layout": self.kv_layout,
                "prefill_chunk": self.prefill_chunk,
                "dispatcher_mode": self.ecfg.dispatcher_mode,
                "site_timings": self.site_timings()}
        write_chrome_trace(path, self.obs, meta)
        jsonl = (path[:-5] if path.endswith(".json") else path) + ".jsonl"
        write_jsonl(jsonl, self.obs, meta)
        return jsonl
