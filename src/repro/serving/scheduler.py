"""Continuous-batching request scheduler.

Wave-based serving (``launch/serve.py::serve_waves``) admits a whole batch,
decodes until the *longest* member finishes, then starts over — short
requests pad out the wave and the array idles, the serving-side analogue of
the shape-diversity/utilization problem SARA targets.  This scheduler
instead re-plans every decode step: finished requests retire immediately,
their KV blocks return to the pool, and queued requests are admitted into
the freed slots mid-flight.

The engine owns the model math; the scheduler owns admission:

  submit()  enqueue a Request (FCFS by arrival time)
  plan(now) -> StepPlan: which queued requests to prefill into which free
              slots this step (bounded by ``max_prefills_per_step`` and the
              KV pool budget), plus the set of slots to decode
  grow()    per-token block-table extension (incremental mode)
  retire()  free the slot + every KV block of a finished request

Admission control: ``reserve="full"`` reserves blocks for the worst case
(prompt + max_new + 1) at admit time, so a decode can never OOM;
``reserve="incremental"`` admits on prompt-size blocks only and extends
block-by-block during decode — denser packing, and a slot whose extension
fails simply stalls (skips sampling) until another request retires.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serving.kv_pool import KVBlockPool, PoolError


@dataclass
class Request:
    """One serving request: immutable inputs + engine-owned runtime state.

    ``prompt`` is the (prompt_len,) int32 token array; ``extras`` carries
    per-request model inputs for the non-text families (vlm patch embeds,
    encdec source features) at batch size 1.  The engine mutates the
    runtime fields; callers should treat them as read-only telemetry.
    """

    rid: str
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int
    arrival_time: float = 0.0
    eos_id: Optional[int] = None
    extras: Optional[Dict] = None       # per-request vlm/encdec inputs (B=1)
    # completion deadline in seconds after ``arrival_time`` (engine-clock
    # units: wall seconds or virtual steps).  The scheduler expires a
    # queued request once the deadline passes, and sheds it at admission
    # when the rolling-TTFT estimate says the deadline cannot be met.
    deadline_s: Optional[float] = None

    # runtime state (engine-owned)
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    stalled: bool = False
    # terminal outcome ("" while live): done | failed | expired | shed |
    # cancelled | rejected — see serving/faults.py
    outcome: str = ""
    # preempt/readmit cycles consumed (engine fails the request when it
    # exceeds EngineConfig.preempt_budget — the livelock guard)
    preempt_count: int = 0
    cancel_requested: bool = False
    # prefill phase: ``prefilling`` is set at admission and cleared when the
    # prefill completes (bucketed: same step; chunked: after the final
    # chunk); ``prefill_pos`` counts context tokens already streamed into
    # the cache during the current prefill
    prefilling: bool = False
    prefill_pos: int = 0
    # prefix-cache telemetry: tokens / pages the current admission mapped
    # from the cache instead of recomputing (reset on preempt)
    cached_prefix_tokens: int = 0
    cached_pages: int = 0
    t_admit: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def context_len(self) -> int:
        """Tokens a (re-)prefill must cover: prompt plus anything already
        generated before a preemption."""
        return self.prompt_len + len(self.generated)

    def context(self) -> np.ndarray:
        """The (context_len,) token array a (re-)prefill streams — the
        recompute-on-readmit contract shared by the bucketed and chunked
        prefill paths."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and len(self.generated) > 0
                and self.generated[-1] == self.eos_id)

    def cancel(self) -> None:
        """Revoke the request.  Takes effect at the next scheduling pass:
        queued or active, the request leaves the system with outcome
        ``cancelled`` and its pages return to the pool."""
        self.cancel_requested = True

    def expired_at(self, now: float) -> bool:
        """Deadline already missed at engine time ``now`` (always False
        without a deadline, or before the request has even arrived)."""
        return (self.deadline_s is not None
                and self.arrival_time <= now
                and now - self.arrival_time > self.deadline_s)


@dataclass
class StepPlan:
    prefills: List[Request]             # admitted this step (slot assigned)
    decode_slots: List[int]             # slots active after the prefills
    # requests the scheduling pass terminated (expired / shed /
    # cancelled) — the engine finishes their metrics/obs bookkeeping
    finished: List[Request] = field(default_factory=list)


class ContinuousScheduler:
    """Admission control for the serving engine: maps queued requests to
    decode slots and meters their KV pages through the shared
    :class:`~repro.serving.kv_pool.KVBlockPool`.

    ``prefill_chunk`` (when the engine streams prompts in chunks) makes
    incremental-mode page reservations *chunk-incremental*: admission
    reserves only the first chunk's pages and each later chunk extends the
    table via :meth:`grow`, so a request preempted mid-prefill frees
    exactly the pages it has written — not a full-prompt reservation it
    never used.  Full-prompt reservation at admission (the pre-chunking
    behaviour) assumed the whole prompt lands in pages the same step it is
    admitted."""

    def __init__(self, num_slots: int, pool: KVBlockPool,
                 max_prefills_per_step: int = 1, reserve: str = "full",
                 token_overhead: int = 0,
                 prefill_chunk: Optional[int] = None,
                 tracker=None, prefix_cache=None, metrics=None):
        if reserve not in ("full", "incremental"):
            raise ValueError(reserve)
        self.num_slots = num_slots
        self.pool = pool
        # request-lifecycle span tracker (repro.obs.RequestTracker): the
        # scheduler owns the admit/preempt/retire transitions, so it is
        # the layer that stamps them into the trace
        self.tracker = tracker
        self.max_prefills_per_step = max_prefills_per_step
        self.reserve = reserve
        # extra KV rows every request's block table must also cover beyond
        # its text tokens — the vlm frontend's per-slot rows when the paged
        # arena stores them in pool pages (0 under the dense layout, where
        # that overhead lives outside the metered budget)
        self.token_overhead = token_overhead
        self.prefill_chunk = prefill_chunk
        # optional PrefixCache (serving/prefix_cache.py): admission matches
        # each prompt's longest cached prefix, shares those pages into the
        # new table, and reserves pool blocks only for the suffix
        self.prefix_cache = prefix_cache
        # optional ServingMetrics: the rolling-TTFT window feeds the
        # load-shedding estimate, and plan() counts cache-miss fallbacks
        self.metrics = metrics
        self.waiting: deque = deque()
        self.active: Dict[int, Request] = {}
        self._free_slots = list(range(num_slots - 1, -1, -1))

    # -- queue ----------------------------------------------------------------
    def submit(self, req: Request) -> None:
        # a request whose admission-time reservation exceeds the whole
        # pool can never be admitted: plan() would break on it (FCFS)
        # forever — reject up front instead of livelocking the queue
        # head.  The floor follows the reservation policy: full mode
        # reserves worst-case (prompt + max_new + 1) at admit time, so
        # that whole footprint must fit; incremental modes only ever
        # need the prompt's pages live at once to finish a prefill.
        if self.reserve == "full":
            floor_tokens = (self.token_overhead + req.prompt_len
                            + req.max_new_tokens + 1)
            what = "worst-case reservation"
        else:
            floor_tokens = self.token_overhead + req.prompt_len
            what = "prompt"
        floor = self.pool.blocks_for(floor_tokens)
        if floor > self.pool.num_blocks:
            raise PoolError(
                f"request {req.rid}: {what} needs {floor} blocks, pool has "
                f"{self.pool.num_blocks} — can never be admitted")
        self.waiting.append(req)
        if self.tracker is not None:
            self.tracker.on_submit(req.rid, prompt_len=req.prompt_len,
                                   max_new=req.max_new_tokens)

    def pending(self) -> int:
        return len(self.waiting)

    def idle(self) -> bool:
        return not self.waiting and not self.active

    # -- planning -------------------------------------------------------------
    def _reservation(self, req: Request, cached_tokens: int = 0) -> int:
        if self.reserve == "full":
            return self.token_overhead + req.prompt_len + req.max_new_tokens + 1
        if self.prefill_chunk:
            # chunk-incremental: admission covers only the first chunk's
            # rows (+ the per-request overhead); every later chunk and
            # decoded token extends through grow(), so mid-prefill
            # preemption frees exactly what was written.  A cache hit
            # starts the first chunk at the cached offset, so the
            # reservation covers the shared pages plus one chunk.
            return self.token_overhead + min(cached_tokens + self.prefill_chunk,
                                             req.context_len)
        return self.token_overhead + req.context_len + 1

    def _match_prefix(self, req: Request):
        """(pages, cached_offset) for the head-of-queue request: the
        longest cached prefix's pages and the context position prefill
        resumes from.  The offset is capped at ``prompt_len - 1`` so at
        least one suffix token is always recomputed — the final chunk must
        emit first-token logits even when the cache covers the whole
        prompt (the write into that last shared page is what exercises
        copy-on-write)."""
        if self.prefix_cache is None or not self.prefill_chunk:
            return [], 0
        pages = self.prefix_cache.match(req.prompt)
        if not pages:
            return [], 0
        offset = min(len(pages) * self.pool.block_size, req.prompt_len - 1)
        return pages, offset

    def plan(self, now: float = float("inf")) -> StepPlan:
        """Terminate cancelled/expired requests, shed admissions that can
        no longer meet their deadline, then admit up to
        ``max_prefills_per_step`` arrived requests into free slots, KV
        budget permitting, then decode every active slot.  (``now`` =
        inf, the no-clock default, disables the deadline machinery —
        there is no time to judge a deadline against.)"""
        finished: List[Request] = []
        timed = np.isfinite(now)
        # cancellation reaches active lanes too: their slot and pages
        # free here, before admission can use them
        for req in [r for r in self.active.values() if r.cancel_requested]:
            self.finish(req, "cancelled", now)
            finished.append(req)
        for req in [r for r in self.waiting
                    if r.cancel_requested or (timed and r.expired_at(now))]:
            self.finish(req, "cancelled" if req.cancel_requested
                        else "expired", now)
            finished.append(req)
        prefills: List[Request] = []
        while (len(prefills) < self.max_prefills_per_step
               and self._free_slots and self.waiting
               and self.waiting[0].arrival_time <= now):
            req = self.waiting[0]
            # load shedding: when the live TTFT estimate already exceeds
            # the head's remaining deadline budget, admitting it would
            # only burn pool pages on a doomed request — drop it now,
            # with its own terminal outcome so callers can retry later
            if timed and req.deadline_s is not None \
                    and self.metrics is not None:
                est = self.metrics.ttft_estimate()
                if est is not None and \
                        (now - req.arrival_time) + est > req.deadline_s:
                    self.waiting.popleft()
                    self.finish(req, "shed", now)
                    finished.append(req)
                    continue
            pages, offset = self._match_prefix(req)
            reservation = self._reservation(req, cached_tokens=offset)
            need_new = self.pool.blocks_for(reservation) - len(pages)
            if need_new > self.pool.num_free:
                # pool pressure: reclaim LRU unpinned cache entries before
                # giving up on the queue head.  The matched pages are
                # excluded — no table references them yet (pin-only), so
                # eviction of their trie descendants would otherwise
                # expose them as evictable leaves and share() below would
                # hit a dead page.
                if self.prefix_cache is not None:
                    self.prefix_cache.evict(need_new - self.pool.num_free,
                                            exclude=pages)
                if need_new > self.pool.num_free and pages:
                    # still short while protecting the hit: give the hit
                    # up and retry as a cache miss, which makes the
                    # matched pages themselves reclaimable
                    pages, offset = [], 0
                    reservation = self._reservation(req, cached_tokens=0)
                    need_new = self.pool.blocks_for(reservation)
                    if need_new > self.pool.num_free:
                        self.prefix_cache.evict(
                            need_new - self.pool.num_free)
                    self._count_fallback(req)
                if need_new > self.pool.num_free:
                    break                # FCFS: don't starve the head
            self.waiting.popleft()
            req.slot = self._free_slots.pop()
            req.t_admit = now if now != float("inf") else req.arrival_time
            req.prefilling = True
            if pages:
                # map the cached prefix pages, then reserve the suffix
                self.pool.share(req.rid, pages)
                self.pool.extend(req.rid, max(
                    reservation, len(pages) * self.pool.block_size))
                req.prefill_pos = offset
                req.cached_prefix_tokens = offset
                req.cached_pages = len(pages)
            else:
                self.pool.alloc(req.rid, reservation)
                req.prefill_pos = 0
                req.cached_prefix_tokens = 0
                req.cached_pages = 0
            if self.prefix_cache is not None and self.prefill_chunk:
                self.prefix_cache.record_lookup(len(pages))
            self.active[req.slot] = req
            prefills.append(req)
            if self.tracker is not None:
                self.tracker.on_admit(req.rid, slot=req.slot)
        return StepPlan(prefills, sorted(self.active), finished)

    def _count_fallback(self, req: Request) -> None:
        """A matched prefix was abandoned under pool pressure and the
        admission retried as a cache miss.  Count it: each fallback
        silently re-prefills tokens the cache had, so a storm of these
        erases the prefix-cache win while hit-rate still looks healthy."""
        if self.metrics is not None:
            self.metrics.prefix_cache_fallbacks += 1
        if self.tracker is not None:
            rec = self.tracker.rec
            rec.count("prefix_cache_fallbacks", 1)
            rec.instant("arena", "prefix_cache_fallback", track="arena",
                        rid=req.rid)

    # -- per-token growth (incremental mode) ----------------------------------
    def grow(self, req: Request, total_tokens: int) -> bool:
        """Ensure the request's block table covers ``total_tokens`` (plus
        the per-request ``token_overhead``); returns False (stall) when the
        pool cannot extend."""
        total_tokens += self.token_overhead
        table = self.pool.table(req.rid)
        if table.capacity(self.pool.block_size) >= total_tokens:
            table.num_tokens = max(table.num_tokens, total_tokens)
            req.stalled = False
            return True
        need = self.pool.blocks_for(total_tokens) - len(table.blocks)
        if need > self.pool.num_free and self.prefix_cache is not None:
            self.prefix_cache.evict(need - self.pool.num_free)
        try:
            self.pool.extend(req.rid, total_tokens)
            req.stalled = False
            return True
        except PoolError:
            req.stalled = True
            return False

    # -- retirement -----------------------------------------------------------
    def retire(self, req: Request, now: float = 0.0) -> None:
        del self.active[req.slot]
        self.pool.free(req.rid)
        self._free_slots.append(req.slot)
        req.t_done = now
        req.slot = -1
        if self.tracker is not None:
            self.tracker.on_retire(req.rid, tokens=len(req.generated))

    def finish(self, req: Request, outcome: str, now: float = 0.0,
               reason: str = "") -> None:
        """Terminally remove a request on a *failure* outcome (``failed``
        / ``expired`` / ``shed`` / ``cancelled``), queued or active:
        free its slot and pages and close its span with the outcome.
        ``retire`` remains the normal-completion path; engine-side
        bookkeeping (outcome counters, lane arrays) is the caller's job."""
        if req.slot >= 0 and self.active.get(req.slot) is req:
            del self.active[req.slot]
            self._free_slots.append(req.slot)
        else:
            try:
                self.waiting.remove(req)
            except ValueError:
                pass                    # already off the queue (shed path)
        if req.rid in self.pool.live_requests():
            self.pool.free(req.rid)
        req.slot = -1
        req.stalled = False
        req.prefilling = False
        req.outcome = outcome
        req.t_done = now if np.isfinite(now) else req.arrival_time
        if self.tracker is not None:
            self.tracker.on_finish(req.rid, outcome=outcome, reason=reason)

    # -- preemption -----------------------------------------------------------
    def preempt(self, req: Request) -> None:
        """Evict an admitted-but-unfinished request: free its slot and KV
        blocks and requeue it at the head (recompute-on-readmit).  Unlike
        ``retire`` this resets the lifecycle fields admission/stalling
        stamped — a preempted request is NOT done, so ``t_done`` must stay
        unset until a real retirement records it (metrics would otherwise
        inherit a stale completion time)."""
        del self.active[req.slot]
        self.pool.free(req.rid)
        self._free_slots.append(req.slot)
        req.slot = -1
        req.stalled = False
        req.prefilling = False       # recompute-on-readmit streams anew
        req.prefill_pos = 0
        req.cached_prefix_tokens = 0
        req.cached_pages = 0
        req.t_done = -1.0
        self.waiting.appendleft(req)
        if self.tracker is not None:
            self.tracker.on_preempt(req.rid, tokens=len(req.generated))
