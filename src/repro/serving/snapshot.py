"""Crash-safe serving-engine snapshot/restore.

A snapshot captures everything a mid-trace engine needs to resume
serving *exactly* where it stopped: KV storage (page arena or dense
slot caches), per-lane decode state, the scheduler's queue and slot
map, every live request's prompt/progress, the KV pool's ownership
state (free-list order included — future allocations must replay
identically), the prefix-cache trie, metrics, and the sampling PRNG
key.  Storage goes through :class:`repro.checkpoint.manager.
CheckpointManager` (atomic temp-dir + rename), so a crash mid-save
never corrupts the latest snapshot — the same contract the training
fault-tolerance loop relies on.

Restore targets a FRESH engine built with the same ``ArchConfig`` /
``EngineConfig`` (validated against the manifest): arrays are loaded
into the engine's own freshly-initialized pytree structures, request
objects and pool tables are rebuilt, and request lifecycle spans are
re-opened in the tracker so the close-exactly-once invariant keeps
holding across the restart.  Under the greedy (temperature=0) decode
path a restored engine produces token-for-token identical completions
for every surviving request — the kill-and-resume test asserts it.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.serving.kv_pool import BlockTable
from repro.serving.scheduler import Request

# EngineConfig fields that must match between snapshot and restore —
# anything that changes array shapes, allocation behaviour, or the
# token stream itself.
_SANITY = ("num_slots", "max_len", "block_size", "reserve", "temperature",
           "top_k", "seed", "prefill_chunk", "prefix_cache", "src_len")

_METRIC_SCALARS = (
    "decode_steps", "decode_tokens", "decode_s", "prefill_tokens",
    "prefill_s", "completed", "stalls", "preemptions", "failed", "expired",
    "shed", "cancelled", "rejected", "completed_in_deadline",
    "prefix_cache_fallbacks", "kv_read_tokens", "kv_read_tokens_dense",
    "prefill_kv_write_rows", "prefill_kv_write_rows_padded",
    "cache_hit_tokens", "cache_hit_pages", "prefill_flops_saved")
_METRIC_LISTS = ("ttft", "latency", "queue_delay", "slot_occupancy")

_POOL_COUNTERS = ("peak_in_use", "defrag_moves", "shared_pages",
                  "cow_copies", "poison_fills", "generation_faults",
                  "sanitize_checks")


def _path_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "name",
                    getattr(p, "idx", p)))) for p in path)


def _rebuild(template, flat: Dict[str, np.ndarray], prefix: str):
    """Load leaves for ``template``'s pytree structure from ``flat``
    (keys ``prefix/<path>`` — the same path scheme CheckpointManager's
    flatten uses, so save and restore cannot disagree on naming)."""
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves_p:
        key = f"{prefix}/{_path_key(path)}"
        if key not in flat:
            raise KeyError(f"snapshot missing array {key!r}")
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def _req_meta(req: Request) -> Dict:
    return {
        "rid": req.rid,
        "max_new_tokens": int(req.max_new_tokens),
        "arrival_time": float(req.arrival_time),
        "eos_id": None if req.eos_id is None else int(req.eos_id),
        "deadline_s": (None if req.deadline_s is None
                       else float(req.deadline_s)),
        "slot": int(req.slot),
        "stalled": bool(req.stalled),
        "prefilling": bool(req.prefilling),
        "prefill_pos": int(req.prefill_pos),
        "cached_prefix_tokens": int(req.cached_prefix_tokens),
        "cached_pages": int(req.cached_pages),
        "preempt_count": int(req.preempt_count),
        "t_admit": float(req.t_admit),
        "t_first_token": float(req.t_first_token),
    }


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------

def save_engine(engine, directory: str, blocking: bool = True) -> int:
    """Write one restorable snapshot of ``engine`` under ``directory``
    (step-numbered by the engine's step index).  Returns that step."""
    sched, pool = engine.sched, engine.pool
    live: List[Request] = (list(sched.waiting)
                           + [sched.active[s] for s in sorted(sched.active)])

    tree: Dict = {"last_tok": np.asarray(engine._last_tok),
                  "rng_key": np.asarray(engine._key)}
    if engine.kv_layout == "paged":
        tree["arena"] = engine.arena.leaves
        tree["state"] = engine._state
        tree["kv_rows"] = np.asarray(engine._kv_rows)
    else:
        tree["cache"] = engine._cache
    reqs: Dict[str, Dict] = {}
    for i, r in enumerate(live):
        entry: Dict = {"prompt": np.asarray(r.prompt, np.int32),
                       "generated": np.asarray(r.generated, np.int32)}
        if r.extras:
            entry["extras"] = {k: np.asarray(v)
                               for k, v in r.extras.items()}
        reqs[str(i)] = entry
    if reqs:
        tree["req"] = reqs

    metrics = {k: getattr(engine.metrics, k) for k in _METRIC_SCALARS}
    metrics.update({k: list(getattr(engine.metrics, k))
                    for k in _METRIC_LISTS})
    metrics["windows"] = {
        "ttft": list(engine.metrics._ttft_win),
        "latency": list(engine.metrics._latency_win),
        "decode": [list(x) for x in engine.metrics._decode_win],
    }
    meta = {
        "arch": engine.cfg.name,
        "kv_layout": engine.kv_layout,
        "engine": {k: getattr(engine.ecfg, k) for k in _SANITY},
        "vtime": float(engine._vtime),
        "step_idx": int(engine._step_idx),
        "waiting": [r.rid for r in sched.waiting],
        "active": {str(s): sched.active[s].rid for s in sched.active},
        "free_slots": [int(s) for s in sched._free_slots],
        "requests": [_req_meta(r) for r in live],
        "pool": {
            "free": [int(b) for b in pool._free],
            "refs": list(pool._refs),
            "pins": list(pool._pins),
            "gen": list(pool._gen),
            "tables": {rid: {"blocks": list(t.blocks),
                             "num_tokens": int(t.num_tokens)}
                       for rid, t in pool._tables.items()},
            "counters": {k: getattr(pool, k) for k in _POOL_COUNTERS},
        },
        "metrics": metrics,
    }
    if engine.prefix_cache is not None:
        meta["prefix_cache"] = engine.prefix_cache.export_state()

    mgr = CheckpointManager(directory)
    step = int(engine._step_idx)
    mgr.save(step, tree, metadata=meta, blocking=blocking)
    return step


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------

def restore_engine(engine, directory: str,
                   step: Optional[int] = None) -> int:
    """Load a snapshot into a freshly-constructed engine (same configs,
    nothing submitted yet).  Returns the restored step index."""
    if engine.requests or not engine.sched.idle():
        raise ValueError("restore needs a fresh engine: requests were "
                         "already submitted to this one")
    mgr = CheckpointManager(directory)
    step, flat, meta = mgr.restore_flat(step)

    if meta["arch"] != engine.cfg.name:
        raise ValueError(f"snapshot is for arch {meta['arch']!r}, engine "
                         f"runs {engine.cfg.name!r}")
    if meta["kv_layout"] != engine.kv_layout:
        raise ValueError(f"snapshot kv_layout {meta['kv_layout']!r} != "
                         f"engine {engine.kv_layout!r}")
    for k in _SANITY:
        want, have = meta["engine"][k], getattr(engine.ecfg, k)
        if want != have:
            raise ValueError(f"snapshot EngineConfig.{k}={want!r} != "
                             f"engine {have!r}")

    # -- arrays ------------------------------------------------------------
    engine._last_tok = np.asarray(flat["last_tok"], np.int32)
    engine._key = jnp.asarray(flat["rng_key"])
    if engine.kv_layout == "paged":
        engine.arena.leaves = _rebuild(engine.arena.leaves, flat, "arena")
        engine._state = _rebuild(engine._state, flat, "state")
        engine._kv_rows = np.asarray(flat["kv_rows"], np.int32)
    else:
        engine._cache = _rebuild(engine._cache, flat, "cache")

    # -- pool --------------------------------------------------------------
    pool, pm = engine.pool, meta["pool"]
    pool._free = deque(int(b) for b in pm["free"])
    pool._refs = [int(x) for x in pm["refs"]]
    pool._pins = [int(x) for x in pm["pins"]]
    pool._gen = [int(x) for x in pm["gen"]]
    pool._tables = {
        rid: BlockTable(rid, blocks=[int(b) for b in t["blocks"]],
                        num_tokens=int(t["num_tokens"]))
        for rid, t in pm["tables"].items()}
    for k, v in pm["counters"].items():
        setattr(pool, k, v)

    # -- requests + scheduler ---------------------------------------------
    by_rid: Dict[str, Request] = {}
    for i, m in enumerate(meta["requests"]):
        extras_keys = [k for k in flat if k.startswith(f"req/{i}/extras/")]
        extras = ({k.rsplit("/", 1)[1]: flat[k] for k in extras_keys}
                  or None)
        req = Request(rid=m["rid"],
                      prompt=np.asarray(flat[f"req/{i}/prompt"], np.int32),
                      max_new_tokens=m["max_new_tokens"],
                      arrival_time=m["arrival_time"], eos_id=m["eos_id"],
                      extras=extras, deadline_s=m["deadline_s"])
        req.generated = [int(x) for x in flat[f"req/{i}/generated"]]
        req.slot = m["slot"]
        req.stalled = m["stalled"]
        req.prefilling = m["prefilling"]
        req.prefill_pos = m["prefill_pos"]
        req.cached_prefix_tokens = m["cached_prefix_tokens"]
        req.cached_pages = m["cached_pages"]
        req.preempt_count = m["preempt_count"]
        req.t_admit = m["t_admit"]
        req.t_first_token = m["t_first_token"]
        by_rid[req.rid] = req
    sched = engine.sched
    sched.waiting = deque(by_rid[rid] for rid in meta["waiting"])
    sched.active = {int(s): by_rid[rid]
                    for s, rid in meta["active"].items()}
    sched._free_slots = [int(s) for s in meta["free_slots"]]
    engine.requests = dict(by_rid)
    # re-open lifecycle spans so close-exactly-once holds across restarts
    for rid in meta["waiting"]:
        r = by_rid[rid]
        engine.req_spans.on_submit(rid, prompt_len=r.prompt_len,
                                   max_new=r.max_new_tokens)
    for s, rid in sorted(meta["active"].items()):
        r = by_rid[rid]
        engine.req_spans.on_submit(rid, prompt_len=r.prompt_len,
                                   max_new=r.max_new_tokens)
        engine.req_spans.on_admit(rid, slot=r.slot)

    # -- prefix cache ------------------------------------------------------
    if engine.prefix_cache is not None and "prefix_cache" in meta:
        engine.prefix_cache.restore_state(meta["prefix_cache"])

    # -- metrics -----------------------------------------------------------
    mm = meta["metrics"]
    for k in _METRIC_SCALARS:
        setattr(engine.metrics, k, mm[k])
    for k in _METRIC_LISTS:
        setattr(engine.metrics, k, list(mm[k]))
    engine.metrics._ttft_win.extend(mm["windows"]["ttft"])
    engine.metrics._latency_win.extend(mm["windows"]["latency"])
    engine.metrics._decode_win.extend(
        tuple(x) for x in mm["windows"]["decode"])

    engine._vtime = float(meta["vtime"])
    engine._step_idx = int(meta["step_idx"])
    return step
