"""Continuous-batching serving subsystem (ROADMAP north-star: production
serving of shape-diverse traffic — the serving-side analogue of the paper's
utilization argument).

  kv_pool    paged KV-cache block pool + the physical page arena (KVArena)
             it meters: fixed-size blocks, per-request block tables,
             alloc/extend/free, defrag that compacts storage in place
  scheduler  request queue + continuous batching into fixed decode slots,
             with chunk-incremental page reservations under chunked prefill
  engine     ServingEngine: chunked paged prefill (ragged per-row lengths,
             KV rows written straight into pages) or padded-bucket prefill,
             plus paged flash-decode through per-slot block tables (dense
             vmapped decode for recurrent-state families); every GEMM site
             routed through the SARA dispatch layer
  metrics    TTFT / latency percentiles (lifetime + rolling-window twins)
             / tokens-per-second / slot utilization / KV rows streamed per
             decode step / prefill KV rows written vs the padded-bucket
             equivalent
  faults     terminal request outcomes (failed/expired/shed/cancelled/
             rejected), fault attribution for the engine's step error
             boundary, and the seed-driven chaos-injection harness
             (EngineConfig.chaos)
  snapshot   crash-safe engine snapshot/restore through
             checkpoint/manager (EngineConfig.snapshot_dir)

Every layer also reports into the ``repro.obs`` trace recorder the engine
owns: request-lifecycle spans, a per-step phase timeline, KV-arena and
jit-compile events — exportable as a Chrome/Perfetto trace when
``EngineConfig.trace`` (``serve --trace-out``) is set.  See
docs/SERVING.md for the request lifecycle and page accounting,
docs/OBSERVABILITY.md for the trace schema.
"""

from repro.serving.engine import EngineConfig, ServingEngine, sample_logits
from repro.serving.faults import (OUTCOME_COUNTERS, OUTCOMES, ChaosConfig,
                                  FaultInjector, attach_rids, fault_rids)
from repro.serving.kv_pool import (KVArena, KVBlockPool, PoolError,
                                   SanitizerError)
from repro.serving.metrics import ServingMetrics
from repro.serving.scheduler import ContinuousScheduler, Request

__all__ = ["EngineConfig", "ServingEngine", "sample_logits", "KVArena",
           "KVBlockPool", "PoolError", "SanitizerError", "ServingMetrics",
           "ContinuousScheduler", "Request", "OUTCOMES", "OUTCOME_COUNTERS",
           "ChaosConfig", "FaultInjector", "attach_rids", "fault_rids"]
