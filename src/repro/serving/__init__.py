"""Continuous-batching serving subsystem (ROADMAP north-star: production
serving of shape-diverse traffic — the serving-side analogue of the paper's
utilization argument).

  kv_pool    paged KV-cache block pool: fixed-size blocks, per-request block
             tables, alloc/extend/free/defrag, admission accounting
  scheduler  request queue + continuous batching into fixed decode slots
  engine     ServingEngine: jitted bucketed prefill + vmapped slot decode,
             every GEMM site routed through SaraDispatcher.recommend
  metrics    TTFT / latency percentiles / tokens-per-second / slot utilization
"""

from repro.serving.engine import EngineConfig, ServingEngine, sample_logits
from repro.serving.kv_pool import KVBlockPool
from repro.serving.metrics import ServingMetrics
from repro.serving.scheduler import ContinuousScheduler, Request

__all__ = ["EngineConfig", "ServingEngine", "sample_logits", "KVBlockPool",
           "ServingMetrics", "ContinuousScheduler", "Request"]
