"""Paged KV-cache block pool.

The pool divides the KV-cache budget into fixed-size blocks of
``block_size`` tokens and hands them out to requests on demand — the
admission-control half of continuous batching (cf. the paged backends in
vLLM/flashinfer).  Each live request owns a *block table*: the ordered list
of physical block ids backing its logical token range.  Blocks are
allocated lazily as a request's sequence crosses block boundaries and all
return to the free list when the request retires, so short requests stop
holding memory the moment they finish instead of at the end of a wave.

Physical layout: the engine's per-slot caches (``models/serving.py``
pytrees) are contiguous arenas; one slot spans ``slot_capacity //
block_size`` consecutive logical pages, so allocation never fails from
fragmentation and no data ever moves.  ``defrag()`` computes the
{old: new} remapping that compacts live block tables to the front — a
physically paged arena (the flashinfer-style layout ROADMAP names as a
follow-up) would mirror those moves in storage; today it is pool-level
bookkeeping only and the engine does not call it.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional


class PoolError(RuntimeError):
    pass


@dataclass
class BlockTable:
    """Ordered physical block ids backing one request's token range."""

    request_id: str
    blocks: List[int] = field(default_factory=list)
    num_tokens: int = 0

    def capacity(self, block_size: int) -> int:
        return len(self.blocks) * block_size


class KVBlockPool:
    """Fixed-size-block KV allocator with per-request block tables."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque = deque(range(num_blocks))
        self._owner: List[Optional[str]] = [None] * num_blocks
        self._tables: Dict[str, BlockTable] = {}
        self.peak_in_use = 0

    # -- accounting ----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def blocks_for(self, num_tokens: int) -> int:
        return -(-max(num_tokens, 0) // self.block_size)

    def can_alloc(self, num_tokens: int) -> bool:
        return self.blocks_for(num_tokens) <= self.num_free

    def utilization(self) -> float:
        return self.num_in_use / self.num_blocks

    def fragmentation(self) -> float:
        """Fraction of live block-table adjacencies that are physically
        non-contiguous (0.0 = fully compact)."""
        pairs = jumps = 0
        for t in self._tables.values():
            for a, b in zip(t.blocks, t.blocks[1:]):
                pairs += 1
                jumps += b != a + 1
        return jumps / pairs if pairs else 0.0

    def table(self, request_id: str) -> BlockTable:
        return self._tables[request_id]

    def live_requests(self) -> List[str]:
        return list(self._tables)

    # -- alloc / extend / free ----------------------------------------------
    def _take_block(self, request_id: str) -> int:
        bid = self._free.popleft()
        if self._owner[bid] is not None:
            raise PoolError(f"block {bid} double-allocated "
                            f"({self._owner[bid]} -> {request_id})")
        self._owner[bid] = request_id
        return bid

    def alloc(self, request_id: str, num_tokens: int) -> BlockTable:
        """Reserve blocks covering ``num_tokens`` for a new request."""
        if request_id in self._tables:
            raise PoolError(f"request {request_id} already has a block table")
        need = self.blocks_for(num_tokens)
        if need > self.num_free:
            raise PoolError(f"OOM: need {need} blocks, {self.num_free} free")
        t = BlockTable(request_id)
        for _ in range(need):
            t.blocks.append(self._take_block(request_id))
        t.num_tokens = num_tokens
        self._tables[request_id] = t
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        return t

    def extend(self, request_id: str, num_tokens: int) -> List[int]:
        """Grow a request's table to cover ``num_tokens`` total; returns the
        newly allocated block ids (empty if capacity already suffices)."""
        t = self._tables[request_id]
        if num_tokens < t.num_tokens:
            raise PoolError("extend cannot shrink a table")
        need = self.blocks_for(num_tokens) - len(t.blocks)
        if need > self.num_free:
            raise PoolError(f"OOM: need {need} blocks, {self.num_free} free")
        new = [self._take_block(request_id) for _ in range(need)]
        t.blocks.extend(new)
        t.num_tokens = num_tokens
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        return new

    def free(self, request_id: str) -> int:
        """Return every block owned by the request; returns the count."""
        t = self._tables.pop(request_id)
        for bid in t.blocks:
            if self._owner[bid] != request_id:
                raise PoolError(f"block {bid} not owned by {request_id}")
            self._owner[bid] = None
            self._free.append(bid)
        return len(t.blocks)

    # -- defrag --------------------------------------------------------------
    def defrag(self) -> Dict[int, int]:
        """Compact live blocks to the lowest physical ids (stable order:
        table order within request, requests by first block).  Returns the
        {old_id: new_id} moves a physically paged arena would mirror in
        storage."""
        order = sorted(self._tables.values(),
                       key=lambda t: t.blocks[0] if t.blocks else 0)
        moves: Dict[int, int] = {}
        nxt = 0
        new_owner: List[Optional[str]] = [None] * self.num_blocks
        for t in order:
            for i, bid in enumerate(t.blocks):
                if bid != nxt:
                    moves[bid] = nxt
                t.blocks[i] = nxt
                new_owner[nxt] = t.request_id
                nxt += 1
        self._owner = new_owner
        self._free = deque(range(nxt, self.num_blocks))
        return moves

    # -- invariant check (tests / debug) -------------------------------------
    def check(self) -> None:
        seen: Dict[int, str] = {}
        for t in self._tables.values():
            for bid in t.blocks:
                if bid in seen:
                    raise PoolError(f"block {bid} owned by both "
                                    f"{seen[bid]} and {t.request_id}")
                if self._owner[bid] != t.request_id:
                    raise PoolError(f"owner mismatch for block {bid}")
                seen[bid] = t.request_id
        if len(seen) + len(self._free) != self.num_blocks:
            raise PoolError("free list + live tables do not cover the pool")
