"""Paged KV-cache block pool + the physical arena it meters.

The pool divides the KV-cache budget into fixed-size blocks of
``block_size`` tokens and hands them out to requests on demand — the
admission-control half of continuous batching (cf. the paged backends in
vLLM/flashinfer).  Each live request owns a *block table*: the ordered list
of physical block ids backing its logical token range.  Blocks are
allocated lazily as a request's sequence crosses block boundaries and all
return to the free list when the request retires, so short requests stop
holding memory the moment they finish instead of at the end of a wave.

Physical layout: a pool can be *bound* to a :class:`KVArena` — the
per-layer K/V page tensors ``(layers, num_blocks + 1, block_size, *feat)``
the paged decode kernel (``kernels/paged_attn.py``) reads through dense
per-slot block tables.  Pool block id ``b`` IS arena page ``b``; the
arena's one extra trailing block is the engine's write-discard scratch for
masked decode lanes and is never pool-allocated.  ``defrag()`` computes the
{old: new} remapping that compacts live block tables to the front AND
applies it to the bound arena as one batched gather over the page axis, so
the freed tail is physically contiguous (the flashinfer-style layout the
ROADMAP named).  Unbound pools (the engine's dense fallback layout) keep
defrag as pure bookkeeping, exactly as before.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


class PoolError(RuntimeError):
    pass


class KVArena:
    """Physical KV pages for a :class:`KVBlockPool`.

    ``leaves`` maps names (``"k"``/``"v"``) to page tensors shaped
    ``(layers, num_blocks + 1, block_size, *feat)`` — built by
    ``models/serving.py::init_paged_arena``.  The trailing page is the
    write-discard scratch (``trash_block``).  The engine swaps ``leaves``
    functionally after every decode/prefill write; ``apply_moves`` mutates
    in place when ``defrag`` compacts the pool.
    """

    def __init__(self, leaves: Dict[str, Any], block_size: int):
        shapes = {k: v.shape for k, v in leaves.items()}
        nb = {s[1] for s in shapes.values()}
        bsz = {s[2] for s in shapes.values()}
        if len(nb) != 1 or bsz != {block_size}:
            raise ValueError(f"inconsistent arena leaves: {shapes}")
        self.leaves = leaves
        self.block_size = block_size
        self.num_blocks = nb.pop() - 1       # pool-allocatable pages

    @property
    def trash_block(self) -> int:
        return self.num_blocks

    def apply_moves(self, moves: Dict[int, int]) -> int:
        """Mirror a defrag move map in storage: one batched gather per leaf
        over the page axis (new page ``n`` takes old page ``moves^-1(n)``;
        untouched pages — including the trash page — map to themselves).
        Returns the number of pages moved."""
        if not moves:
            return 0
        import jax.numpy as jnp
        src = np.arange(self.num_blocks + 1)
        for old, new in moves.items():
            src[new] = old
        src = jnp.asarray(src, jnp.int32)
        self.leaves = {name: jnp.take(leaf, src, axis=1)
                       for name, leaf in self.leaves.items()}
        return len(moves)


@dataclass
class BlockTable:
    """Ordered physical block ids backing one request's token range."""

    request_id: str
    blocks: List[int] = field(default_factory=list)
    num_tokens: int = 0

    def capacity(self, block_size: int) -> int:
        return len(self.blocks) * block_size


class KVBlockPool:
    """Fixed-size-block KV allocator with per-request block tables.

    The admission-control half of paged KV: ``alloc`` / ``extend`` /
    ``free`` move blocks between the free list and per-request
    :class:`BlockTable`\\ s, ``can_alloc`` / ``blocks_for`` answer the
    scheduler's budget questions, ``dense_block_table`` materializes the
    (slots, width) int32 tables the paged kernels consume, and ``defrag``
    compacts live blocks to the front (mirroring moves into the bound
    :class:`KVArena`'s storage when one is attached via ``bind_arena``).
    ``check()`` asserts the ownership invariants; tests call it after
    every scenario."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque = deque(range(num_blocks))
        self._owner: List[Optional[str]] = [None] * num_blocks
        self._tables: Dict[str, BlockTable] = {}
        self.peak_in_use = 0
        self.arena: Optional[KVArena] = None
        self.defrag_moves = 0          # lifetime pages moved by defrag()
        # optional trace sink (repro.obs.TraceRecorder): reserve / grow /
        # free / defrag land as "arena" events + always-on counters
        self.recorder = None

    def attach_recorder(self, recorder) -> None:
        self.recorder = recorder

    def _trace(self, name: str, rid: str, blocks: int, **args) -> None:
        if self.recorder is None:
            return
        self.recorder.count(f"kv_{name}_blocks", blocks)
        self.recorder.instant("arena", name, track="arena", rid=rid,
                              blocks=blocks, in_use=self.num_in_use, **args)

    def bind_arena(self, arena: KVArena) -> None:
        """Attach physical page storage; defrag() moves now mirror into it."""
        if arena.num_blocks != self.num_blocks or \
                arena.block_size != self.block_size:
            raise ValueError(
                f"arena ({arena.num_blocks} blocks x {arena.block_size}) "
                f"does not match pool ({self.num_blocks} x {self.block_size})")
        self.arena = arena

    # -- accounting ----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def blocks_for(self, num_tokens: int) -> int:
        return -(-max(num_tokens, 0) // self.block_size)

    def can_alloc(self, num_tokens: int) -> bool:
        return self.blocks_for(num_tokens) <= self.num_free

    def utilization(self) -> float:
        return self.num_in_use / self.num_blocks

    def fragmentation(self) -> float:
        """Fraction of live block-table adjacencies that are physically
        non-contiguous (0.0 = fully compact)."""
        pairs = jumps = 0
        for t in self._tables.values():
            for a, b in zip(t.blocks, t.blocks[1:]):
                pairs += 1
                jumps += b != a + 1
        return jumps / pairs if pairs else 0.0

    def table(self, request_id: str) -> BlockTable:
        return self._tables[request_id]

    def live_requests(self) -> List[str]:
        return list(self._tables)

    @staticmethod
    def table_width(need: int, cap: int) -> int:
        """Block-table width for the paged decode kernel: the needed page
        count rounded up to a power of two (one jit compilation per width
        bucket), clamped to the per-slot maximum."""
        width = 1
        while width < need:
            width *= 2
        return max(1, min(width, cap))

    def dense_block_table(self, rids: Sequence[Optional[str]],
                          width: int) -> np.ndarray:
        """(len(rids), width) int32 block table for the paged decode kernel:
        row i holds ``rids[i]``'s block ids in logical order, tail-padded
        with the last live id (consecutive grid steps mapping to the same
        page elide the DMA); ``None``/empty rows are all zeros (the kernel
        masks them out via length 0)."""
        t = np.zeros((len(rids), width), np.int32)
        for i, rid in enumerate(rids):
            if rid is None:
                continue
            blocks = self._tables[rid].blocks[:width]
            if blocks:
                t[i, :len(blocks)] = blocks
                t[i, len(blocks):] = blocks[-1]
        return t

    # -- alloc / extend / free ----------------------------------------------
    def _take_block(self, request_id: str) -> int:
        bid = self._free.popleft()
        if self._owner[bid] is not None:
            raise PoolError(f"block {bid} double-allocated "
                            f"({self._owner[bid]} -> {request_id})")
        self._owner[bid] = request_id
        return bid

    def alloc(self, request_id: str, num_tokens: int) -> BlockTable:
        """Reserve blocks covering ``num_tokens`` for a new request."""
        if request_id in self._tables:
            raise PoolError(f"request {request_id} already has a block table")
        need = self.blocks_for(num_tokens)
        if need > self.num_free:
            raise PoolError(f"OOM: need {need} blocks, {self.num_free} free")
        t = BlockTable(request_id)
        for _ in range(need):
            t.blocks.append(self._take_block(request_id))
        t.num_tokens = num_tokens
        self._tables[request_id] = t
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        self._trace("reserve", request_id, need, tokens=num_tokens)
        return t

    def extend(self, request_id: str, num_tokens: int) -> List[int]:
        """Grow a request's table to cover ``num_tokens`` total; returns the
        newly allocated block ids (empty if capacity already suffices)."""
        t = self._tables[request_id]
        if num_tokens < t.num_tokens:
            raise PoolError("extend cannot shrink a table")
        need = self.blocks_for(num_tokens) - len(t.blocks)
        if need > self.num_free:
            raise PoolError(f"OOM: need {need} blocks, {self.num_free} free")
        new = [self._take_block(request_id) for _ in range(need)]
        t.blocks.extend(new)
        t.num_tokens = num_tokens
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        if new:
            self._trace("grow", request_id, len(new), tokens=num_tokens)
        return new

    def free(self, request_id: str) -> int:
        """Return every block owned by the request; returns the count."""
        t = self._tables.pop(request_id)
        for bid in t.blocks:
            if self._owner[bid] != request_id:
                raise PoolError(f"block {bid} not owned by {request_id}")
            self._owner[bid] = None
            self._free.append(bid)
        self._trace("free", request_id, len(t.blocks))
        return len(t.blocks)

    # -- defrag --------------------------------------------------------------
    def defrag(self) -> Dict[int, int]:
        """Compact live blocks to the lowest physical ids (stable order:
        table order within request, requests by first block) and mirror the
        moves into the bound arena's page storage (a single batched gather
        per K/V leaf).  Returns the {old_id: new_id} move map; afterwards
        the free list is the contiguous tail."""
        order = sorted(self._tables.values(),
                       key=lambda t: t.blocks[0] if t.blocks else 0)
        moves: Dict[int, int] = {}
        nxt = 0
        new_owner: List[Optional[str]] = [None] * self.num_blocks
        for t in order:
            for i, bid in enumerate(t.blocks):
                if bid != nxt:
                    moves[bid] = nxt
                t.blocks[i] = nxt
                new_owner[nxt] = t.request_id
                nxt += 1
        self._owner = new_owner
        self._free = deque(range(nxt, self.num_blocks))
        if self.arena is not None:
            # the counter records physical page moves, so it only advances
            # when storage is bound (unbound defrag is table bookkeeping)
            self.arena.apply_moves(moves)
            self.defrag_moves += len(moves)
        self._trace("defrag", "_pool", len(moves),
                    storage_moved=self.arena is not None)
        return moves

    # -- invariant check (tests / debug) -------------------------------------
    def check(self) -> None:
        seen: Dict[int, str] = {}
        for t in self._tables.values():
            for bid in t.blocks:
                if bid in seen:
                    raise PoolError(f"block {bid} owned by both "
                                    f"{seen[bid]} and {t.request_id}")
                if self._owner[bid] != t.request_id:
                    raise PoolError(f"owner mismatch for block {bid}")
                seen[bid] = t.request_id
        if len(seen) + len(self._free) != self.num_blocks:
            raise PoolError("free list + live tables do not cover the pool")
