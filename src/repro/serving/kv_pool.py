"""Paged KV-cache block pool + the physical arena it meters.

The pool divides the KV-cache budget into fixed-size blocks of
``block_size`` tokens and hands them out to requests on demand — the
admission-control half of continuous batching (cf. the paged backends in
vLLM/flashinfer).  Each live request owns a *block table*: the ordered list
of physical block ids backing its logical token range.  Blocks are
allocated lazily as a request's sequence crosses block boundaries and all
return to the free list when the request retires, so short requests stop
holding memory the moment they finish instead of at the end of a wave.

Physical layout: a pool can be *bound* to a :class:`KVArena` — the
per-layer K/V page tensors ``(layers, num_blocks + 1, block_size, *feat)``
the paged decode kernel (``kernels/paged_attn.py``) reads through dense
per-slot block tables.  Pool block id ``b`` IS arena page ``b``; the
arena's one extra trailing block is the engine's write-discard scratch for
masked decode lanes and is never pool-allocated.  ``defrag()`` computes the
{old: new} remapping that compacts live block tables to the front AND
applies it to the bound arena as one batched gather over the page axis, so
the freed tail is physically contiguous (the flashinfer-style layout the
ROADMAP named).  Unbound pools (the engine's dense fallback layout) keep
defrag as pure bookkeeping, exactly as before.

Sharing (prefix caching): pages are *refcounted*.  ``share(rid, pages)``
maps already-written pages into a new request's table without copying —
the vLLM block-pool move that makes cross-request prefix reuse free.  Two
counters guard each page: ``_refs`` (how many block tables name it) and
``_pins`` (whether the prefix cache holds it); a page returns to the free
list only when both hit zero.  ``ensure_writable(rid, i)`` is the
copy-on-write gate: before a request writes into logical page ``i``, a
page that is shared (refs > 1) or cached (pinned) is replaced by a fresh
private copy (one page gather in the bound arena), so the sibling readers
never observe the write.  ``defrag()`` moves only exclusively-owned,
unpinned pages — shared/pinned pages are landmarks other tables and the
cache index at by physical id.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


class PoolError(RuntimeError):
    pass


class SanitizerError(PoolError):
    """A sanitize-mode trap fired: use-after-free through a stale block
    table, a poisoned page read, or a refcount/pin leak at teardown."""


class KVArena:
    """Physical KV pages for a :class:`KVBlockPool`.

    ``leaves`` maps names (``"k"``/``"v"``) to page tensors shaped
    ``(layers, num_blocks + 1, block_size, *feat)`` — built by
    ``models/serving.py::init_paged_arena``.  The trailing page is the
    write-discard scratch (``trash_block``).  The engine swaps ``leaves``
    functionally after every decode/prefill write; ``apply_moves`` mutates
    in place when ``defrag`` compacts the pool.
    """

    def __init__(self, leaves: Dict[str, Any], block_size: int):
        shapes = {k: v.shape for k, v in leaves.items()}
        nb = {s[1] for s in shapes.values()}
        bsz = {s[2] for s in shapes.values()}
        if len(nb) != 1 or bsz != {block_size}:
            raise ValueError(f"inconsistent arena leaves: {shapes}")
        self.leaves = leaves
        self.block_size = block_size
        self.num_blocks = nb.pop() - 1       # pool-allocatable pages

    @property
    def trash_block(self) -> int:
        return self.num_blocks

    def apply_moves(self, moves: Dict[int, int]) -> int:
        """Mirror a defrag move map in storage: one batched gather per leaf
        over the page axis (new page ``n`` takes old page ``moves^-1(n)``;
        untouched pages — including the trash page — map to themselves).
        Returns the number of pages moved."""
        if not moves:
            return 0
        import jax.numpy as jnp
        src = np.arange(self.num_blocks + 1)
        for old, new in moves.items():
            src[new] = old
        src = jnp.asarray(src, jnp.int32)
        self.leaves = {name: jnp.take(leaf, src, axis=1)
                       for name, leaf in self.leaves.items()}
        return len(moves)

    def copy_page(self, src: int, dst: int) -> None:
        """Copy one physical page (copy-on-write divergence): every leaf's
        page ``dst`` becomes a copy of page ``src``."""
        self.leaves = {name: leaf.at[:, dst].set(leaf[:, src])
                       for name, leaf in self.leaves.items()}

    def poison_page(self, bid: int) -> None:
        """Sanitize mode: fill a just-freed page with NaN so any read
        through a stale block table surfaces as NaN logits instead of
        silently serving another request's KV rows.  Never applied to the
        trash page — masked-lane writes legitimately land there."""
        import jax.numpy as jnp
        self.leaves = {name: leaf.at[:, bid].set(jnp.nan)
                       for name, leaf in self.leaves.items()}

    def unpoison_page(self, bid: int) -> None:
        """Sanitize mode: zero a page on (re-)allocation, restoring the
        fresh-arena state.  Poison therefore lives ONLY on currently-free
        pages — the decode kernel reads whole pages and masks tail rows
        as ``0 * row``, so a re-used page's not-yet-written rows must be
        finite for live lanes while any read of a *free* page still traps
        (the ASan poison-on-free / unpoison-on-malloc discipline)."""
        self.leaves = {name: leaf.at[:, bid].set(0)
                       for name, leaf in self.leaves.items()}


@dataclass
class BlockTable:
    """Ordered physical block ids backing one request's token range."""

    request_id: str
    blocks: List[int] = field(default_factory=list)
    num_tokens: int = 0

    def capacity(self, block_size: int) -> int:
        return len(self.blocks) * block_size


class KVBlockPool:
    """Fixed-size-block KV allocator with per-request block tables.

    The admission-control half of paged KV: ``alloc`` / ``extend`` /
    ``free`` move blocks between the free list and per-request
    :class:`BlockTable`\\ s, ``can_alloc`` / ``blocks_for`` answer the
    scheduler's budget questions, ``dense_block_table`` materializes the
    (slots, width) int32 tables the paged kernels consume, and ``defrag``
    compacts live blocks to the front (mirroring moves into the bound
    :class:`KVArena`'s storage when one is attached via ``bind_arena``).
    ``check()`` asserts the ownership invariants; tests call it after
    every scenario.

    Pages are refcounted for cross-request sharing: ``share`` maps live
    pages into a new table, ``pin``/``unpin`` add a cache reference, and
    ``ensure_writable`` performs copy-on-write before a request mutates a
    page other owners can still see."""

    def __init__(self, num_blocks: int, block_size: int, *,
                 sanitize: bool = False):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque = deque(range(num_blocks))
        self._refs: List[int] = [0] * num_blocks   # block-table references
        self._pins: List[int] = [0] * num_blocks   # prefix-cache references
        self._tables: Dict[str, BlockTable] = {}
        self.peak_in_use = 0
        self.arena: Optional[KVArena] = None
        self.defrag_moves = 0          # lifetime pages moved by defrag()
        self.shared_pages = 0          # lifetime pages mapped via share()
        self.cow_copies = 0            # lifetime copy-on-write divergences
        # sanitize mode: freed pages are NaN-poisoned in the bound arena
        # and every allocation bumps the page's generation counter, so a
        # stale block table (use-after-free) is trappable by generation
        # mismatch or by poison surfacing in decode logits.
        self.sanitize = sanitize
        self._gen: List[int] = [0] * num_blocks    # bumped per allocation
        self.poison_fills = 0          # lifetime pages NaN-poisoned
        self.generation_faults = 0     # stale-table traps fired
        self.sanitize_checks = 0       # check()/assert_generations runs
        # optional trace sink (repro.obs.TraceRecorder): reserve / grow /
        # free / defrag / share / cow land as "arena" events + counters
        self.recorder = None

    def attach_recorder(self, recorder) -> None:
        self.recorder = recorder

    def _trace(self, name: str, rid: str, blocks: int, **args) -> None:
        if self.recorder is None:
            return
        self.recorder.count(f"kv_{name}_blocks", blocks)
        self.recorder.instant("arena", name, track="arena", rid=rid,
                              blocks=blocks, in_use=self.num_in_use, **args)

    def bind_arena(self, arena: KVArena) -> None:
        """Attach physical page storage; defrag() moves now mirror into it."""
        if arena.num_blocks != self.num_blocks or \
                arena.block_size != self.block_size:
            raise ValueError(
                f"arena ({arena.num_blocks} blocks x {arena.block_size}) "
                f"does not match pool ({self.num_blocks} x {self.block_size})")
        self.arena = arena

    # -- accounting ----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def blocks_for(self, num_tokens: int) -> int:
        return -(-max(num_tokens, 0) // self.block_size)

    def can_alloc(self, num_tokens: int) -> bool:
        return self.blocks_for(num_tokens) <= self.num_free

    def utilization(self) -> float:
        return self.num_in_use / self.num_blocks

    def fragmentation(self) -> float:
        """Fraction of live block-table adjacencies that are physically
        non-contiguous (0.0 = fully compact)."""
        pairs = jumps = 0
        for t in self._tables.values():
            for a, b in zip(t.blocks, t.blocks[1:]):
                pairs += 1
                jumps += b != a + 1
        return jumps / pairs if pairs else 0.0

    def table(self, request_id: str) -> BlockTable:
        return self._tables[request_id]

    def live_requests(self) -> List[str]:
        return list(self._tables)

    @staticmethod
    def table_width(need: int, cap: int) -> int:
        """Block-table width for the paged decode kernel: the needed page
        count rounded up to a power of two (one jit compilation per width
        bucket), clamped to the per-slot maximum."""
        width = 1
        while width < need:
            width *= 2
        return max(1, min(width, cap))

    def dense_block_table(self, rids: Sequence[Optional[str]],
                          width: int) -> np.ndarray:
        """(len(rids), width) int32 block table for the paged decode kernel:
        row i holds ``rids[i]``'s block ids in logical order, tail-padded
        with the last live id (consecutive grid steps mapping to the same
        page elide the DMA); ``None``/empty rows are all zeros (the kernel
        masks them out via length 0)."""
        t = np.zeros((len(rids), width), np.int32)
        for i, rid in enumerate(rids):
            if rid is None:
                continue
            blocks = self._tables[rid].blocks[:width]
            if blocks:
                t[i, :len(blocks)] = blocks
                t[i, len(blocks):] = blocks[-1]
        return t

    def refcount(self, bid: int) -> int:
        return self._refs[bid]

    def pincount(self, bid: int) -> int:
        return self._pins[bid]

    def generation(self, bid: int) -> int:
        return self._gen[bid]

    # -- sanitizer: generation tags + leak audit -----------------------------
    def table_generations(self, rids: Sequence[Optional[str]],
                          width: int) -> np.ndarray:
        """Generation stamp per :meth:`dense_block_table` entry, captured
        at table-build time.  ``assert_generations`` replays the pair to
        trap tables consumed after their pages were reclaimed."""
        g = np.zeros((len(rids), width), np.int64)
        for i, rid in enumerate(rids):
            if rid is None:
                continue
            blocks = self._tables[rid].blocks[:width]
            if blocks:
                gens = [self._gen[b] for b in blocks]
                g[i, :len(gens)] = gens
                g[i, len(gens):] = gens[-1]
        return g

    def assert_generations(self, rids: Sequence[Optional[str]],
                           tables: np.ndarray, gens: np.ndarray) -> None:
        """Trap use-after-free through a stale block table: every
        (page, generation) pair captured when the table was built must
        still be current — a page freed and re-allocated since then
        carries a later generation.  Raises :class:`SanitizerError`."""
        self.sanitize_checks += 1
        tables = np.asarray(tables)
        gens = np.asarray(gens)
        for i, rid in enumerate(rids):
            if rid is None:
                continue
            for j in range(tables.shape[1]):
                bid = int(tables[i, j])
                if self._gen[bid] != int(gens[i, j]):
                    self.generation_faults += 1
                    err = SanitizerError(
                        f"use-after-free: lane {i} ({rid}) block table names "
                        f"page {bid} at generation {int(gens[i, j])} but the "
                        f"page is now generation {self._gen[bid]} — it was "
                        "reclaimed and re-allocated after the table was "
                        "built")
                    # structured attribution: the engine's fault boundary
                    # fails exactly this request instead of the engine
                    err.rids = [str(rid)]
                    raise err

    def audit_leaks(self, expected_pins: Optional[Sequence[int]] = None
                    ) -> Dict[str, int]:
        """Teardown audit: after every request drains, no table may
        survive, no page may keep a table reference, and the pinned set
        must equal ``expected_pins`` (the prefix-cache trie's pages).
        Raises :class:`SanitizerError` on any leak; returns the totals
        the engine folds into ``summary()``."""
        if self._tables:
            raise SanitizerError(
                f"leak audit: {len(self._tables)} block table(s) never "
                f"freed: {sorted(self._tables)[:8]}")
        leaked = [b for b in range(self.num_blocks) if self._refs[b] != 0]
        if leaked:
            raise SanitizerError(
                f"leak audit: {len(leaked)} page(s) keep table references "
                f"with no live table: {leaked[:8]}")
        pinned = {b for b in range(self.num_blocks) if self._pins[b] > 0}
        if expected_pins is not None:
            expect = set(expected_pins)
            if pinned != expect:
                raise SanitizerError(
                    "leak audit: pinned pages disagree with the prefix "
                    f"cache trie (pinned-not-in-trie: "
                    f"{sorted(pinned - expect)[:8]}, trie-not-pinned: "
                    f"{sorted(expect - pinned)[:8]})")
        self.check()
        return {
            "kv_leaked_tables": 0,
            "kv_leaked_refs": 0,
            "kv_pinned_pages": len(pinned),
            "kv_poison_fills": self.poison_fills,
        }

    # -- alloc / extend / free ----------------------------------------------
    def _take_block(self, request_id: str) -> int:
        bid = self._free.popleft()
        if self._refs[bid] or self._pins[bid]:
            raise PoolError(f"block {bid} double-allocated "
                            f"(refs={self._refs[bid]} pins={self._pins[bid]} "
                            f"-> {request_id})")
        self._refs[bid] = 1
        self._gen[bid] += 1
        if self.sanitize and self.arena is not None:
            self.arena.unpoison_page(bid)
        return bid

    def _release_block(self, bid: int) -> None:
        """A page's last reference dropped: return it to the free list and,
        under sanitize with bound storage, NaN-poison its rows."""
        self._free.append(bid)
        if self.sanitize and self.arena is not None:
            self.arena.poison_page(bid)
            self.poison_fills += 1

    def alloc(self, request_id: str, num_tokens: int) -> BlockTable:
        """Reserve blocks covering ``num_tokens`` for a new request."""
        if request_id in self._tables:
            raise PoolError(f"request {request_id} already has a block table")
        need = self.blocks_for(num_tokens)
        if need > self.num_free:
            raise PoolError(f"OOM: need {need} blocks, {self.num_free} free")
        t = BlockTable(request_id)
        for _ in range(need):
            t.blocks.append(self._take_block(request_id))
        t.num_tokens = num_tokens
        self._tables[request_id] = t
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        self._trace("reserve", request_id, need, tokens=num_tokens)
        return t

    def extend(self, request_id: str, num_tokens: int) -> List[int]:
        """Grow a request's table to cover ``num_tokens`` total; returns the
        newly allocated block ids (empty if capacity already suffices)."""
        t = self._tables[request_id]
        if num_tokens < t.num_tokens:
            raise PoolError("extend cannot shrink a table")
        need = self.blocks_for(num_tokens) - len(t.blocks)
        if need > self.num_free:
            raise PoolError(f"OOM: need {need} blocks, {self.num_free} free")
        new = [self._take_block(request_id) for _ in range(need)]
        t.blocks.extend(new)
        t.num_tokens = num_tokens
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        if new:
            self._trace("grow", request_id, len(new), tokens=num_tokens)
        return new

    def free(self, request_id: str) -> int:
        """Release the request's reference on every block in its table;
        returns the number of pages actually reclaimed (a shared or pinned
        page outlives the release — its last owner reclaims it)."""
        t = self._tables.pop(request_id)
        released = 0
        for bid in t.blocks:
            if self._refs[bid] <= 0:
                raise PoolError(f"block {bid} freed with refcount 0 "
                                f"({request_id})")
            self._refs[bid] -= 1
            if self._refs[bid] == 0 and self._pins[bid] == 0:
                self._release_block(bid)
                released += 1
        self._trace("free", request_id, released, held=len(t.blocks))
        return released

    # -- sharing: refcounts, pins, copy-on-write -----------------------------
    def share(self, request_id: str, pages: Sequence[int]) -> BlockTable:
        """Map already-written live pages into a new request's table without
        copying (one new table reference per page).  The table's initial
        ``num_tokens`` is the shared pages' full capacity; the caller
        ``extend``\\ s it for the suffix it still has to prefill."""
        if request_id in self._tables:
            raise PoolError(f"request {request_id} already has a block table")
        t = BlockTable(request_id)
        for bid in pages:
            if not 0 <= bid < self.num_blocks or \
                    (self._refs[bid] == 0 and self._pins[bid] == 0):
                raise PoolError(f"cannot share dead page {bid}")
            self._refs[bid] += 1
            t.blocks.append(bid)
        t.num_tokens = len(t.blocks) * self.block_size
        self._tables[request_id] = t
        self.shared_pages += len(t.blocks)
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        if t.blocks:
            self._trace("share", request_id, len(t.blocks))
        return t

    def pin(self, bid: int) -> None:
        """Add a cache reference: the page survives (and never moves) after
        every table releases it, until ``unpin``."""
        if self._refs[bid] == 0 and self._pins[bid] == 0:
            raise PoolError(f"cannot pin free block {bid}")
        self._pins[bid] += 1

    def unpin(self, bid: int) -> bool:
        """Drop a cache reference; returns True when that reclaimed the
        page (no table references it either)."""
        if self._pins[bid] <= 0:
            raise PoolError(f"block {bid} not pinned")
        self._pins[bid] -= 1
        if self._pins[bid] == 0 and self._refs[bid] == 0:
            self._release_block(bid)
            return True
        return False

    def ensure_writable(self, request_id: str, page_index: int) -> int:
        """Copy-on-write gate: make logical page ``page_index`` of the
        request's table safe to mutate.  Exclusive unpinned pages pass
        through; a shared or pinned page is swapped for a fresh private
        copy (page gather in the bound arena).  Returns the physical id
        the caller may now write.  Raises :class:`PoolError` when no free
        block is available for the copy (caller may evict cache entries
        and retry)."""
        t = self._tables[request_id]
        bid = t.blocks[page_index]
        if self._refs[bid] == 1 and self._pins[bid] == 0:
            return bid
        if not self._free:
            raise PoolError(f"OOM: copy-on-write of block {bid} needs a "
                            f"free block")
        new = self._take_block(request_id)
        if self.arena is not None:
            self.arena.copy_page(bid, new)
        t.blocks[page_index] = new
        self._refs[bid] -= 1
        if self._refs[bid] == 0 and self._pins[bid] == 0:
            self._release_block(bid)
        self.cow_copies += 1
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        self._trace("cow", request_id, 1, src=bid, dst=new,
                    page_index=page_index)
        return new

    # -- defrag --------------------------------------------------------------
    def defrag(self) -> Dict[int, int]:
        """Compact exclusively-owned live blocks to the lowest physical ids
        (stable order: table order within request, requests by first block)
        and mirror the moves into the bound arena's page storage (a single
        batched gather per K/V leaf).  Shared (refcount > 1) and pinned
        pages never move: other tables and the prefix-cache index hold
        them by physical id.  With no sharing this degenerates to full
        compaction with a contiguous free tail.  Returns the
        {old_id: new_id} move map."""
        immovable = {bid for bid in range(self.num_blocks)
                     if self._pins[bid] > 0 or self._refs[bid] > 1}
        order = sorted(self._tables.values(),
                       key=lambda t: t.blocks[0] if t.blocks else 0)
        moves: Dict[int, int] = {}
        occupied = set(immovable)
        nxt = 0
        for t in order:
            for i, bid in enumerate(t.blocks):
                if bid in immovable:
                    continue
                while nxt in immovable:
                    nxt += 1
                if bid != nxt:
                    moves[bid] = nxt
                t.blocks[i] = nxt
                occupied.add(nxt)
                nxt += 1
        new_refs = [0] * self.num_blocks
        for t in self._tables.values():
            for bid in t.blocks:
                new_refs[bid] += 1
        self._refs = new_refs
        self._free = deque(b for b in range(self.num_blocks)
                           if b not in occupied)
        if self.arena is not None:
            # the counter records physical page moves, so it only advances
            # when storage is bound (unbound defrag is table bookkeeping)
            # saralint: ok[cow-gate] defrag relocates whole pages and never moves shared/pinned ones (immovable landmarks); content is copied, not mutated
            self.arena.apply_moves(moves)
            self.defrag_moves += len(moves)
        self._trace("defrag", "_pool", len(moves),
                    storage_moved=self.arena is not None,
                    pinned_landmarks=len(immovable))
        return moves

    # -- invariant check (tests / debug / per-step under sanitize) -----------
    def check(self) -> None:
        self.sanitize_checks += 1
        refs = [0] * self.num_blocks
        for t in self._tables.values():
            if len(set(t.blocks)) != len(t.blocks):
                raise PoolError(f"table {t.request_id} names a page twice")
            for bid in t.blocks:
                refs[bid] += 1
        if refs != self._refs:
            bad = [b for b in range(self.num_blocks)
                   if refs[b] != self._refs[b]]
            raise PoolError(f"refcount drift on blocks {bad[:8]}")
        if any(p < 0 for p in self._pins):
            raise PoolError("negative pin count")
        free = sorted(self._free)
        if len(free) != len(set(free)):
            raise PoolError("free list names a block twice")
        expect = [b for b in range(self.num_blocks)
                  if refs[b] == 0 and self._pins[b] == 0]
        if free != expect:
            raise PoolError("free list does not equal the unreferenced, "
                            "unpinned block set")
