"""Serving fault model: terminal request outcomes, fault attribution,
and the deterministic chaos-injection harness.

Terminal outcomes (the four failure states plus normal completion and
admission rejection) close a request's lifecycle exactly once:

  ``done``       retired normally (budget or EOS)
  ``failed``     a step-level fault (pool/sanitizer) was attributed to
                 this request, or its preemption budget ran out
  ``expired``    its deadline passed while it sat in the queue
  ``shed``       admission control dropped it: the rolling-TTFT estimate
                 of queue delay already exceeded its deadline
  ``cancelled``  the caller revoked it (``Request.cancel()``)
  ``rejected``   it could never be served (invalid shape / larger than
                 the whole pool) and was refused at submit

Fault *attribution* is how the engine's error boundary decides between
failing one request and retrying the whole step: a ``PoolError`` /
``SanitizerError`` that names the request(s) it belongs to (via the
``rids`` attribute, attached with :func:`attach_rids` at the raise site)
fails exactly those requests; an unattributable fault is treated as
transient engine trouble and retried with exponential backoff.

The :class:`FaultInjector` is the serving twin of ``TrainDriver``'s
``fail_injector`` (both schedule through
:class:`repro.runtime.failplan.FaultSchedule`, so the two harnesses
cannot drift): a seed-driven chaos harness that injects

  ``pool_oom``   an attributed :class:`PoolError` against a live request
                 (simulated allocation failure on its lane)
  ``poison``     NaN-poisons one fully-written, exclusively-owned page of
                 a decode lane — the PR 8 sanitizer's poison scan is the
                 detection oracle, so this requires ``sanitize=True``
  ``stall``      forces a lane to skip committing for ``stall_steps``
                 steps (its writes land in the trash page, the token is
                 replayed — a simulated slow/stuck lane)
  ``preempt``    forcibly preempts a mid-prefill lane (exercises the
                 recompute-on-readmit path and the preemption budget)

Every draw is keyed on ``(seed, kind, step)``, so one seed reproduces
one fault sequence bit-for-bit regardless of retries.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runtime.failplan import FaultSchedule
from repro.serving.kv_pool import PoolError

# terminal request outcomes (Request.outcome; "" = still live)
OUTCOMES = ("done", "failed", "expired", "shed", "cancelled", "rejected")

# outcome -> the always-on counter it bumps (declared in obs/trace.py
# COUNTERS so saralint guards the spellings)
OUTCOME_COUNTERS = {
    "failed": "requests_failed",
    "expired": "requests_expired",
    "shed": "requests_shed",
    "cancelled": "requests_cancelled",
    "rejected": "requests_rejected",
}


def attach_rids(exc: BaseException, rids: Sequence[str]) -> BaseException:
    """Mark an exception as attributable to specific requests.  The
    engine's step error boundary fails exactly these requests instead of
    retrying (or surfacing) the whole step."""
    exc.rids = [str(r) for r in rids]     # type: ignore[attr-defined]
    return exc


def fault_rids(exc: BaseException) -> List[str]:
    """The request ids a fault is attributed to ([] = unattributable)."""
    rids = getattr(exc, "rids", None)
    return [str(r) for r in rids] if rids else []


@dataclass
class ChaosConfig:
    """Knobs for the chaos harness (``EngineConfig.chaos``).  Every
    probability is per engine step; at most one fault of each kind fires
    per step.  ``poison_p > 0`` requires ``EngineConfig.sanitize`` — the
    sanitizer's poison scan is what detects (and therefore contains) the
    injected page, without it the fault would surface as silent garbage
    tokens."""

    seed: int = 0
    pool_oom_p: float = 0.0     # attributed PoolError against a live lane
    poison_p: float = 0.0       # NaN-poison one page of a decode lane
    stall_p: float = 0.0        # force a lane to stall (skip commit)
    stall_steps: int = 2        # how long a forced stall lasts
    preempt_p: float = 0.0      # force-preempt a mid-prefill lane

    def any_enabled(self) -> bool:
        return any(p > 0 for p in (self.pool_oom_p, self.poison_p,
                                   self.stall_p, self.preempt_p))


# stable per-kind RNG salts (changing these reshuffles every seeded
# chaos schedule, so they are part of the reproducibility contract)
_SALTS = {"pool_oom": 1, "poison": 2, "stall": 3, "preempt": 4}


class FaultInjector:
    """Deterministic, seed-driven fault injection for the serving engine.

    The engine offers candidates (live requests / poisonable pages) at
    its injection points; the injector decides *whether* (per-kind
    :class:`FaultSchedule`) and *what* (deterministic victim pick) and
    records every injection as a ``fault`` trace event + the
    ``faults_injected`` counter.  ``injected`` keeps per-kind totals for
    ``summary()`` and the chaos benchmark."""

    def __init__(self, chaos: ChaosConfig, recorder=None):
        self.chaos = chaos
        self.recorder = recorder
        probs = {"pool_oom": chaos.pool_oom_p, "poison": chaos.poison_p,
                 "stall": chaos.stall_p, "preempt": chaos.preempt_p}
        self._sched = {kind: FaultSchedule(chaos.seed, probability=p,
                                           salt=_SALTS[kind])
                       for kind, p in probs.items()}
        self.injected: Dict[str, int] = {k: 0 for k in _SALTS}
        self._stalled_until: Dict[str, int] = {}   # rid -> last forced step

    # -- bookkeeping ---------------------------------------------------------
    def _record(self, kind: str, step: int, rid: str, **args) -> None:
        self.injected[kind] += 1
        if self.recorder is not None:
            self.recorder.count("faults_injected", 1)
            self.recorder.instant("fault", "fault", track="faults",
                                  kind=kind, rid=rid, step=step, **args)

    def total_injected(self) -> int:
        return sum(self.injected.values())

    def summary(self) -> Dict[str, int]:
        return {f"chaos_{k}_injected": v for k, v in self.injected.items()}

    # -- injection points (called by the engine) -----------------------------
    def pool_oom(self, step: int, candidates: Sequence) -> Optional[object]:
        """A simulated allocation failure attributed to one live request;
        returns the victim Request (the engine raises the attributed
        PoolError) or None."""
        if not candidates or not self._sched["pool_oom"].fires(step):
            return None
        victim = candidates[self._sched["pool_oom"].pick(
            step, len(candidates))]
        self._record("pool_oom", step, victim.rid)
        return victim

    def oom_error(self, step: int, req) -> PoolError:
        """The attributed PoolError for a ``pool_oom`` victim."""
        return attach_rids(PoolError(
            f"chaos: injected pool OOM against request {req.rid} "
            f"at step {step}"), [req.rid])

    def poison(self, step: int,
               candidates: Sequence[Tuple[object, List[int]]]
               ) -> Optional[Tuple[object, int]]:
        """Pick a (request, physical page) to NaN-poison, from candidates
        of (request, eligible_pages) — eligible pages are fully-written
        and exclusively owned, so the poison is both guaranteed to be
        streamed by that lane's next decode and invisible to every other
        lane.  Returns None when the schedule does not fire or nothing
        qualifies."""
        candidates = [(r, pages) for r, pages in candidates if pages]
        if not candidates or not self._sched["poison"].fires(step):
            return None
        sched = self._sched["poison"]
        req, pages = candidates[sched.pick(step, len(candidates))]
        page = pages[sched.pick(step + 1_000_003, len(pages))]
        self._record("poison", step, req.rid, page=page)
        return req, page

    def stall_lanes(self, step: int, candidates: Sequence) -> List:
        """Lanes forced to stall this step: ongoing forced stalls plus at
        most one new victim when the schedule fires.  A stall lasts
        ``stall_steps`` engine steps."""
        out = [r for r in candidates
               if self._stalled_until.get(r.rid, -1) >= step]
        fresh = [r for r in candidates
                 if self._stalled_until.get(r.rid, -1) < step]
        if fresh and self._sched["stall"].fires(step):
            victim = fresh[self._sched["stall"].pick(step, len(fresh))]
            self._stalled_until[victim.rid] = \
                step + max(self.chaos.stall_steps, 1) - 1
            self._record("stall", step, victim.rid,
                         steps=self.chaos.stall_steps)
            out.append(victim)
        return out

    def preempt(self, step: int, candidates: Sequence) -> Optional[object]:
        """A mid-prefill lane to forcibly preempt, or None."""
        if not candidates or not self._sched["preempt"].fires(step):
            return None
        victim = candidates[self._sched["preempt"].pick(
            step, len(candidates))]
        self._record("preempt", step, victim.rid)
        return victim
