"""Sharded numpy checkpointing with atomic writes and elastic restore.

Format: one directory per step — ``step_000123/{manifest.json, data.npz}``.
Leaves are keyed by their tree path; the manifest records step, path list,
shapes/dtypes, and user metadata.  Writes go to a temp dir + atomic rename,
so a crash mid-save never corrupts the latest checkpoint (the fault-
tolerance loop in runtime/driver.py relies on this).

``restore_resharded`` re-shards a checkpoint onto a DIFFERENT mesh — the
elastic-scaling path: save on mesh A, shrink/grow, restore on mesh B.

Async saves run on a worker thread (``save(..., blocking=False)``).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "name",
                       getattr(p, "idx", p)))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             blocking: bool = True) -> None:
        flat = _flatten(tree)       # device_get happens on the caller thread

        def _write():
            with self._lock:
                tmp = self.dir / f".tmp_step_{step:08d}_{os.getpid()}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                np.savez(tmp / "data.npz", **flat)
                manifest = {
                    "step": step,
                    "time": time.time(),
                    "keys": sorted(flat),
                    "shapes": {k: list(v.shape) for k, v in flat.items()},
                    "dtypes": {k: str(v.dtype) for k, v in flat.items()},
                    "metadata": metadata or {},
                }
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                final = self._step_dir(step)
                if final.exists():
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()

        if blocking:
            _write()
        else:
            self.wait()
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore_flat(self, step: Optional[int] = None
                     ) -> Tuple[int, Dict[str, np.ndarray], dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "data.npz")
        return step, {k: data[k] for k in data.files}, manifest["metadata"]

    def restore(self, target_tree: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any, dict]:
        """Restore into the structure of `target_tree` (avals ok).  With
        `shardings`, leaves are device_put with those shardings — pass the
        NEW mesh's shardings for elastic restore."""
        step, flat, meta = self.restore_flat(step)
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        out = []
        sh_leaves = (jax.tree_util.tree_leaves(shardings)
                     if shardings is not None else [None] * len(leaves_p))
        for (path, leaf), sh in zip(leaves_p, sh_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "name",
                           getattr(p, "idx", p)))) for p in path)
            if key not in flat:
                raise KeyError(f"checkpoint missing {key}")
            arr = flat[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return step, tree, meta


def restore_resharded(directory: str, target_tree: Any, mesh, specs
                      ) -> Tuple[int, Any, dict]:
    """Elastic restore: load the latest checkpoint onto a new mesh."""
    from repro.parallel.sharding import to_named
    mgr = CheckpointManager(directory)
    return mgr.restore(target_tree, shardings=to_named(specs, mesh))
