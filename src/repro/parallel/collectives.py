"""Distributed-optimization collectives: int8-compressed gradient reduction.

``quantized_psum`` halves (vs bf16) / quarters (vs f32) the bytes a gradient
all-reduce moves across ICI:

  1. agree on a global scale:      psum-max of |x|        (scalar)
  2. quantize to int8 shards + all_to_all   (1 B/elem on the wire)
  3. dequantize + reduce locally in f32
  4. re-quantize the reduced shard + all_gather (1 B/elem)

Equivalent bytes: ~2 x 1 B/elem vs. 2 x 2 B/elem for a bf16 ring
all-reduce.  Quantization error is bounded by the error-feedback residual
(returned to the caller; add it to the next step's gradient — ZeRO-style EF).

Used by the compressed-DP train-step variant (flag) and §Perf hillclimb.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quant(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def _dequant(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def quantized_psum(x: jnp.ndarray, axis_name: str, axis_size: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: sum x over `axis_name` with int8 wire format.

    x: (..., D) with D % axis_size == 0 (caller pads).
    Returns (summed x (f32), local error-feedback residual)."""
    orig_shape = x.shape
    x = x.astype(jnp.float32).reshape(-1)
    n = x.shape[0]
    pad = (-n) % axis_size
    if pad:
        x = jnp.pad(x, (0, pad))

    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(amax, 1e-20) / 127.0

    q = _quant(x, scale)
    err = x - _dequant(q, scale)                       # error feedback

    # reduce-scatter in int8: all_to_all my shards, reduce locally in f32
    qs = q.reshape(axis_size, -1)
    qs = jax.lax.all_to_all(qs[None], axis_name, split_axis=1,
                            concat_axis=0, tiled=False)[..., 0, :]
    # qs: (axis_size, chunk) — one int8 shard from each peer
    local_sum = jnp.sum(_dequant(qs, scale), axis=0)   # (chunk,) f32

    # re-quantize the reduced shard and all-gather it
    amax2 = jax.lax.pmax(jnp.max(jnp.abs(local_sum)), axis_name)
    scale2 = jnp.maximum(amax2, 1e-20) / 127.0
    q2 = _quant(local_sum, scale2)
    gathered = jax.lax.all_gather(q2, axis_name, tiled=True)
    out = _dequant(gathered, scale2)[:n].reshape(orig_shape)
    err = err[:n].reshape(orig_shape)
    return out, err


def quantized_psum_tree(grads, axis_name: str, axis_size: int):
    """Apply quantized_psum per leaf; returns (summed grads, error tree)."""
    flat, treedef = jax.tree_util.tree_flatten(grads)
    outs, errs = [], []
    for leaf in flat:
        o, e = quantized_psum(leaf, axis_name, axis_size)
        outs.append(o.astype(leaf.dtype))
        errs.append(e.astype(leaf.dtype))
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, errs))
