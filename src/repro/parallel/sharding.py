"""NamedSharding rules for params, optimizer state, caches and batches.

Strategy (DESIGN.md §5):
  - TP (Megatron-style) over the `model` axis: attention heads / FFN hidden /
    experts (EP) / vocab.
  - FSDP (ZeRO-3) over the `data` axis (and over `pod`×`data` on the
    multi-pod mesh): the *other* big dimension of every matrix.
  - Optimizer state inherits the parameter sharding.
  - KV caches: batch over `data`(×`pod`), kv-heads over `model` when the head
    count divides the axis (MQA kv=1 replicates over `model` — documented).
  - SSM states: batch over `data`, ssm-heads over `model`.

Rules are matched on the parameter path suffix; any dim whose size does not
divide its assigned axis falls back to replication on that dim (GSPMD would
pad, but even sharding keeps the roofline numbers clean).
"""

from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= _axis_size(mesh, a)
        return n
    return mesh.devices.shape[mesh.axis_names.index(axis)]


def fsdp_axes(mesh: Mesh):
    """FSDP shards over pod×data when a pod axis exists."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


# (path-suffix regex, spec builder).  `F` = fsdp axes, "model" = TP axis.
# Specs are written WITHOUT the leading scan axis; a leading stack dim is
# detected from rank and prepended as None.
def _param_rules(F):
    return [
        # embeddings / unembeddings
        (r"embed$",          lambda: P("model", F)),
        (r"unembed$",        lambda: P(F, "model")),
        # attention
        (r"attn/wq$",        lambda: P(F, "model")),
        (r"attn/wk$",        lambda: P(F, "model")),
        (r"attn/wv$",        lambda: P(F, "model")),
        (r"attn/wo$",        lambda: P("model", F)),
        (r"cross/w[qkv]$",   lambda: P(F, "model")),
        (r"cross/wo$",       lambda: P("model", F)),
        # MLA
        (r"attn/w_dq$",      lambda: P(F, None)),
        (r"attn/w_uq$",      lambda: P(None, "model")),
        (r"attn/w_dkv$",     lambda: P(F, None)),
        (r"attn/w_uk$",      lambda: P(None, "model")),
        (r"attn/w_uv$",      lambda: P(None, "model")),
        # dense MLP
        (r"mlp/w_gate$",     lambda: P(F, "model")),
        (r"mlp/w_up$",       lambda: P(F, "model")),
        (r"mlp/w_down$",     lambda: P("model", F)),
        # MoE (EP over model)
        (r"moe/router$",     lambda: P(F, None)),
        (r"moe/w_gate$",     lambda: P("model", F, None)),
        (r"moe/w_up$",       lambda: P("model", F, None)),
        (r"moe/w_down$",     lambda: P("model", None, F)),
        (r"shared/w_gate$",  lambda: P(F, "model")),
        (r"shared/w_up$",    lambda: P(F, "model")),
        (r"shared/w_down$",  lambda: P("model", F)),
        # RWKV6
        (r"blk/w_[rkvg]$",   lambda: P(F, "model")),
        (r"blk/w_o$",        lambda: P("model", F)),
        (r"blk/w_ck$",       lambda: P(F, "model")),
        (r"blk/w_cv$",       lambda: P("model", F)),
        (r"blk/w_cr$",       lambda: P(F, "model")),
        (r"blk/w_decay_a$",  lambda: P(F, None)),
        (r"blk/w_decay_b$",  lambda: P(None, "model")),
        # Mamba2
        (r"blk/w_in$",       lambda: P(F, "model")),
        (r"blk/w_out$",      lambda: P("model", F)),
        # frontends
        (r"frontend/proj1?$", lambda: P(F, "model")),
        (r"frontend/proj2$", lambda: P("model", F)),
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _fits(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Replicate any dim whose size doesn't divide its axis."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def param_specs(params_aval: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching the parameter tree.

    tp_strategy="tp" (default): Megatron TP over `model` + FSDP over `data`.
    tp_strategy="dp_all": no tensor parallelism — pure ZeRO-3: every >=2-D
    parameter shards its largest non-stack dim over data x model (batch also
    runs over both axes via hints layout "dp_all").  The right choice is
    workload-dependent — this is the sharding-class output of the SARA-TPU
    recommender (§Perf lever for small-model cells whose TP collectives
    dominate)."""
    F = fsdp_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_aval)
    specs = []
    if cfg.tp_strategy in ("dp_all", "dp_all_noep"):
        Fall = (F if isinstance(F, tuple) else (F,)) + ("model",)
        ep_rules = [] if cfg.tp_strategy == "dp_all_noep" else \
            [(pat, b) for pat, b in _param_rules(F)
             if pat.startswith(r"moe/")]
        for path, leaf in flat:
            ps = _path_str(path)
            shape = leaf.shape
            if len(shape) < 2:
                specs.append(P())
                continue
            # MoE expert banks keep EP over `model` (tokens all-to-all to
            # the expert shards); ZeRO-gathering every expert per layer
            # would cost E/top_k more gather traffic than EP's dispatch.
            spec = None
            for pat, builder in ep_rules:
                if re.search(pat, ps):
                    spec = builder()
                    if len(shape) == len(spec) + 1:
                        spec = P(*((None,) + tuple(spec)))
                    elif len(shape) != len(spec):
                        spec = None
                    break
            if spec is None:
                big = max(range(len(shape)), key=lambda d: shape[d])
                sp = [None] * len(shape)
                sp[big] = Fall
                spec = P(*sp)
            specs.append(_fits(spec, shape, mesh))
        return jax.tree_util.tree_unflatten(treedef, specs)

    rules = _param_rules(F)
    for path, leaf in flat:
        ps = _path_str(path)
        shape = leaf.shape
        spec = None
        for pat, builder in rules:
            if re.search(pat, ps):
                spec = builder()
                break
        if spec is None:
            spec = P()                       # norms, biases, scalars: replicate
        else:
            # prepend None for a leading stack (layer) axis
            if len(shape) == len(spec) + 1:
                spec = P(*((None,) + tuple(spec)))
            elif len(shape) != len(spec):
                spec = P()
        specs.append(_fits(spec, shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch_aval: Any, mesh: Mesh,
                cfg: Optional[ArchConfig] = None) -> Any:
    """Shard the batch dim over pod×data (replicate if indivisible, e.g. B=1).
    Under tp_strategy="dp_all" the batch also shards over `model`."""
    B_axes = batch_axes(mesh)
    if cfg is not None and cfg.tp_strategy.startswith("dp_all"):
        B_axes = (B_axes if isinstance(B_axes, tuple) else (B_axes,)) \
            + ("model",)

    def spec(leaf):
        s = P(*((B_axes,) + (None,) * (len(leaf.shape) - 1)))
        return _fits(s, leaf.shape, mesh)

    return jax.tree_util.tree_map(spec, batch_aval)


def cache_specs_tree(cache_aval: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    """Shard decode caches: (L, B, S, heads, ...) -> B on data, heads on model."""
    B_axes = batch_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_aval)
    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        shape = leaf.shape
        if ps.endswith("pos") or leaf.ndim == 0:
            specs.append(P())
            continue
        if ps.endswith("length"):
            specs.append(_fits(P(None), shape, mesh))
            continue
        if leaf.ndim >= 4:
            # (L, B, S, KVH[, hd]) or states (L, B, H, ...)
            if "wkv" in ps or ("ssm" in ps and "layers" in ps):
                spec = P(None, B_axes, "model")
            elif leaf.ndim == 5:
                spec = P(None, B_axes, None, "model", None)
            else:
                spec = P(None, B_axes, None, None)
        elif leaf.ndim == 3:
            spec = P(None, B_axes, None)
        elif leaf.ndim == 2:
            spec = P(None, B_axes)
        else:
            spec = P(None)
        specs.append(_fits(spec, shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def to_named(specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
