"""Activation sharding hints (``with_sharding_constraint`` helpers).

GSPMD's propagation through deeply nested scans (layers x flash-attention
chunks) drops the batch sharding without explicit anchors — measured on the
llama3.2-1b/train_4k cell: activations replicated over `data`, 16x inflated
HLO bytes.  Model code therefore pins activations with ``hint(x, ...)`` at
block boundaries.

The mesh is ambient state set by the launch layer (``use_mesh``); when no
mesh is set (single-device CPU tests) hints are no-ops, so model code stays
mesh-agnostic.  Axis tokens:
  'B'     -> the batch axes ('pod','data') or 'data'
  'M'     -> the tensor-parallel axis 'model'
  None    -> replicated
A dim whose size does not divide its axis falls back to None.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_LAYOUT: str = "tp"


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def set_layout(layout: str) -> None:
    global _LAYOUT
    _LAYOUT = layout


def current_mesh() -> Optional[Mesh]:
    return _MESH


def current_layout() -> str:
    return _LAYOUT


@contextlib.contextmanager
def use_mesh(mesh: Mesh, layout: str = "tp"):
    global _MESH, _LAYOUT
    prev, prev_l = _MESH, _LAYOUT
    _MESH, _LAYOUT = mesh, layout
    try:
        yield
    finally:
        _MESH, _LAYOUT = prev, prev_l


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= _axis_size(mesh, a)
        return n
    if axis not in mesh.axis_names:
        return 0
    return mesh.devices.shape[mesh.axis_names.index(axis)]


def hint(x, *axes):
    """Constrain x's sharding.  axes: one token ('B'|'M'|'E'|None) per dim.

    Specific tokens ('M', 'E') reserve their mesh axes first; 'B' then takes
    whatever batch axes remain — so under layout "dp_all" a tensor with both
    a batch dim and an expert dim shards batch over data and experts over
    `model` instead of colliding."""
    mesh = _MESH
    if mesh is None or not hasattr(x, "shape"):
        return x
    if len(axes) != x.ndim:
        return x
    used = set()
    for tok in axes:                       # reserve non-batch axes first
        if (tok == "E" and _LAYOUT != "dp_all_noep") or \
                (tok == "M" and _LAYOUT == "tp"):
            used.add("model")
    spec = []
    for dim, tok in zip(x.shape, axes):
        if tok is None:
            spec.append(None)
            continue
        if tok == "B":
            ax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
            if _LAYOUT.startswith("dp_all") and "model" not in used:
                ax = ax + ("model",)       # dense archs, DP over every axis
            ax = ax if len(ax) > 1 else ax[0]
        elif tok == "M":
            if _LAYOUT != "tp":
                spec.append(None)          # no tensor parallelism
                continue
            ax = "model"
        elif tok == "E":                   # expert-parallel dim -> model
            if _LAYOUT == "dp_all_noep":
                spec.append(None)          # experts ZeRO-sharded, not EP
                continue
            ax = "model"
        else:
            ax = tok
        n = _axis_size(mesh, ax)
        spec.append(ax if (n > 0 and dim % n == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
