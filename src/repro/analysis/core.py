"""saralint core: findings, suppressions, source model, check registry.

A *check* is a function ``fn(ctx: Context) -> Iterable[Finding]``
registered under a kebab-case id with :func:`register`.  The runner
parses every ``.py`` file under the requested paths once into
:class:`SourceFile` records (AST + import aliases + suppression
pragmas), hands the whole :class:`Context` to each check (so passes can
reason across files, e.g. ops.py wrappers vs ref.py twins), then applies
inline suppressions::

    out = jnp.einsum("bqhd,bkhd->bhqk", q, k)  # saralint: ok[dispatch-escape] activation-activation score

A pragma suppresses findings of that check id on the same line or the
line directly below it (i.e. it may trail the flagged line or sit on its
own line above).  A pragma with no reason text does not count — it
produces a ``suppression-reason`` error instead, so every suppression in
the tree documents *why* the contract does not apply.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

PRAGMA_RE = re.compile(r"#\s*saralint:\s*ok\[([a-z0-9_-]+)\]\s*(.*?)\s*$")

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass
class Finding:
    """One contract violation at ``path:line``."""

    check: str
    severity: str               # "error" | "warning"
    path: str                   # scan-root-relative posix path
    line: int                   # 1-indexed
    message: str
    suppressed: bool = False
    suppress_reason: str = ""

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tail = f"  (suppressed: {self.suppress_reason})" if self.suppressed else ""
        return f"{self.location}: {self.severity}[{self.check}] {self.message}{tail}"


def _collect_imports(tree: ast.AST) -> Dict[str, str]:
    """Alias -> dotted module/name map.  Relative imports keep their dots
    (``from . import ref`` -> ``ref: .ref``) so checks can match suffixes."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom):
            base = "." * node.level + (node.module or "")
            for a in node.names:
                if a.name == "*":
                    continue
                dotted = f"{base}.{a.name}" if base and not base.endswith(".") \
                    else f"{base}{a.name}"
                out[a.asname or a.name] = dotted
    return out


def _collect_pragmas(lines: Sequence[str]) -> Dict[int, List[Tuple[str, str]]]:
    out: Dict[int, List[Tuple[str, str]]] = {}
    for i, text in enumerate(lines, start=1):
        for m in PRAGMA_RE.finditer(text):
            out.setdefault(i, []).append((m.group(1), m.group(2)))
    return out


class SourceFile:
    """One parsed module: text, AST, import aliases, pragmas, parents."""

    def __init__(self, path: Path, root: Path):
        self.path = path
        try:
            self.rel = path.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            self.rel = path.as_posix()
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))
        self.imports = _collect_imports(self.tree)
        self.pragmas = _collect_pragmas(self.lines)
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self._parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self._parents.get(cur)
        return None

    def dotted(self, node: ast.AST) -> Optional[str]:
        """Best-effort dotted name for a Name/Attribute chain, with the
        base segment expanded through this file's import aliases
        (``jnp.einsum`` -> ``jax.numpy.einsum``)."""
        parts: List[str] = []
        cur = node
        while isinstance(cur, ast.Attribute):
            parts.append(cur.attr)
            cur = cur.value
        if not isinstance(cur, ast.Name):
            return None
        parts.append(self.imports.get(cur.id, cur.id))
        return ".".join(reversed(parts))

    def pragma_for(self, line: int, check: str) -> Optional[str]:
        """Reason text if a pragma for ``check`` covers ``line`` (same
        line or the line above); None if not suppressed."""
        for lno in (line, line - 1):
            for cid, reason in self.pragmas.get(lno, ()):
                if cid == check:
                    return reason
        return None


class Context:
    """Everything a check may look at: all scanned files plus lookups."""

    def __init__(self, files: List[SourceFile], root: Path):
        self.files = files
        self.root = root
        self.by_rel = {f.rel: f for f in files}

    def find(self, rel_suffix: str) -> Optional[SourceFile]:
        """First file whose root-relative path ends with ``rel_suffix``."""
        for f in self.files:
            if f.rel == rel_suffix or f.rel.endswith("/" + rel_suffix):
                return f
        return None


CheckFn = Callable[[Context], Iterable[Finding]]
CHECKS: Dict[str, Tuple[str, CheckFn]] = {}


def register(check_id: str, description: str):
    def deco(fn: CheckFn) -> CheckFn:
        if check_id in CHECKS:
            raise ValueError(f"duplicate check id: {check_id}")
        CHECKS[check_id] = (description, fn)
        return fn
    return deco


def collect_files(paths: Sequence[str]) -> Tuple[List[SourceFile], Path]:
    """Parse every ``.py`` under ``paths``.  Relative paths are computed
    against the first argument (a directory) so check scoping such as
    ``models/`` works for both the real tree and fixture corpora."""
    roots = [Path(p) for p in paths]
    scan_root = roots[0] if roots[0].is_dir() else roots[0].parent
    files: List[SourceFile] = []
    seen = set()
    for r in roots:
        candidates = sorted(r.rglob("*.py")) if r.is_dir() else [r]
        for p in candidates:
            key = p.resolve()
            if key in seen:
                continue
            seen.add(key)
            files.append(SourceFile(p, scan_root))
    return files, scan_root


def apply_suppressions(findings: List[Finding],
                       ctx: Context) -> List[Finding]:
    """Mark findings covered by a pragma; add a ``suppression-reason``
    error for every pragma used without a reason."""
    extra: List[Finding] = []
    for f in findings:
        sf = ctx.by_rel.get(f.path)
        if sf is None:
            continue
        reason = sf.pragma_for(f.line, f.check)
        if reason is None:
            continue
        f.suppressed = True
        f.suppress_reason = reason or "<missing>"
        if not reason:
            extra.append(Finding(
                check="suppression-reason", severity=ERROR, path=f.path,
                line=f.line,
                message=(f"saralint: ok[{f.check}] suppression must state a "
                         "reason"),
            ))
    return findings + extra


def run_paths(paths: Sequence[str],
              only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run (a subset of) the registered checks over ``paths``; returns
    all findings, suppressed ones included and marked."""
    files, root = collect_files(paths)
    ctx = Context(files, root)
    findings: List[Finding] = []
    for cid, (_desc, fn) in sorted(CHECKS.items()):
        if only and cid not in only:
            continue
        findings.extend(fn(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.check))
    return apply_suppressions(findings, ctx)


def render_report(findings: List[Finding], as_json: bool = False,
                  show_suppressed: bool = False) -> str:
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    if as_json:
        payload = {
            "findings": [f.to_dict() for f in findings],
            "summary": {
                "errors": sum(1 for f in active if f.severity == ERROR),
                "warnings": sum(1 for f in active if f.severity == WARNING),
                "suppressed": len(suppressed),
            },
        }
        return json.dumps(payload, indent=2)
    lines = [f.render() for f in active]
    if show_suppressed:
        lines += [f.render() for f in suppressed]
    lines.append(
        f"saralint: {sum(1 for f in active if f.severity == ERROR)} error(s), "
        f"{sum(1 for f in active if f.severity == WARNING)} warning(s), "
        f"{len(suppressed)} suppressed")
    return "\n".join(lines)
