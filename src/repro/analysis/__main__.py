"""``python -m repro.analysis [paths...]`` — run saralint over a tree.

Exits non-zero when any unsuppressed finding remains (errors *and*
warnings gate: a warning is a contract the author has neither fixed nor
explained).  ``--json`` emits machine-readable findings for tooling.
"""

from __future__ import annotations

import argparse
import sys

from . import CHECKS, run_paths
from .core import render_report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="saralint: contract-checking static analysis")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to scan (default: src/repro)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON")
    ap.add_argument("--check", action="append", dest="checks", metavar="ID",
                    help="run only this check id (repeatable)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed findings")
    ap.add_argument("--list-checks", action="store_true",
                    help="list registered checks and exit")
    ns = ap.parse_args(argv)

    if ns.list_checks:
        for cid, (desc, _fn) in sorted(CHECKS.items()):
            print(f"{cid:18s} {desc}")
        return 0

    if ns.checks:
        unknown = [c for c in ns.checks if c not in CHECKS]
        if unknown:
            ap.error(f"unknown check id(s): {', '.join(unknown)}")

    findings = run_paths(ns.paths, only=ns.checks)
    print(render_report(findings, as_json=ns.json,
                        show_suppressed=ns.show_suppressed))
    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
