"""Built-in saralint checks.  Importing this package registers all five."""

from . import cow_gate  # noqa: F401
from . import dispatch_escape  # noqa: F401
from . import obs_taxonomy  # noqa: F401
from . import pallas_contract  # noqa: F401
from . import retrace_hazard  # noqa: F401
