"""cow-gate: arena writers must be reachable only behind the COW gate.

KV pages are refcounted and shared across requests (prefix cache, COW
forks).  Writing a shared or pinned page in place corrupts every other
reader, so each write path must first pass ``KVBlockPool.ensure_writable``
(or the engine's chunk-level ``_cow_chunk_pages`` wrapper), which forks
the page when its refcount > 1 or it is pinned.

The pass flags any function in ``serving/`` or ``models/`` that calls a
known arena-writing entry point without also calling a gate in the same
function body.  Call sites that are safe by construction — e.g. decode
appending into a tail page the request owns exclusively — carry a
``# saralint: ok[cow-gate] <reason>`` pragma documenting the ownership
argument.
"""

from __future__ import annotations

import ast
from typing import Iterable

from ..core import Context, ERROR, Finding, register

CHECK = "cow-gate"

#: entry points that mutate arena page storage
WRITERS = {
    "_arena_write_chunk",       # models/attention.py chunk scatter
    "_paged_write",             # engine jit wrapper: bucketed prefill write
    "_chunk_prefill",           # engine jit wrapper: ragged chunk prefill
    "_paged_decode",            # engine jit wrapper: decode append + attend
    "_paged_shared_decode",     # engine jit wrapper: cascade decode append
    "paged_prefill_write",      # model-level bucketed KV scatter
    "copy_page",                # raw arena page copy
    "apply_moves",              # raw arena defrag gather
    "_spec_verify",             # engine jit wrapper: spec-decode verify chunk
    "_draft_prefill",           # spec_decode jit wrapper: draft catch-up
    "_draft_loop",              # spec_decode jit wrapper: fused draft rounds
}

#: calls that establish copy-on-write protection for the writes that follow
GATES = {"ensure_writable", "_cow_chunk_pages"}


def _call_name(node: ast.Call) -> str:
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node.func, ast.Name):
        return node.func.id
    return ""


@register("cow-gate",
          "arena writers reachable without ensure_writable protection")
def check(ctx: Context) -> Iterable[Finding]:
    for sf in ctx.files:
        if not (sf.rel.startswith(("serving/", "models/"))
                or "/serving/" in sf.rel or "/models/" in sf.rel):
            continue
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name in GATES:
                continue                    # this *is* the gate
            calls = [n for n in ast.walk(fn) if isinstance(n, ast.Call)]
            names = {_call_name(c) for c in calls}
            if names & GATES:
                continue                    # gated in this body
            seen = set()
            for c in calls:
                name = _call_name(c)
                if name in WRITERS and name not in seen:
                    seen.add(name)
                    yield Finding(
                        check=CHECK, severity=ERROR, path=sf.rel,
                        line=c.lineno,
                        message=(f"'{fn.name}' calls arena writer '{name}' "
                                 "with no ensure_writable/_cow_chunk_pages "
                                 "gate in scope — shared or pinned pages "
                                 "would be mutated in place"))
