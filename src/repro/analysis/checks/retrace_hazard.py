"""retrace-hazard: jit entry points that recompile more than they should.

PR 6's ``jit_compiles`` counter catches retraces at runtime; this is the
static twin.  Four hazard patterns:

1. **inline wrap-and-invoke** — ``jax.jit(f)(x)`` builds a fresh wrapper
   (and a fresh compilation cache) on every call;
2. **jit under a loop** — ``jax.jit(...)`` constructed inside
   ``for``/``while`` re-wraps per iteration;
3. **unknown static name** — ``static_argnames`` naming a parameter the
   wrapped function does not declare (jit raises only when the name is
   actually passed, so the typo hides until production traffic);
4. **unhashable static default** — a static parameter whose default is a
   list/dict/set literal: the first defaulted call raises
   ``TypeError: unhashable``, and a per-call-constructed value would
   retrace every step.  ``static_argnums`` out of positional range is
   flagged the same way.

Signature checks run only when the wrapped callable resolves to a
function defined in the same module (decorator form or
``g = jax.jit(f, ...)``); bound methods and imported callables are
skipped rather than guessed.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional

from ..core import Context, ERROR, Finding, SourceFile, WARNING, register

CHECK = "retrace-hazard"

_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp)


def _is_jit(sf: SourceFile, node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    dotted = sf.dotted(node.func) or ""
    return dotted in ("jax.jit", "jax.api.jit") or dotted.endswith(".jax.jit")


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _str_items(node: Optional[ast.AST]) -> Optional[List[str]]:
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, str)):
                return None
            out.append(e.value)
        return out
    return None


def _int_items(node: Optional[ast.AST]) -> Optional[List[int]]:
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
            out.append(e.value)
        return out
    return None


def _signature_check(sf: SourceFile, fn: ast.FunctionDef, jit_call: ast.Call,
                     line: int) -> Iterable[Finding]:
    args = fn.args
    params = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    pos_params = [a.arg for a in args.posonlyargs + args.args]
    defaults: Dict[str, ast.AST] = {}
    pos_with_default = (args.posonlyargs + args.args)[
        len(args.posonlyargs) + len(args.args) - len(args.defaults):]
    for a, d in zip(pos_with_default, args.defaults):
        defaults[a.arg] = d
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is not None:
            defaults[a.arg] = d

    for name in _str_items(_kw(jit_call, "static_argnames")) or []:
        if name not in params:
            yield Finding(
                check=CHECK, severity=ERROR, path=sf.rel, line=line,
                message=(f"static_argnames names '{name}' but "
                         f"'{fn.name}' has no such parameter"))
        elif isinstance(defaults.get(name), _UNHASHABLE):
            yield Finding(
                check=CHECK, severity=ERROR, path=sf.rel, line=line,
                message=(f"static parameter '{name}' of '{fn.name}' defaults "
                         "to an unhashable literal — jit static arguments "
                         "must be hashable and low-variety"))
    for num in _int_items(_kw(jit_call, "static_argnums")) or []:
        if args.vararg is None and num >= len(pos_params):
            yield Finding(
                check=CHECK, severity=ERROR, path=sf.rel, line=line,
                message=(f"static_argnums {num} is out of range for "
                         f"'{fn.name}' ({len(pos_params)} positional "
                         "parameter(s))"))


def _module_functions(sf: SourceFile) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in ast.walk(sf.tree)
            if isinstance(n, ast.FunctionDef)}


@register("retrace-hazard",
          "jit entry points with unhashable or unbounded static arguments")
def check(ctx: Context) -> Iterable[Finding]:
    for sf in ctx.files:
        fns = _module_functions(sf)
        for node in ast.walk(sf.tree):
            # R1: jax.jit(f)(...) — fresh wrapper per call.
            if isinstance(node, ast.Call) and _is_jit(sf, node.func):
                yield Finding(
                    check=CHECK, severity=WARNING, path=sf.rel,
                    line=node.lineno,
                    message=("jax.jit(...) wrapped and invoked inline — the "
                             "wrapper (and its compile cache) is rebuilt "
                             "every call; hoist the jitted callable"))
            # R2: jax.jit constructed under a loop.
            if _is_jit(sf, node):
                cur = sf.parent(node)
                invoked_inline = isinstance(cur, ast.Call) \
                    and cur.func is node
                while cur is not None and not isinstance(
                        cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                    if isinstance(cur, (ast.For, ast.While)) \
                            and not invoked_inline:
                        yield Finding(
                            check=CHECK, severity=WARNING, path=sf.rel,
                            line=node.lineno,
                            message=("jax.jit(...) constructed inside a loop "
                                     "— re-wrapped (and potentially "
                                     "recompiled) every iteration"))
                        break
                    cur = sf.parent(cur)
            # R3/R4 assignment form: g = jax.jit(f, static_arg...=...)
            if isinstance(node, ast.Call) and _is_jit(sf, node) and node.args:
                target = node.args[0]
                if isinstance(target, ast.Name) and target.id in fns:
                    yield from _signature_check(sf, fns[target.id], node,
                                                node.lineno)
        # R3/R4 decorator form: @partial(jax.jit, static_arg...=...)
        for fn in fns.values():
            for deco in fn.decorator_list:
                if isinstance(deco, ast.Call):
                    dotted = sf.dotted(deco.func) or ""
                    if dotted.endswith("partial") and deco.args \
                            and (sf.dotted(deco.args[0]) or "").endswith("jit"):
                        yield from _signature_check(sf, fn, deco, fn.lineno)
                    elif _is_jit(sf, deco):
                        yield from _signature_check(sf, fn, deco, fn.lineno)
