"""pallas-contract: BlockSpec/grid/prefetch arithmetic and ref twins.

Every ``pl.pallas_call`` site encodes the same arithmetic by hand:

* each ``BlockSpec`` index-map lambda takes ``grid rank +
  num_scalar_prefetch`` arguments (grid indices first, then the
  prefetched scalar refs);
* the index map returns one coordinate per block-shape dimension;
* the immediately-invoked call receives ``num_scalar_prefetch +
  len(in_specs)`` operands.

And cross-file: every public ``kernels/ops.py`` wrapper that lowers to a
``*_pallas`` kernel must keep a registered XLA twin in
``kernels/ref.py`` (``<wrapper>_ref``) or reference the ref module
directly in its fallback branch — the parity suites and serving XLA
paths depend on the twin existing.

Static resolution is best-effort: a grid/in_specs expression the pass
cannot resolve to a literal (e.g. built dynamically) is skipped, never
guessed — the check aims for zero false positives.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from ..core import Context, ERROR, Finding, SourceFile, register

CHECK = "pallas-contract"


def _resolve_local(sf: SourceFile, node: ast.AST,
                   at: ast.AST) -> Optional[ast.AST]:
    """Resolve a Name to the value of a simple assignment in the
    enclosing function (``grid = (S, KVH, W)``); None if not found."""
    if not isinstance(node, ast.Name):
        return node
    fn = sf.enclosing_function(at)
    scope = fn if fn is not None else sf.tree
    found = None
    for stmt in ast.walk(scope):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and stmt.targets[0].id == node.id:
            found = stmt.value
    return found


def _spec_list(sf: SourceFile, node: ast.AST,
               at: ast.AST) -> Optional[List[ast.AST]]:
    """Flatten an in_specs expression to a list of element nodes;
    handles list literals, resolvable names, and list concatenation."""
    node = _resolve_local(sf, node, at)
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        left = _spec_list(sf, node.left, at)
        right = _spec_list(sf, node.right, at)
        if left is not None and right is not None:
            return left + right
    return None


def _is_blockspec(sf: SourceFile, node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    dotted = sf.dotted(node.func) or ""
    return dotted.endswith("BlockSpec")


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _int_const(node: Optional[ast.AST]) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    return None


def _check_blockspec(sf: SourceFile, spec: ast.Call, grid_rank: Optional[int],
                     nsp: int, where: str) -> Iterable[Finding]:
    shape = spec.args[0] if spec.args else _kw(spec, "block_shape")
    index_map = spec.args[1] if len(spec.args) > 1 else _kw(spec, "index_map")
    if not isinstance(index_map, ast.Lambda):
        return
    arity = len(index_map.args.args)
    if grid_rank is not None and arity != grid_rank + nsp:
        yield Finding(
            check=CHECK, severity=ERROR, path=sf.rel, line=index_map.lineno,
            message=(f"{where}: index map takes {arity} arg(s) but grid rank "
                     f"{grid_rank} + num_scalar_prefetch {nsp} requires "
                     f"{grid_rank + nsp}"))
    if isinstance(shape, ast.Tuple):
        ndim = len(shape.elts)
        body = index_map.body
        ret = len(body.elts) if isinstance(body, ast.Tuple) else 1
        if ret != ndim:
            yield Finding(
                check=CHECK, severity=ERROR, path=sf.rel,
                line=index_map.lineno,
                message=(f"{where}: block shape has {ndim} dim(s) but the "
                         f"index map returns {ret} coordinate(s)"))


def _check_call_site(sf: SourceFile, call: ast.Call) -> Iterable[Finding]:
    grid_spec = _kw(call, "grid_spec")
    grid_spec = _resolve_local(sf, grid_spec, call) if grid_spec is not None \
        else None
    if isinstance(grid_spec, ast.Call):
        holder = grid_spec
        nsp = _int_const(_kw(holder, "num_scalar_prefetch")) or 0
    else:
        holder = call
        nsp = 0
    grid_node = _resolve_local(sf, _kw(holder, "grid"), call)
    grid_rank = len(grid_node.elts) if isinstance(grid_node, ast.Tuple) \
        else None
    in_specs = _spec_list(sf, _kw(holder, "in_specs"), call) \
        if _kw(holder, "in_specs") is not None else None
    out_specs = _kw(holder, "out_specs")
    out_list = _spec_list(sf, out_specs, call) if out_specs is not None \
        else None
    if out_list is None and out_specs is not None:
        out_list = [out_specs]

    for i, spec in enumerate(in_specs or []):
        if _is_blockspec(sf, spec):
            yield from _check_blockspec(sf, spec, grid_rank, nsp,
                                        f"in_specs[{i}]")
    for i, spec in enumerate(out_list or []):
        if _is_blockspec(sf, spec):
            yield from _check_blockspec(sf, spec, grid_rank, nsp,
                                        f"out_specs[{i}]")

    # Immediately-invoked form: operand count must cover prefetch + inputs.
    parent = sf.parent(call)
    if isinstance(parent, ast.Call) and parent.func is call \
            and in_specs is not None \
            and not any(isinstance(a, ast.Starred) for a in parent.args):
        want = nsp + len(in_specs)
        got = len(parent.args)
        if got != want:
            yield Finding(
                check=CHECK, severity=ERROR, path=sf.rel, line=parent.lineno,
                message=(f"pallas_call invoked with {got} operand(s) but "
                         f"num_scalar_prefetch {nsp} + {len(in_specs)} "
                         f"in_specs requires {want}"))


def _ref_aliases(sf: SourceFile) -> set:
    """Import aliases in ``sf`` that point at the kernels ref module."""
    out = set()
    for alias, dotted in sf.imports.items():
        tail = dotted.lstrip(".")
        if tail == "ref" or tail.endswith(".ref") or ".ref." in tail \
                or tail.startswith("ref."):
            out.add(alias)
    return out


def _check_ref_twins(ctx: Context) -> Iterable[Finding]:
    ops = ctx.find("kernels/ops.py")
    ref = ctx.find("kernels/ref.py")
    if ops is None or ref is None:
        return
    ref_names = set()
    for node in ref.tree.body:
        if isinstance(node, ast.FunctionDef):
            ref_names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    ref_names.add(t.id)
    aliases = _ref_aliases(ops)
    for node in ops.tree.body:
        if not isinstance(node, ast.FunctionDef) or node.name.startswith("_"):
            continue
        lowers = any(
            isinstance(c, ast.Call) and (
                (isinstance(c.func, ast.Name) and c.func.id.endswith("_pallas"))
                or (isinstance(c.func, ast.Attribute)
                    and c.func.attr.endswith("_pallas")))
            for c in ast.walk(node))
        if not lowers:
            continue
        uses_ref = any(
            (isinstance(n, ast.Name) and n.id in aliases)
            or (isinstance(n, ast.Attribute) and isinstance(n.value, ast.Name)
                and n.value.id in aliases)
            for n in ast.walk(node))
        if uses_ref or f"{node.name}_ref" in ref_names:
            continue
        yield Finding(
            check=CHECK, severity=ERROR, path=ops.rel, line=node.lineno,
            message=(f"wrapper '{node.name}' lowers to a Pallas kernel but "
                     f"has no XLA twin: define {node.name}_ref in "
                     "kernels/ref.py or call through the ref module in its "
                     "fallback branch"))


@register("pallas-contract",
          "BlockSpec/grid/prefetch arithmetic and ops<->ref twin registry")
def check(ctx: Context) -> Iterable[Finding]:
    for sf in ctx.files:
        if not (sf.rel.startswith("kernels/") or "/kernels/" in sf.rel):
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                dotted = sf.dotted(node.func) or ""
                if dotted.endswith("pallas_call"):
                    yield from _check_call_site(sf, node)
    yield from _check_ref_twins(ctx)
