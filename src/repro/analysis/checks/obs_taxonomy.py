"""obs-taxonomy: trace string literals must match the declared taxonomy.

``obs/trace.py`` declares the event taxonomy (``CATEGORIES``,
``STEP_PHASES``, ``COUNTERS``, ``GAUGES``).  ``validate_trace`` enforces
categories at export time, but a typo'd phase/counter/gauge string
silently creates a new series that no dashboard or test ever reads.
This pass checks, at every recorder call site:

* ``.emit/.instant/.slice/.span`` — first literal argument must be a
  declared category;
* ``.phase`` (step timeline) — literal must be a declared step phase;
* ``.count`` / ``.gauge`` — literal must be a declared counter / gauge.

Only calls whose receiver is a recorder-ish attribute (``obs``, ``rec``,
``recorder``, ``timeline``, ``tl``) are considered, so ``list.count(x)``
never trips it; non-literal first arguments (f-strings, variables) are
skipped.  The taxonomy is read from the scanned tree's own
``obs/trace.py``, so fixture corpora carry their own declarations.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, Optional, Tuple

from ..core import Context, ERROR, Finding, register

CHECK = "obs-taxonomy"

RECEIVERS = {"obs", "rec", "recorder", "timeline", "tl"}
CATEGORY_METHODS = {"emit", "instant", "slice", "span"}

_TAXONOMY_NAMES = ("CATEGORIES", "STEP_PHASES", "COUNTERS", "GAUGES")


def _taxonomy(ctx: Context) -> Optional[Dict[str, Tuple[str, ...]]]:
    trace = ctx.find("obs/trace.py")
    if trace is None:
        return None
    out: Dict[str, Tuple[str, ...]] = {}
    for node in trace.tree.body:
        if not isinstance(node, ast.Assign):
            continue
        for t in node.targets:
            if isinstance(t, ast.Name) and t.id in _TAXONOMY_NAMES \
                    and isinstance(node.value, (ast.Tuple, ast.List)):
                vals = tuple(e.value for e in node.value.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, str))
                out[t.id] = vals
    return out or None


def _receiver_tail(func: ast.Attribute) -> str:
    base = func.value
    if isinstance(base, ast.Attribute):
        return base.attr
    if isinstance(base, ast.Name):
        return base.id
    return ""


def _first_literal(call: ast.Call) -> Optional[Tuple[str, int]]:
    if not call.args:
        return None
    a = call.args[0]
    if isinstance(a, ast.Constant) and isinstance(a.value, str):
        return a.value, a.lineno
    return None


@register("obs-taxonomy",
          "trace category/phase/counter literals vs obs/trace.py taxonomy")
def check(ctx: Context) -> Iterable[Finding]:
    tax = _taxonomy(ctx)
    if tax is None:
        return
    categories = tax.get("CATEGORIES", ())
    phases = tax.get("STEP_PHASES", ())
    counters = tax.get("COUNTERS", ())
    gauges = tax.get("GAUGES", ())
    for sf in ctx.files:
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            if _receiver_tail(node.func) not in RECEIVERS:
                continue
            method = node.func.attr
            lit = _first_literal(node)
            if lit is None:
                continue
            value, line = lit
            bad = None
            if method in CATEGORY_METHODS and value not in categories:
                bad = ("category", "CATEGORIES", categories)
            elif method == "phase" and value not in phases:
                bad = ("step phase", "STEP_PHASES", phases)
            elif method == "count" and value not in counters:
                bad = ("counter", "COUNTERS", counters)
            elif method == "gauge" and value not in gauges:
                bad = ("gauge", "GAUGES", gauges)
            if bad is None:
                continue
            kind, decl, known = bad
            yield Finding(
                check=CHECK, severity=ERROR, path=sf.rel, line=line,
                message=(f'.{method}("{value}"): unknown {kind} — declare it '
                         f"in obs/trace.py {decl} or fix the literal "
                         f"(known: {', '.join(known) or '<none>'})"))
