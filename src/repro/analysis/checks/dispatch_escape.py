"""dispatch-escape: model GEMMs must route through ``dispatch.gemm``.

The paper's 99.93%-of-best result assumes ADAPTNET observes *every*
layer GEMM shape; a raw ``jnp.einsum``/``@``/``jnp.dot``/``jnp.matmul``
in model code is a shape the recommender never sees and a tile choice
the RSA never makes.  This pass flags every raw contraction in
``models/`` and ``core/adaptnet.py``:

* **error** when an operand looks like a *weight* (``w_uk``, ``w1``,
  ``params["w2"]``, ``kernel`` ...) — a true escape that should be
  rerouted through ``dispatch.gemm``;
* **warning** otherwise — typically an activation-activation contraction
  (attention scores, recurrence mixes) that dispatch legitimately does
  not own, to be annotated with a
  ``# saralint: ok[dispatch-escape] <reason>`` pragma.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, List

from ..core import Context, ERROR, Finding, SourceFile, WARNING, register

GEMM_FUNCS = {
    "jax.numpy.einsum", "jax.numpy.dot", "jax.numpy.matmul",
    "jax.numpy.tensordot",
    "numpy.einsum", "numpy.dot", "numpy.matmul", "numpy.tensordot",
}

_WEIGHT_NAME = re.compile(r"^(w|wt|weight|kernel|proj)(_|\d|$)")

#: layout/cast wrappers to look through when deciding weight-likeness
_TRANSPARENT_ATTRS = {"astype", "reshape", "transpose", "swapaxes", "T"}


def _in_scope(sf: SourceFile) -> bool:
    return sf.rel.startswith("models/") or sf.rel == "core/adaptnet.py"


def _weight_like(node: ast.AST) -> bool:
    while True:
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr in _TRANSPARENT_ATTRS:
            node = node.func.value
        elif isinstance(node, ast.Attribute) and node.attr in _TRANSPARENT_ATTRS:
            node = node.value
        else:
            break
    if isinstance(node, ast.Name):
        return bool(_WEIGHT_NAME.match(node.id))
    if isinstance(node, ast.Attribute):
        return bool(_WEIGHT_NAME.match(node.attr))
    if isinstance(node, ast.Subscript):
        s = node.slice
        if isinstance(s, ast.Constant) and isinstance(s.value, str):
            return bool(_WEIGHT_NAME.match(s.value))
    return False


def _operands(call: ast.Call) -> List[ast.AST]:
    """Tensor operands of a contraction call (skip einsum's spec string)."""
    ops = []
    for a in call.args:
        if isinstance(a, ast.Constant) and isinstance(a.value, str):
            continue
        if isinstance(a, ast.Starred):
            continue
        ops.append(a)
    return ops


@register("dispatch-escape",
          "model GEMMs not routed through dispatch.gemm")
def check(ctx: Context) -> Iterable[Finding]:
    for sf in ctx.files:
        if not _in_scope(sf):
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                dotted = sf.dotted(node.func)
                if dotted not in GEMM_FUNCS:
                    continue
                fn = dotted.rsplit(".", 1)[-1]
                weighted = any(_weight_like(a) for a in _operands(node))
                spec = ""
                if node.args and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str):
                    spec = f' "{node.args[0].value}"'
                yield Finding(
                    check="dispatch-escape",
                    severity=ERROR if weighted else WARNING,
                    path=sf.rel, line=node.lineno,
                    message=(f"raw {fn}{spec} "
                             + ("contracts a weight operand — route it "
                                "through dispatch.gemm(site=...)"
                                if weighted else
                                "bypasses the dispatch layer — route "
                                "through dispatch.gemm or annotate why "
                                "dispatch does not own this contraction")))
            elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
                weighted = _weight_like(node.right) or _weight_like(node.left)
                yield Finding(
                    check="dispatch-escape",
                    severity=ERROR if weighted else WARNING,
                    path=sf.rel, line=node.lineno,
                    message=("raw @ matmul "
                             + ("against a weight operand — route it "
                                "through dispatch.gemm(site=...)"
                                if weighted else
                                "bypasses the dispatch layer — route "
                                "through dispatch.gemm or annotate why "
                                "dispatch does not own this contraction")))
