"""saralint: contract-checking static analysis for this repo.

The stack's correctness rests on cross-cutting contracts no single test
enumerates: every model GEMM must route through ``dispatch.gemm`` (or
ADAPTNET never observes the shape), every arena write into a shared page
must pass the ``ensure_writable`` copy-on-write gate, every Pallas
``BlockSpec`` index map must agree with its grid rank and scalar-prefetch
count, trace taxonomy strings must match ``obs/trace.py``, and jit entry
points must not be fed retrace hazards.  ``saralint`` walks the AST and
enforces those contracts; ``python -m repro.analysis src/repro`` is the
CI gate.

See ``docs/ANALYSIS.md`` for the check taxonomy and the
``# saralint: ok[check-id] <reason>`` suppression syntax.
"""

from .core import (  # noqa: F401
    CHECKS,
    Context,
    Finding,
    SourceFile,
    collect_files,
    register,
    run_paths,
)

# Importing the package registers every built-in check.
from . import checks  # noqa: F401,E402
