"""ADAPTNETX — the fused recommendation core as one Pallas kernel.

Mirrors the paper's hardware (Fig. 9b): the input activations stay resident
(input-stationary), weights stream through; everything — 3 embedding-row
gathers, the 128-unit hidden layer, the classifier layer, and the argmax —
happens in ONE kernel launch, so a configuration query is a single ~μs-class
device op, matching the paper's ~576-cycle budget at 1 GHz.

The embedding gather uses scalar prefetch: the (M, K, N) ids arrive as a
scalar-prefetch operand and drive the BlockSpec index_maps, so only THREE
embedding rows ever leave HBM — not the 480 KB of tables.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, row_m_ref, row_k_ref, row_n_ref, w1_ref, b1_ref,
            w2_ref, b2_ref, logits_ref):
    x = jnp.concatenate([row_m_ref[0], row_k_ref[0], row_n_ref[0]], axis=-1)
    h = jnp.maximum(x @ w1_ref[...] + b1_ref[...], 0.0)
    logits_ref[...] = (h @ w2_ref[...] + b2_ref[...])[None, :]


def adaptnetx_pallas(ids: jnp.ndarray, emb_m: jnp.ndarray, emb_k: jnp.ndarray,
                     emb_n: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
                     w2: jnp.ndarray, b2: jnp.ndarray, *,
                     interpret: bool = True) -> jnp.ndarray:
    """ids: (3,) int32 (M, K, N clamped to vocab); returns (num_classes,)
    logits.  Argmax is left to the caller (one tiny op) so tests can check
    the full distribution."""
    C = w2.shape[-1]
    E = emb_m.shape[-1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, E), lambda i, ids: (ids[0], 0)),
            pl.BlockSpec((1, E), lambda i, ids: (ids[1], 0)),
            pl.BlockSpec((1, E), lambda i, ids: (ids[2], 0)),
            pl.BlockSpec(w1.shape, lambda i, ids: (0, 0)),
            pl.BlockSpec(b1.shape, lambda i, ids: (0,)),
            pl.BlockSpec(w2.shape, lambda i, ids: (0, 0)),
            pl.BlockSpec(b2.shape, lambda i, ids: (0,)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda i, ids: (0, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, C), jnp.float32),
        interpret=interpret,
    )(ids, emb_m, emb_k, emb_n, w1.astype(jnp.float32),
      b1.astype(jnp.float32), w2.astype(jnp.float32), b2.astype(jnp.float32))
    return out[0]
