"""RSA GEMM — the TPU-native reconfigurable-tiling GEMM kernel.

The RSA's (sub-array dims x dataflow) configuration space maps onto the
Pallas tiling space (DESIGN.md §2): BlockSpec tile sizes are the sub-array
dimensions, and the *residency mode* — which operand's tile stays pinned in
VMEM while the grid iterates — is the dataflow:

  OS (output-stationary): grid (Mt, Nt, Kt), K innermost; the f32
      accumulator tile lives in VMEM scratch for the whole K loop.
  WS (weight-stationary): grid (Nt, Kt, Mt), M innermost; the B (weight)
      tile is revisited with a constant index over the whole M sweep, so it
      stays resident; partial sums accumulate into the output tile.
  IS (input-stationary):  grid (Mt, Kt, Nt), N innermost; the A (input)
      tile stays resident; partial sums accumulate into the output tile.

Block shapes are the SARA-recommended configuration (core/sara.py); MXU
alignment wants multiples of 128 in M/N and the lane dim.  Validated in
interpret mode against kernels/ref.py on CPU; compiled path targets TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hw import IS, OS, WS

# jax<0.5 ships the class as TPUCompilerParams; newer as CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _kernel_os(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_psum(a_ref, b_ref, o_ref, *, k_axis: int):
    """WS/IS: accumulate partial sums directly into the revisited out tile."""
    prod = jnp.dot(a_ref[...], b_ref[...],
                   preferred_element_type=jnp.float32).astype(o_ref.dtype)

    @pl.when(pl.program_id(k_axis) == 0)
    def _init():
        o_ref[...] = prod

    @pl.when(pl.program_id(k_axis) != 0)
    def _acc():
        o_ref[...] = o_ref[...] + prod


def rsa_gemm_pallas(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int,
                    block_n: int, block_k: int, mode: int = OS,
                    interpret: bool = True) -> jnp.ndarray:
    """a: (M, K), b: (K, N) — M, K, N must be multiples of the blocks
    (ops.rsa_gemm pads arbitrary shapes)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    mt, nt, kt = M // block_m, N // block_n, K // block_k
    out_shape = jax.ShapeDtypeStruct((M, N), a.dtype)

    if mode == OS:
        grid = (mt, nt, kt)
        return pl.pallas_call(
            functools.partial(_kernel_os, n_k=kt),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
                pl.BlockSpec((block_k, block_n), lambda m, n, k: (k, n)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda m, n, k: (m, n)),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(a, b)

    if mode == WS:
        grid = (nt, kt, mt)       # B tile constant over the M sweep
        return pl.pallas_call(
            functools.partial(_kernel_psum, k_axis=1),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda n, k, m: (m, k)),
                pl.BlockSpec((block_k, block_n), lambda n, k, m: (k, n)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda n, k, m: (m, n)),
            out_shape=out_shape,
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "arbitrary", "arbitrary")),
            interpret=interpret,
        )(a, b)

    if mode == IS:
        grid = (mt, kt, nt)       # A tile constant over the N sweep
        return pl.pallas_call(
            functools.partial(_kernel_psum, k_axis=1),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda m, k, n: (m, k)),
                pl.BlockSpec((block_k, block_n), lambda m, k, n: (k, n)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda m, k, n: (m, n)),
            out_shape=out_shape,
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "arbitrary", "arbitrary")),
            interpret=interpret,
        )(a, b)

    raise ValueError(f"unknown mode {mode}")
