"""Flash attention Pallas kernel (fwd + bwd), causal or full, GQA-aware.

The TPU adaptation of the paper's operand-reuse argument (DESIGN.md §2.2):
the (block_q, block_k) score/probability tiles live ONLY in VMEM — HBM sees
q/k/v/o blocks, never an S x S intermediate.  The XLA blockwise-scan path
(models/attention.py `_chunked_attn`) materializes every score block at a
fusion boundary; this kernel is the §Perf lever that removes that traffic.

Block scheduling uses a *pair list* prefetched as scalars (PrefetchScalarGrid):
the grid's last dimension enumerates exactly the (q-block, kv-block) pairs
that matter — lower-triangular for causal attention — so causal skip is a
real traffic reduction, not masked compute.  The (m, l, acc) running softmax
state lives in VMEM scratch, reset at each row start and emitted on the
row's last pair (same revisiting discipline as kernels/linear_attn.py).

Backward follows the standard two-kernel flash decomposition:
  dq : i-major pair order (same as fwd), accumulate ds @ k over kv blocks.
  dkv: j-major pair order, accumulate p^T do / ds^T q over q blocks,
       per q-head; the G group heads are reduced outside.
using the saved lse = m + log(l) and delta = rowsum(do * o).

Layouts are model-native (B, S, H, hd) — no transposes at the call site.
All shapes must be pre-padded to block multiples (kernels/ops.py pads and
masks with kv_len).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the class as TPUCompilerParams; newer as CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG = -1e30


def _pairs(n_q: int, n_k: int, bq: int, bk: int, causal: bool,
           order: str) -> np.ndarray:
    """(4, n_pairs) int32: q-block i, kv-block j, start flag, emit flag.

    Causal enumerates only (i, j) block pairs that overlap the lower
    triangle: some (row, col) with row >= col, i.e. (i+1)*bq - 1 >= j*bk.
    """
    def overlap(i: int, j: int) -> bool:
        return (not causal) or ((i + 1) * bq - 1 >= j * bk)

    if order == "i":      # i-major (fwd, dq): row i accumulates over j
        ps = [(i, j) for i in range(n_q) for j in range(n_k) if overlap(i, j)]
        key = 0
    else:                 # j-major (dkv): column j accumulates over i
        ps = []
        for j in range(n_k):
            js = [(i, j) for i in range(n_q) if overlap(i, j)]
            # a kv block past every q row (padded kv): visit once, fully
            # masked, so its dk/dv output block is written (= zeros)
            ps.extend(js if js else [(n_q - 1, j)])
        key = 1
    start = [t == 0 or ps[t][key] != ps[t - 1][key] for t in range(len(ps))]
    emit = [t == len(ps) - 1 or ps[t][key] != ps[t + 1][key]
            for t in range(len(ps))]
    return np.array([[p[0] for p in ps], [p[1] for p in ps],
                     [int(s) for s in start], [int(e) for e in emit]],
                    dtype=np.int32)


def _mask(s, i, j, bq, bk, kv_len: int, causal: bool):
    """Apply kv-validity and causal masking to an (bq, bk) score tile."""
    row = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    col = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = col < kv_len
    if causal:
        m = m & (col <= row)
    return jnp.where(m, s, NEG)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(ij, q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                acc_scr, *, bq, bk, kv_len, causal, scale):
    p = pl.program_id(2)
    i, j = ij[0, p], ij[1, p]

    @pl.when(ij[2, p] == 1)
    def _reset():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                                 # (bq, hd)
    k = k_ref[0, :, 0, :]                                 # (bk, hd)
    v = v_ref[0, :, 0, :]                                 # (bk, hd_v)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = _mask(s, i, j, bq, bk, kv_len, causal)

    m_prev, l_prev = m_scr[0], l_scr[0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    pexp = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(pexp, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        pexp.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[0], l_scr[0] = m_new, l_new

    @pl.when(ij[3, p] == 1)
    def _emit():
        l = jnp.maximum(l_scr[0], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, :] = m_scr[0] + jnp.log(l)


def _flash_fwd(q, k, v, *, causal: bool, scale: float, kv_len: int,
               bq: int, bk: int, interpret: bool
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, Sq, H, hd = q.shape
    _, Skv, KVH, hd_v = v.shape
    G = H // KVH
    n_q, n_k = Sq // bq, Skv // bk
    ij = jnp.asarray(_pairs(n_q, n_k, bq, bk, causal, "i"))

    grid = (B, H, ij.shape[1])
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, bq=bq, bk=bk, kv_len=kv_len,
                          causal=causal, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bq, 1, hd),
                             lambda b, h, p, ij: (b, ij[0, p], h, 0)),
                pl.BlockSpec((1, bk, 1, hd),
                             lambda b, h, p, ij: (b, ij[1, p], h // G, 0)),
                pl.BlockSpec((1, bk, 1, hd_v),
                             lambda b, h, p, ij: (b, ij[1, p], h // G, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, bq, 1, hd_v),
                             lambda b, h, p, ij: (b, ij[0, p], h, 0)),
                pl.BlockSpec((1, 1, bq),
                             lambda b, h, p, ij: (b, h, ij[0, p])),
            ],
            scratch_shapes=[pltpu.VMEM((1, bq), jnp.float32),
                            pltpu.VMEM((1, bq), jnp.float32),
                            pltpu.VMEM((bq, hd_v), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, Sq, H, hd_v), q.dtype),
                   jax.ShapeDtypeStruct((B, H, Sq), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ij, q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _dq_kernel(ij, q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dq_ref,
               acc_scr, *, bq, bk, kv_len, causal, scale):
    p = pl.program_id(2)
    i, j = ij[0, p], ij[1, p]

    @pl.when(ij[2, p] == 1)
    def _reset():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]
    k = k_ref[0, :, 0, :]
    v = v_ref[0, :, 0, :]
    do = do_ref[0, :, 0, :].astype(jnp.float32)           # (bq, hd_v)
    lse = lse_ref[0, 0, :]                                # (bq,)
    delta = dl_ref[0, 0, :]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = _mask(s, i, j, bq, bk, kv_len, causal)
    pexp = jnp.exp(s - lse[:, None])                      # (bq, bk)
    dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = pexp * (dp - delta[:, None]) * scale             # (bq, bk)
    acc_scr[...] += jax.lax.dot_general(ds.astype(k.dtype), k,
                                        (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(ij[3, p] == 1)
    def _emit():
        dq_ref[0, :, 0, :] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(ij, q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, bq, bk, kv_len, causal,
                scale):
    p = pl.program_id(2)
    i, j = ij[0, p], ij[1, p]

    @pl.when(ij[2, p] == 1)
    def _reset():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, :, 0, :]
    k = k_ref[0, :, 0, :]
    v = v_ref[0, :, 0, :]
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, 0, :]
    delta = dl_ref[0, 0, :]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = _mask(s, i, j, bq, bk, kv_len, causal)
    pexp = jnp.exp(s - lse[:, None])                      # (bq, bk)
    dv_scr[...] += jax.lax.dot_general(pexp.astype(do.dtype), do,
                                       (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = pexp * (dp - delta[:, None]) * scale             # (bq, bk)
    dk_scr[...] += jax.lax.dot_general(ds.astype(q.dtype), q,
                                       (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(ij[3, p] == 1)
    def _emit():
        dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)


def _flash_bwd(q, k, v, o, lse, do, *, causal: bool, scale: float,
               kv_len: int, bq: int, bk: int, interpret: bool):
    B, Sq, H, hd = q.shape
    _, Skv, KVH, hd_v = v.shape
    G = H // KVH
    n_q, n_k = Sq // bq, Skv // bk
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1).transpose(0, 2, 1)           # (B, H, Sq)

    common = dict(bq=bq, bk=bk, kv_len=kv_len, causal=causal, scale=scale)
    in_specs = [
        pl.BlockSpec((1, bq, 1, hd), lambda b, h, p, ij: (b, ij[0, p], h, 0)),
        pl.BlockSpec((1, bk, 1, hd),
                     lambda b, h, p, ij: (b, ij[1, p], h // G, 0)),
        pl.BlockSpec((1, bk, 1, hd_v),
                     lambda b, h, p, ij: (b, ij[1, p], h // G, 0)),
        pl.BlockSpec((1, bq, 1, hd_v),
                     lambda b, h, p, ij: (b, ij[0, p], h, 0)),
        pl.BlockSpec((1, 1, bq), lambda b, h, p, ij: (b, h, ij[0, p])),
        pl.BlockSpec((1, 1, bq), lambda b, h, p, ij: (b, h, ij[0, p])),
    ]

    ij_i = jnp.asarray(_pairs(n_q, n_k, bq, bk, causal, "i"))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, H, ij_i.shape[1]),
            in_specs=in_specs,
            out_specs=[pl.BlockSpec((1, bq, 1, hd),
                                    lambda b, h, p, ij: (b, ij[0, p], h, 0))],
            scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ij_i, q, k, v, do, lse, delta)[0]

    ij_j = jnp.asarray(_pairs(n_q, n_k, bq, bk, causal, "j"))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, H, ij_j.shape[1]),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, bk, 1, hd),
                             lambda b, h, p, ij: (b, ij[1, p], h, 0)),
                pl.BlockSpec((1, bk, 1, hd_v),
                             lambda b, h, p, ij: (b, ij[1, p], h, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                            pltpu.VMEM((bk, hd_v), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, Skv, H, hd), q.dtype),
                   jax.ShapeDtypeStruct((B, Skv, H, hd_v), q.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ij_j, q, k, v, do, lse, delta)

    if G > 1:   # reduce the per-q-head dk/dv over each kv head's group
        dk = dk.reshape(B, Skv, KVH, G, hd).sum(axis=3)
        dv = dv.reshape(B, Skv, KVH, G, hd_v).sum(axis=3)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom-vjp entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_pallas(q, k, v, causal: bool, scale: float, kv_len: int,
                           bq: int, bk: int, interpret: bool):
    """q: (B, Sq, H, hd); k: (B, Skv, KVH, hd); v: (B, Skv, KVH, hd_v).
    Sq % bq == 0, Skv % bk == 0 (kernels/ops.py pads); kv positions >= kv_len
    are masked.  Returns (B, Sq, H, hd_v)."""
    o, _ = _flash_fwd(q, k, v, causal=causal, scale=scale, kv_len=kv_len,
                      bq=bq, bk=bk, interpret=interpret)
    return o


def _vjp_fwd(q, k, v, causal, scale, kv_len, bq, bk, interpret):
    o, lse = _flash_fwd(q, k, v, causal=causal, scale=scale, kv_len=kv_len,
                        bq=bq, bk=bk, interpret=interpret)
    return o, (q, k, v, o, lse)

def _vjp_bwd(causal, scale, kv_len, bq, bk, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd(q, k, v, o, lse, do, causal=causal, scale=scale,
                            kv_len=kv_len, bq=bq, bk=bk, interpret=interpret)
    return dq, dk, dv


flash_attention_pallas.defvjp(_vjp_fwd, _vjp_bwd)
