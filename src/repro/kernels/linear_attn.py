"""Chunked linear attention (RWKV6/GLA-class) Pallas kernel.

Computes, per (batch*head), the data-dependent-decay linear attention

  o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T),   S_t = diag(w_t) S_{t-1} + k_t v_t^T

with the chunked closed form of models/ssm.py: the recurrent state S lives
in VMEM scratch and is carried across the (sequential) chunk grid dimension
— HBM sees only the chunk inputs and outputs, never the (lc, lc) decay
block.  This kernel is the hot spot of the rwkv6-1.6b / zamba2-7b cells
(the §Perf memory-bound term).

Grid: (BH, n_chunks) — chunk axis innermost/sequential; state resets at
chunk 0 of each (batch, head).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the class as TPUCompilerParams; newer as CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG = -1e30


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref, *,
            lc: int):
    @pl.when(pl.program_id(1) == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, 0].astype(jnp.float32)         # (lc, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)         # (lc, V)
    lw = lw_ref[0, 0].astype(jnp.float32)       # (lc, K) log decays (<= 0)
    u = u_ref[0].astype(jnp.float32)            # (K,) bonus

    cs = jnp.cumsum(lw, axis=0)                 # inclusive
    cs_prev = cs - lw
    h = state_ref[...]

    # inter-chunk
    o = (r * jnp.exp(cs_prev)) @ h              # (lc, V)
    # intra-chunk (strictly lower triangular)
    diff = cs_prev[:, None, :] - cs[None, :, :]             # (t, j, K)
    tri = jnp.tril(jnp.ones((lc, lc), jnp.bool_), k=-1)
    a = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    A = jnp.einsum("tk,jk,tjk->tj", r, k, a)
    o = o + A @ v
    # bonus diagonal
    o = o + jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    o_ref[0, 0] = o.astype(o_ref.dtype)

    # state update
    wsum = cs[-1]                               # (K,)
    kdec = k * jnp.exp(wsum[None, :] - cs)
    state_ref[...] = jnp.exp(wsum)[:, None] * h + kdec.T @ v


def _kernel_bshk(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sf_ref,
                 state_scr, *, lc: int, n: int):
    """Native (B, S, H, K) layout WKV kernel with carried state io.

    Grid (B, H, n_chunks); the recurrent (K, V) state lives in VMEM scratch,
    seeded from s0 at chunk 0 and emitted to sf at the last chunk.
    """
    @pl.when(pl.program_id(2) == 0)
    def _seed():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)       # (lc, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)       # (lc, V)
    lw = lw_ref[0, :, 0, :].astype(jnp.float32)     # (lc, K) log decays
    u = u_ref[0].astype(jnp.float32)                # (K,)

    cs = jnp.cumsum(lw, axis=0)
    cs_prev = cs - lw
    h = state_scr[...]

    o = (r * jnp.exp(cs_prev)) @ h                  # inter-chunk
    diff = cs_prev[:, None, :] - cs[None, :, :]     # (t, j, K)
    tri = jnp.tril(jnp.ones((lc, lc), jnp.bool_), k=-1)
    a = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    A = jnp.einsum("tk,jk,tjk->tj", r, k, a)
    o = o + A @ v                                   # intra-chunk
    o = o + jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    o_ref[0, :, 0, :] = o.astype(o_ref.dtype)

    wsum = cs[-1]
    kdec = k * jnp.exp(wsum[None, :] - cs)
    state_scr[...] = jnp.exp(wsum)[:, None] * h + kdec.T @ v

    @pl.when(pl.program_id(2) == n - 1)
    def _emit():
        sf_ref[0, 0] = state_scr[...].astype(sf_ref.dtype)


def linear_attn_bshk_pallas(r, k, v, logw, u, state0, *, chunk: int = 64,
                            interpret: bool = True):
    """r, k, logw: (B, S, H, K); v: (B, S, H, V); u: (H, K);
    state0: (B, H, K, V).  S must be a multiple of `chunk` (padded k/logw
    rows must be zero: k=0 contributes nothing, logw=0 preserves state).
    Returns (o: (B, S, H, V), final_state: (B, H, K, V))."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    assert S % chunk == 0
    n = S // chunk
    o, sf = pl.pallas_call(
        functools.partial(_kernel_bshk, lc=chunk, n=n),
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, V), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, K), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, V), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, S, H, V), r.dtype),
                   jax.ShapeDtypeStruct((B, H, K, V), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, logw, u, state0)
    return o, sf


def linear_attn_pallas(r, k, v, logw, u, *, chunk: int = 64,
                       interpret: bool = True):
    """r,k,logw: (BH, S, K); v: (BH, S, V); u: (BH, K).
    S must be a multiple of `chunk` (ops.linear_attn pads).
    Returns (o: (BH, S, V), final_state: (BH, K, V))."""
    BH, S, K = r.shape
    V = v.shape[-1]
    assert S % chunk == 0
    n = S // chunk

    def reshape(x):
        return x.reshape(BH, n, chunk, x.shape[-1])

    rr, kk, vv, ww = map(reshape, (r, k, v, logw))

    o = pl.pallas_call(
        functools.partial(_kernel, lc=chunk),
        grid=(BH, n),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, V), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, K), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, V), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, n, chunk, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(rr, kk, vv, ww, u)
    return o.reshape(BH, S, V)
