"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rsa_gemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """f32-accumulated GEMM (all modes compute the same function)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def adaptnetx_ref(ids, emb_m, emb_k, emb_n, w1, b1, w2, b2) -> jnp.ndarray:
    x = jnp.concatenate([emb_m[ids[0]], emb_k[ids[1]], emb_n[ids[2]]], -1)
    h = jnp.maximum(x.astype(jnp.float32) @ w1.astype(jnp.float32)
                    + b1.astype(jnp.float32), 0.0)
    return h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)


# XLA twin of ops.adaptnetx_recommend under its wrapper name, so the
# saralint pallas-contract ops<->ref registry resolves it.
adaptnetx_recommend_ref = adaptnetx_ref


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        kv_len: int | None = None,
                        scale: float | None = None) -> jnp.ndarray:
    """Naive f32 softmax attention.  q: (B,Sq,H,hd); k/v: (B,Skv,KVH,hd[_v])."""
    B, Sq, H, hd = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    if kv_len is None:
        kv_len = Skv
    qf = q.astype(jnp.float32).reshape(B, Sq, KVH, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    mask = (jnp.arange(Skv) < kv_len)[None, :]
    if causal:
        mask = mask & (jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None])
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Sq, H, vf.shape[-1]).astype(q.dtype)


def paged_gather(arena: jnp.ndarray, tables: jnp.ndarray) -> jnp.ndarray:
    """Linearize paged KV: arena (NB, bs, *feat) gathered through per-lane
    block tables (S, W) -> logical rows (S, W*bs, *feat)."""
    g = arena[tables]                     # (S, W, bs, *feat)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def paged_attention_ref(q, k_arena, v_arena, tables, lengths,
                        *, scale: float | None = None,
                        logit_cap: float = 0.0) -> jnp.ndarray:
    """Masked-dense decode attention over gathered pages (f32 softmax).

    q: (S, H, hd) one query token per lane; k_arena: (NB, bs, KVH, hd);
    v_arena: (NB, bs, KVH, hd_v); tables: (S, W) int32; lengths: (S,) int32.
    Returns (S, H, hd_v); empty lanes (length 0) yield zeros.
    """
    S, H, hd = q.shape
    KVH = k_arena.shape[2]
    G = H // KVH
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    k = paged_gather(k_arena, tables).astype(jnp.float32)   # (S, L, KVH, hd)
    v = paged_gather(v_arena, tables).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(S, KVH, G, hd)
    s = jnp.einsum("shgd,slhd->shgl", qf, k) * scale
    if logit_cap > 0.0:
        s = jnp.tanh(s / logit_cap) * logit_cap
    mask = jnp.arange(k.shape[1])[None, :] < lengths[:, None]   # (S, L)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("shgl,slhd->shgd", p, v)
    o = jnp.where((lengths > 0)[:, None, None, None], o, 0.0)
    return o.reshape(S, H, v.shape[-1]).astype(q.dtype)


def paged_mla_attention_ref(q_abs, q_rope, ckv_arena, krope_arena, tables,
                            lengths, *, scale: float) -> jnp.ndarray:
    """Absorbed-MLA decode over gathered latent pages.

    q_abs: (S, H, r); q_rope: (S, H, rd); ckv_arena: (NB, bs, r);
    krope_arena: (NB, bs, rd).  Returns the latent mix o_lat (S, H, r).
    """
    ckv = paged_gather(ckv_arena, tables).astype(jnp.float32)   # (S, L, r)
    krope = paged_gather(krope_arena, tables).astype(jnp.float32)
    s = (jnp.einsum("shr,slr->shl", q_abs.astype(jnp.float32), ckv) +
         jnp.einsum("shd,sld->shl", q_rope.astype(jnp.float32), krope)) * scale
    mask = jnp.arange(ckv.shape[1])[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("shl,slr->shr", p, ckv)
    o = jnp.where((lengths > 0)[:, None, None], o, 0.0)
    return o.astype(q_abs.dtype)


def paged_prefill_attention_ref(q, k_arena, v_arena, tables, starts, lengths,
                                *, scale: float | None = None,
                                logit_cap: float = 0.0) -> jnp.ndarray:
    """Chunked-prefill attention over gathered pages (f32 softmax).

    q: (S, C, H, hd) one prompt chunk per lane (rows already written to the
    arena); tables: (S, W) int32; starts: (S,) absolute position of chunk
    row 0; lengths: (S,) valid tokens including the chunk.  Chunk row r
    attends causally to arena columns ``<= starts + r`` (and ``< lengths``).
    Returns (S, C, H, hd_v); lanes with length 0 yield zeros.
    """
    S, C, H, hd = q.shape
    KVH = k_arena.shape[2]
    G = H // KVH
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    k = paged_gather(k_arena, tables).astype(jnp.float32)   # (S, L, KVH, hd)
    v = paged_gather(v_arena, tables).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(S, C, KVH, G, hd)
    s = jnp.einsum("schgd,slhd->shgcl", qf, k) * scale
    if logit_cap > 0.0:
        s = jnp.tanh(s / logit_cap) * logit_cap
    col = jnp.arange(k.shape[1])
    qpos = starts[:, None] + jnp.arange(C)[None, :]         # (S, C)
    mask = (col[None, None, :] < lengths[:, None, None]) & \
           (col[None, None, :] <= qpos[:, :, None])         # (S, C, L)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("shgcl,slhd->schgd", p, v)
    o = jnp.where((lengths > 0)[:, None, None, None, None], o, 0.0)
    return o.reshape(S, C, H, v.shape[-1]).astype(q.dtype)


def paged_mla_prefill_attention_ref(q_abs, q_rope, ckv_arena, krope_arena,
                                    tables, starts, lengths, *,
                                    scale: float) -> jnp.ndarray:
    """Absorbed-MLA chunked prefill over gathered latent pages.

    q_abs: (S, C, H, r); q_rope: (S, C, H, rd); ckv_arena: (NB, bs, r);
    krope_arena: (NB, bs, rd); starts / lengths as in
    :func:`paged_prefill_attention_ref`.  Returns o_lat (S, C, H, r).
    """
    S, C, H, _ = q_abs.shape
    ckv = paged_gather(ckv_arena, tables).astype(jnp.float32)   # (S, L, r)
    krope = paged_gather(krope_arena, tables).astype(jnp.float32)
    s = (jnp.einsum("schr,slr->schl", q_abs.astype(jnp.float32), ckv) +
         jnp.einsum("schd,sld->schl", q_rope.astype(jnp.float32),
                    krope)) * scale
    col = jnp.arange(ckv.shape[1])
    qpos = starts[:, None] + jnp.arange(C)[None, :]
    mask = (col[None, None, :] < lengths[:, None, None]) & \
           (col[None, None, :] <= qpos[:, :, None])         # (S, C, L)
    s = jnp.where(mask[:, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)                          # (S, C, H, L)
    o = jnp.einsum("schl,slr->schr", p, ckv)
    o = jnp.where((lengths > 0)[:, None, None, None], o, 0.0)
    return o.astype(q_abs.dtype)


def merge_softmax_states(o_a, m_a, l_a, o_b, m_b, l_b):
    """Combine two *normalized* partial-attention outputs over disjoint key
    sets into the exact softmax over their union.

    ``o_*``: (..., hd_v) normalized partial outputs; ``m_*``: (...) running
    max of the raw scores; ``l_*``: (...) sum of ``exp(score - m)``.  An
    empty state (``l == 0``, ``m == -1e30``) degenerates to the other side;
    two empty states yield zeros.  Returns (o, m, l) of the union.
    """
    o_a, o_b = o_a.astype(jnp.float32), o_b.astype(jnp.float32)
    m = jnp.maximum(m_a, m_b)
    a = l_a * jnp.exp(m_a - m)
    b = l_b * jnp.exp(m_b - m)
    l = a + b
    denom = jnp.maximum(l, 1e-30)
    o = (o_a * a[..., None] + o_b * b[..., None]) / denom[..., None]
    return o, m, l


def paged_attention_lse_ref(q, k_arena, v_arena, tables, lengths,
                            *, scale: float | None = None,
                            logit_cap: float = 0.0):
    """:func:`paged_attention_ref` that also returns the online-softmax
    state, for merging with another phase (shared-prefix cascade decode).

    Returns (o (S, H, hd_v) normalized, m (S, H) f32 running max, l (S, H)
    f32 exp-sum); empty lanes come back as (0, -1e30, 0).
    """
    S, H, hd = q.shape
    KVH = k_arena.shape[2]
    G = H // KVH
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    k = paged_gather(k_arena, tables).astype(jnp.float32)   # (S, L, KVH, hd)
    v = paged_gather(v_arena, tables).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(S, KVH, G, hd)
    s = jnp.einsum("shgd,slhd->shgl", qf, k) * scale
    if logit_cap > 0.0:
        s = jnp.tanh(s / logit_cap) * logit_cap
    mask = jnp.arange(k.shape[1])[None, :] < lengths[:, None]   # (S, L)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)                                 # (S, KVH, G)
    # the explicit mask on p (not just on s) keeps fully-masked lanes at
    # l == 0: with m == -1e30 every masked exp(s - m) would be exp(0) == 1
    p = jnp.where(mask[:, None, None, :], jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("shgl,slhd->shgd", p, v) / \
        jnp.maximum(l, 1e-30)[..., None]
    return (o.reshape(S, H, v.shape[-1]).astype(q.dtype),
            m.reshape(S, H), l.reshape(S, H))


def shared_prefix_attention_ref(q, k_arena, v_arena, prefix_pages,
                                prefix_lens, *, scale: float | None = None,
                                logit_cap: float = 0.0):
    """Partial decode attention over ONE shared page list for every lane.

    q: (S, H, hd); prefix_pages: (P,) int32 physical pages every sharing
    lane's table starts with; prefix_lens: (S,) int32 prefix rows lane s
    attends (0 = lane not in the sharing group -> empty state).  Returns
    (o, m, l) as in :func:`paged_attention_lse_ref`.
    """
    S, H, hd = q.shape
    KVH = k_arena.shape[2]
    G = H // KVH
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    k = k_arena[prefix_pages].astype(jnp.float32)           # (P, bs, KVH, hd)
    v = v_arena[prefix_pages].astype(jnp.float32)
    k = k.reshape((-1,) + k.shape[2:])                      # (P*bs, KVH, hd)
    v = v.reshape((-1,) + v.shape[2:])
    qf = q.astype(jnp.float32).reshape(S, KVH, G, hd)
    s = jnp.einsum("shgd,lhd->shgl", qf, k) * scale
    if logit_cap > 0.0:
        s = jnp.tanh(s / logit_cap) * logit_cap
    mask = jnp.arange(k.shape[0])[None, :] < prefix_lens[:, None]   # (S, L)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.where(mask[:, None, None, :], jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("shgl,lhd->shgd", p, v) / \
        jnp.maximum(l, 1e-30)[..., None]
    return (o.reshape(S, H, v.shape[-1]).astype(q.dtype),
            m.reshape(S, H), l.reshape(S, H))


def shared_paged_attention_ref(q, k_arena, v_arena, unique_tables,
                               unique_lens, prefix_pages, prefix_lens,
                               *, scale: float | None = None,
                               logit_cap: float = 0.0) -> jnp.ndarray:
    """Cascade decode oracle — BITWISE equal to :func:`paged_attention_ref`
    over the concatenated page lists.

    Instead of running the prefix and unique phases separately and merging
    online-softmax states (which reassociates the reduction, so greedy
    parity with the plain path held only numerically), each lane's combined
    table is rebuilt gap-free — its prefix pages followed immediately by
    its unique pages, exactly the order the lane's full block table has
    them in — and ONE masked softmax runs over it via
    :func:`paged_attention_ref`.  The only difference from the plain path
    is trailing table padding, and padded columns are exact no-ops: their
    ``-1e30`` scores underflow to 0.0 after ``exp``, leaving every partial
    sum bit-identical.  The two-phase + merge structure survives in the
    Pallas kernel path (``ops.shared_paged_attention``), where streaming
    the shared pages once per group is the point.  Returns (S, H, hd_v).
    """
    S = q.shape[0]
    bs = k_arena.shape[1]
    pw = prefix_pages.shape[0]
    uw = unique_tables.shape[1]
    # pages each lane takes from the shared run (prefix_lens is a whole
    # number of fully-written pages by construction; 0 = not in the group)
    npref = prefix_lens // bs                               # (S,)
    j = jnp.arange(pw + uw)                                 # (W,)
    in_prefix = j[None, :] < npref[:, None]                 # (S, W)
    pref_cols = jnp.broadcast_to(prefix_pages[jnp.clip(j, 0, pw - 1)][None],
                                 (S, pw + uw))
    uniq_idx = jnp.clip(j[None, :] - npref[:, None], 0, uw - 1)
    uniq_cols = jnp.take_along_axis(unique_tables, uniq_idx, axis=1)
    combined = jnp.where(in_prefix, pref_cols, uniq_cols)   # (S, W) int32
    return paged_attention_ref(q, k_arena, v_arena, combined,
                               prefix_lens + unique_lens, scale=scale,
                               logit_cap=logit_cap)


def linear_attn_ref(r, k, v, logw, u) -> jnp.ndarray:
    """Exact sequential recurrence (the definition, O(S) steps).

    r,k,logw: (BH, S, K); v: (BH, S, V); u: (BH, K).
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k v^T
    """
    BH, S, K = r.shape
    V = v.shape[-1]

    def per_bh(rb, kb, vb, wb, ub):
        def step(h, xs):
            rt, kt, vt, wt = xs
            o = rt @ (h + ub[:, None] * (kt[:, None] * vt[None, :]))
            h = jnp.exp(wt)[:, None] * h + kt[:, None] * vt[None, :]
            return h, o

        h0 = jnp.zeros((K, V), jnp.float32)
        _, o = jax.lax.scan(step, h0, (rb, kb, vb, wb))
        return o

    return jax.vmap(per_bh)(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), logw.astype(jnp.float32),
                            u.astype(jnp.float32)).astype(r.dtype)
