"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rsa_gemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """f32-accumulated GEMM (all modes compute the same function)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def adaptnetx_ref(ids, emb_m, emb_k, emb_n, w1, b1, w2, b2) -> jnp.ndarray:
    x = jnp.concatenate([emb_m[ids[0]], emb_k[ids[1]], emb_n[ids[2]]], -1)
    h = jnp.maximum(x.astype(jnp.float32) @ w1.astype(jnp.float32)
                    + b1.astype(jnp.float32), 0.0)
    return h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        kv_len: int | None = None,
                        scale: float | None = None) -> jnp.ndarray:
    """Naive f32 softmax attention.  q: (B,Sq,H,hd); k/v: (B,Skv,KVH,hd[_v])."""
    B, Sq, H, hd = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    if kv_len is None:
        kv_len = Skv
    qf = q.astype(jnp.float32).reshape(B, Sq, KVH, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    mask = (jnp.arange(Skv) < kv_len)[None, :]
    if causal:
        mask = mask & (jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None])
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Sq, H, vf.shape[-1]).astype(q.dtype)


def linear_attn_ref(r, k, v, logw, u) -> jnp.ndarray:
    """Exact sequential recurrence (the definition, O(S) steps).

    r,k,logw: (BH, S, K); v: (BH, S, V); u: (BH, K).
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k v^T
    """
    BH, S, K = r.shape
    V = v.shape[-1]

    def per_bh(rb, kb, vb, wb, ub):
        def step(h, xs):
            rt, kt, vt, wt = xs
            o = rt @ (h + ub[:, None] * (kt[:, None] * vt[None, :]))
            h = jnp.exp(wt)[:, None] * h + kt[:, None] * vt[None, :]
            return h, o

        h0 = jnp.zeros((K, V), jnp.float32)
        _, o = jax.lax.scan(step, h0, (rb, kb, vb, wb))
        return o

    return jax.vmap(per_bh)(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), logw.astype(jnp.float32),
                            u.astype(jnp.float32)).astype(r.dtype)
