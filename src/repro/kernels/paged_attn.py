"""Paged flash-attention Pallas kernels (GQA + absorbed-MLA): decode + prefill.

Attention kernels for a *physically paged* KV cache: K/V live in a block
arena ``(num_blocks, block_size, ...)`` shared by every lane, and each lane
reads only the pages its block table names.  The masked-dense decode path
(models/attention.py) streams ``num_slots * max_len`` KV rows per step
regardless of how many tokens are actually live; here the split-K grid
walks a lane's block table, so per-step traffic is ``sum_lane ceil(kv_len /
block_size) * block_size`` rows — attention cost scales with live tokens,
not slot capacity (the SARA size-to-the-workload argument applied to the
serving hot path).

Two kernel families share the structure:

* **decode** (``paged_gqa_decode_pallas`` / ``paged_mla_decode_pallas``) —
  one query token per lane attending over its whole table.
* **chunked prefill** (``paged_gqa_prefill_pallas`` /
  ``paged_mla_prefill_pallas``) — ``C`` query tokens per lane (one prompt
  chunk, already written to the arena by the caller) attending *causally*:
  chunk row ``r`` sits at absolute position ``starts[lane] + r`` and sees
  keys at positions ``<= starts[lane] + r``.  Per-lane ``starts`` /
  ``lengths`` make the batch ragged: lanes whose chunk is empty
  (``lengths[lane] == 0``) skip every block, which is how one prefill batch
  carries heterogeneous prompt lengths.

Grid layout: ``(lanes, kv_heads, table_width)`` (GQA) / ``(lanes,
table_width)`` (MLA), table width innermost.  The block table and per-lane
scalars ride in scalar prefetch (PrefetchScalarGridSpec) so the K/V
BlockSpec index maps resolve ``table[lane, j]`` before the body runs —
that indirection IS the paging.  Per (lane, head) the (m, l, acc) online
softmax state lives in VMEM scratch, reset at ``j == 0`` and emitted on the
last table column.  Callers pad dead table columns with the lane's last
live block id: Pallas elides the DMA when consecutive grid steps map to
the same block, and ``pl.when`` skips the compute, so padded columns cost
(almost) nothing.

Absorbed MLA attends in the compressed latent space: queries arrive
pre-absorbed (q @ W_UK) plus the shared-rope query, the arena stores
(c_kv, k_rope) rows, and the output is the latent mix ``p @ c_kv`` — the
caller applies W_UV/W_O outside (models/attention.py::mla_paged_decode /
mla_paged_prefill).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships the class as TPUCompilerParams; newer as CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def _gqa_kernel(tables, lengths, q_ref, k_ref, v_ref, o_ref,
                m_scr, l_scr, acc_scr, *, bs, n_bt, scale, logit_cap):
    lane = pl.program_id(0)
    j = pl.program_id(2)
    kv_len = lengths[lane]

    @pl.when(j == 0)
    def _reset():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * bs < kv_len)
    def _accumulate():
        q = q_ref[0, 0]                                    # (G, hd)
        k = k_ref[0, :, 0, :]                              # (bs, hd)
        v = v_ref[0, :, 0, :]                              # (bs, hd_v)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if logit_cap > 0.0:
            s = jnp.tanh(s / logit_cap) * logit_cap
        col = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(col < kv_len, s, NEG)
        m_prev, l_prev = m_scr[0], l_scr[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[0] = m_new
        l_scr[0] = l_prev * corr + jnp.sum(p, axis=-1)

    @pl.when(j == n_bt - 1)
    def _emit():
        # empty lanes (kv_len == 0) never accumulate: l == 0 -> zeros out
        l = jnp.maximum(l_scr[0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_gqa_decode_pallas(q, k_arena, v_arena, tables, lengths,
                            scale: float, interpret: bool,
                            logit_cap: float = 0.0) -> jnp.ndarray:
    """q: (S, KVH, G, hd); k_arena: (NB, bs, KVH, hd); v_arena:
    (NB, bs, KVH, hd_v); tables: (S, W) int32 physical block ids in logical
    order (tail-pad with the last live id); lengths: (S,) int32 valid
    tokens.  Returns (S, KVH, G, hd_v)."""
    S, KVH, G, hd = q.shape
    NB, bs = k_arena.shape[0], k_arena.shape[1]
    hd_v = v_arena.shape[-1]
    W = tables.shape[1]

    grid = (S, KVH, W)
    out = pl.pallas_call(
        functools.partial(_gqa_kernel, bs=bs, n_bt=W, scale=scale,
                          logit_cap=logit_cap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda s, h, j, t, ln: (s, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda s, h, j, t, ln: (t[s, j], 0, h, 0)),
                pl.BlockSpec((1, bs, 1, hd_v),
                             lambda s, h, j, t, ln: (t[s, j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd_v),
                                   lambda s, h, j, t, ln: (s, h, 0, 0)),
            scratch_shapes=[pltpu.VMEM((1, G), jnp.float32),
                            pltpu.VMEM((1, G), jnp.float32),
                            pltpu.VMEM((G, hd_v), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((S, KVH, G, hd_v), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lengths, q, k_arena, v_arena)
    return out


# ---------------------------------------------------------------------------
# absorbed MLA (latent-space attention; shared keys across heads)
# ---------------------------------------------------------------------------

def _mla_kernel(tables, lengths, qa_ref, qr_ref, ckv_ref, krope_ref, o_ref,
                m_scr, l_scr, acc_scr, *, bs, n_bt, scale):
    lane = pl.program_id(0)
    j = pl.program_id(1)
    kv_len = lengths[lane]

    @pl.when(j == 0)
    def _reset():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * bs < kv_len)
    def _accumulate():
        qa = qa_ref[0]                                     # (H, r)
        qr = qr_ref[0]                                     # (H, rd)
        ckv = ckv_ref[0]                                   # (bs, r)
        krope = krope_ref[0]                               # (bs, rd)
        s = (jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) +
             jax.lax.dot_general(qr, krope, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)) * scale
        col = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(col < kv_len, s, NEG)
        m_prev, l_prev = m_scr[0], l_scr[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(ckv.dtype), ckv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[0] = m_new
        l_scr[0] = l_prev * corr + jnp.sum(p, axis=-1)

    @pl.when(j == n_bt - 1)
    def _emit():
        l = jnp.maximum(l_scr[0], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_mla_decode_pallas(q_abs, q_rope, ckv_arena, krope_arena, tables,
                            lengths, scale: float,
                            interpret: bool) -> jnp.ndarray:
    """q_abs: (S, H, r) pre-absorbed queries; q_rope: (S, H, rd); ckv_arena:
    (NB, bs, r); krope_arena: (NB, bs, rd); tables: (S, W) int32; lengths:
    (S,) int32.  Returns the latent mix o_lat: (S, H, r)."""
    S, H, r = q_abs.shape
    rd = q_rope.shape[-1]
    NB, bs = ckv_arena.shape[0], ckv_arena.shape[1]
    W = tables.shape[1]

    grid = (S, W)
    out = pl.pallas_call(
        functools.partial(_mla_kernel, bs=bs, n_bt=W, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, r), lambda s, j, t, ln: (s, 0, 0)),
                pl.BlockSpec((1, H, rd), lambda s, j, t, ln: (s, 0, 0)),
                pl.BlockSpec((1, bs, r), lambda s, j, t, ln: (t[s, j], 0, 0)),
                pl.BlockSpec((1, bs, rd), lambda s, j, t, ln: (t[s, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, r), lambda s, j, t, ln: (s, 0, 0)),
            scratch_shapes=[pltpu.VMEM((1, H), jnp.float32),
                            pltpu.VMEM((1, H), jnp.float32),
                            pltpu.VMEM((H, r), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((S, H, r), q_abs.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lengths, q_abs, q_rope, ckv_arena, krope_arena)
    return out


# ---------------------------------------------------------------------------
# chunked prefill: C causal queries per lane over previously-written pages
# ---------------------------------------------------------------------------

def _gqa_prefill_kernel(tables, starts, lengths, q_ref, k_ref, v_ref, o_ref,
                        m_scr, l_scr, acc_scr, *, bs, n_bt, scale, logit_cap):
    lane = pl.program_id(0)
    j = pl.program_id(2)
    kv_len = lengths[lane]          # rows valid AFTER this chunk's write
    q0 = starts[lane]               # absolute position of chunk row 0
    C, G = q_ref.shape[1], q_ref.shape[3]
    CG = C * G

    @pl.when(j == 0)
    def _reset():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * bs < kv_len)
    def _accumulate():
        q = q_ref[0, :, 0].reshape(CG, q_ref.shape[-1])    # (C*G, hd)
        k = k_ref[0, :, 0, :]                              # (bs, hd)
        v = v_ref[0, :, 0, :]                              # (bs, hd_v)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if logit_cap > 0.0:
            s = jnp.tanh(s / logit_cap) * logit_cap
        col = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # flat row i is chunk row i // G at absolute position q0 + i // G;
        # the causal mask makes each chunk query see only keys at or before
        # its own position (block 0 always has col 0 <= q0 + row, so every
        # live row accumulates a finite max there — no exp(0) blowups)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        s = jnp.where((col < kv_len) & (col <= qpos), s, NEG)
        m_prev, l_prev = m_scr[0], l_scr[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[0] = m_new
        l_scr[0] = l_prev * corr + jnp.sum(p, axis=-1)

    @pl.when(j == n_bt - 1)
    def _emit():
        # empty lanes (kv_len == 0) never accumulate: l == 0 -> zeros out
        l = jnp.maximum(l_scr[0], 1e-30)
        o = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        o_ref[0, :, 0] = o.reshape(C, G, o_ref.shape[-1])


def paged_gqa_prefill_pallas(q, k_arena, v_arena, tables, starts, lengths,
                             scale: float, interpret: bool,
                             logit_cap: float = 0.0) -> jnp.ndarray:
    """q: (S, C, KVH, G, hd) one prompt chunk per lane; k_arena: (NB, bs,
    KVH, hd); v_arena: (NB, bs, KVH, hd_v); tables: (S, W) int32 physical
    block ids in logical order (tail-pad with the last live id); starts:
    (S,) int32 absolute position of each lane's chunk row 0; lengths: (S,)
    int32 valid tokens *including* the chunk (``starts + chunk_len``).
    The chunk's own K/V rows must already be in the arena.  Returns
    (S, C, KVH, G, hd_v); rows past a lane's chunk are garbage the caller
    discards, lanes with length 0 yield zeros."""
    S, C, KVH, G, hd = q.shape
    bs = k_arena.shape[1]
    hd_v = v_arena.shape[-1]
    W = tables.shape[1]

    grid = (S, KVH, W)
    out = pl.pallas_call(
        functools.partial(_gqa_prefill_kernel, bs=bs, n_bt=W, scale=scale,
                          logit_cap=logit_cap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, C, 1, G, hd),
                             lambda s, h, j, t, st, ln: (s, 0, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda s, h, j, t, st, ln: (t[s, j], 0, h, 0)),
                pl.BlockSpec((1, bs, 1, hd_v),
                             lambda s, h, j, t, st, ln: (t[s, j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, C, 1, G, hd_v),
                                   lambda s, h, j, t, st, ln: (s, 0, h, 0, 0)),
            scratch_shapes=[pltpu.VMEM((1, C * G), jnp.float32),
                            pltpu.VMEM((1, C * G), jnp.float32),
                            pltpu.VMEM((C * G, hd_v), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((S, C, KVH, G, hd_v), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables, starts, lengths, q, k_arena, v_arena)
    return out


def _mla_prefill_kernel(tables, starts, lengths, qa_ref, qr_ref, ckv_ref,
                        krope_ref, o_ref, m_scr, l_scr, acc_scr, *, bs, n_bt,
                        scale):
    lane = pl.program_id(0)
    j = pl.program_id(1)
    kv_len = lengths[lane]
    q0 = starts[lane]
    C, H = qa_ref.shape[1], qa_ref.shape[2]
    CH = C * H

    @pl.when(j == 0)
    def _reset():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * bs < kv_len)
    def _accumulate():
        qa = qa_ref[0].reshape(CH, qa_ref.shape[-1])       # (C*H, r)
        qr = qr_ref[0].reshape(CH, qr_ref.shape[-1])       # (C*H, rd)
        ckv = ckv_ref[0]                                   # (bs, r)
        krope = krope_ref[0]                               # (bs, rd)
        s = (jax.lax.dot_general(qa, ckv, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) +
             jax.lax.dot_general(qr, krope, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)) * scale
        col = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // H
        s = jnp.where((col < kv_len) & (col <= qpos), s, NEG)
        m_prev, l_prev = m_scr[0], l_scr[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(ckv.dtype), ckv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[0] = m_new
        l_scr[0] = l_prev * corr + jnp.sum(p, axis=-1)

    @pl.when(j == n_bt - 1)
    def _emit():
        l = jnp.maximum(l_scr[0], 1e-30)
        o = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        o_ref[0] = o.reshape(C, H, o_ref.shape[-1])


def _gqa_lse_kernel(tables, lengths, q_ref, k_ref, v_ref, o_ref, m_ref,
                    l_ref, m_scr, l_scr, acc_scr, *, bs, n_bt, scale,
                    logit_cap):
    lane = pl.program_id(0)
    j = pl.program_id(2)
    kv_len = lengths[lane]

    @pl.when(j == 0)
    def _reset():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * bs < kv_len)
    def _accumulate():
        q = q_ref[0, 0]                                    # (G, hd)
        k = k_ref[0, :, 0, :]                              # (bs, hd)
        v = v_ref[0, :, 0, :]                              # (bs, hd_v)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if logit_cap > 0.0:
            s = jnp.tanh(s / logit_cap) * logit_cap
        col = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(col < kv_len, s, NEG)
        m_prev, l_prev = m_scr[0], l_scr[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[0] = m_new
        l_scr[0] = l_prev * corr + jnp.sum(p, axis=-1)

    @pl.when(j == n_bt - 1)
    def _emit():
        # empty lanes (kv_len == 0) never accumulate: the (0, NEG, 0) state
        # makes the softmax-state merge degenerate to the other phase
        l = jnp.maximum(l_scr[0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        m_ref[0, 0] = m_scr[0]
        l_ref[0, 0] = l_scr[0]


def paged_gqa_decode_lse_pallas(q, k_arena, v_arena, tables, lengths,
                                scale: float, interpret: bool,
                                logit_cap: float = 0.0):
    """:func:`paged_gqa_decode_pallas` that also emits the online-softmax
    state — the per-lane *unique* phase of cascade decode, whose result is
    merged with the shared-prefix phase outside the kernel.  Returns
    (o (S, KVH, G, hd_v) normalized, m (S, KVH, G) f32 running max,
    l (S, KVH, G) f32 exp-sum)."""
    S, KVH, G, hd = q.shape
    bs = k_arena.shape[1]
    hd_v = v_arena.shape[-1]
    W = tables.shape[1]

    grid = (S, KVH, W)
    out, m, l = pl.pallas_call(
        functools.partial(_gqa_lse_kernel, bs=bs, n_bt=W, scale=scale,
                          logit_cap=logit_cap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda s, h, j, t, ln: (s, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda s, h, j, t, ln: (t[s, j], 0, h, 0)),
                pl.BlockSpec((1, bs, 1, hd_v),
                             lambda s, h, j, t, ln: (t[s, j], 0, h, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, G, hd_v),
                             lambda s, h, j, t, ln: (s, h, 0, 0)),
                pl.BlockSpec((1, 1, G), lambda s, h, j, t, ln: (s, h, 0)),
                pl.BlockSpec((1, 1, G), lambda s, h, j, t, ln: (s, h, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((1, G), jnp.float32),
                            pltpu.VMEM((1, G), jnp.float32),
                            pltpu.VMEM((G, hd_v), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((S, KVH, G, hd_v), q.dtype),
                   jax.ShapeDtypeStruct((S, KVH, G), jnp.float32),
                   jax.ShapeDtypeStruct((S, KVH, G), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lengths, q, k_arena, v_arena)
    return out, m, l


# ---------------------------------------------------------------------------
# shared-prefix (cascade) decode: one walk over the hot pages for all lanes
# ---------------------------------------------------------------------------

def _gqa_prefix_kernel(tables, nlive, plen_ref, q_ref, k_ref, v_ref,
                       o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr, *,
                       bs, n_bt, scale, logit_cap):
    j = pl.program_id(1)
    S, G = q_ref.shape[0], q_ref.shape[2]
    SG = S * G

    @pl.when(j == 0)
    def _reset():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * bs < nlive[0])
    def _accumulate():
        # every lane's queries stacked into one MXU call against the SAME
        # page: the page DMA happens once per (kv_head, page) grid step,
        # not once per lane — that is the cascade win
        q = q_ref[:, 0].reshape(SG, q_ref.shape[-1])       # (S*G, hd)
        k = k_ref[0, :, 0, :]                              # (bs, hd)
        v = v_ref[0, :, 0, :]                              # (bs, hd_v)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if logit_cap > 0.0:
            s = jnp.tanh(s / logit_cap) * logit_cap
        col = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # flat row i belongs to lane i // G; its prefix_len gates how much
        # of the shared run it attends (0 = lane outside the group).  The
        # explicit mask on p — not just on s — keeps fully-masked rows at
        # l == 0: with m == NEG every masked exp(s - m) would be exp(0)
        plen = jnp.broadcast_to(plen_ref[...], (S, G)).reshape(SG, 1)
        live = col < plen
        s = jnp.where(live, s, NEG)
        m_prev, l_prev = m_scr[0], l_scr[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(live, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[0] = m_new
        l_scr[0] = l_prev * corr + jnp.sum(p, axis=-1)

    @pl.when(j == n_bt - 1)
    def _emit():
        l = jnp.maximum(l_scr[0], 1e-30)
        o = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        o_ref[:, 0] = o.reshape(S, G, o_ref.shape[-1])
        m_ref[:, 0] = m_scr[0].reshape(S, G)
        l_ref[:, 0] = l_scr[0].reshape(S, G)


def paged_gqa_prefix_pallas(q, k_arena, v_arena, prefix_pages, prefix_lens,
                            scale: float, interpret: bool,
                            logit_cap: float = 0.0):
    """Shared-prefix phase of cascade decode: ONE grid walk over the hot
    prefix pages serves every lane at once.

    q: (S, KVH, G, hd); prefix_pages: (P,) int32 physical pages of the
    shared prefix in logical order (tail-pad with the last id);
    prefix_lens: (S,) int32 prefix rows lane s attends (0 = lane not in the
    sharing group).  The grid is (KVH, P) — lanes are NOT a grid dimension;
    all S lanes' queries hit each page block together, so a prefix shared
    by k lanes is streamed once instead of k times.  Returns (o (S, KVH, G,
    hd_v) normalized, m (S, KVH, G) f32, l (S, KVH, G) f32); lanes with
    prefix_lens == 0 come back as (0, NEG, 0) so the merge degenerates to
    the unique phase."""
    S, KVH, G, hd = q.shape
    bs = k_arena.shape[1]
    hd_v = v_arena.shape[-1]
    P = prefix_pages.shape[0]
    # scalar skip bound for padded tail columns (every sharing lane spans
    # the same page run, so max == the run's row count)
    nlive = jnp.max(prefix_lens).astype(jnp.int32).reshape(1)
    # per-lane lengths ride as a VMEM operand (not scalar prefetch): the
    # kernel needs them as a vector to mask the stacked (S*G, bs) scores
    plens2d = prefix_lens.astype(jnp.int32).reshape(S, 1)

    grid = (KVH, P)
    out, m, l = pl.pallas_call(
        functools.partial(_gqa_prefix_kernel, bs=bs, n_bt=P, scale=scale,
                          logit_cap=logit_cap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((S, 1), lambda h, j, t, nl: (0, 0)),
                pl.BlockSpec((S, 1, G, hd), lambda h, j, t, nl: (0, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda h, j, t, nl: (t[j], 0, h, 0)),
                pl.BlockSpec((1, bs, 1, hd_v),
                             lambda h, j, t, nl: (t[j], 0, h, 0)),
            ],
            out_specs=[
                pl.BlockSpec((S, 1, G, hd_v),
                             lambda h, j, t, nl: (0, h, 0, 0)),
                pl.BlockSpec((S, 1, G), lambda h, j, t, nl: (0, h, 0)),
                pl.BlockSpec((S, 1, G), lambda h, j, t, nl: (0, h, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((1, S * G), jnp.float32),
                            pltpu.VMEM((1, S * G), jnp.float32),
                            pltpu.VMEM((S * G, hd_v), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((S, KVH, G, hd_v), q.dtype),
                   jax.ShapeDtypeStruct((S, KVH, G), jnp.float32),
                   jax.ShapeDtypeStruct((S, KVH, G), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(prefix_pages, nlive, plens2d, q, k_arena, v_arena)
    return out, m, l


def paged_mla_prefill_pallas(q_abs, q_rope, ckv_arena, krope_arena, tables,
                             starts, lengths, scale: float,
                             interpret: bool) -> jnp.ndarray:
    """q_abs: (S, C, H, r) pre-absorbed chunk queries; q_rope: (S, C, H, rd);
    ckv_arena: (NB, bs, r); krope_arena: (NB, bs, rd); tables: (S, W) int32;
    starts / lengths: (S,) int32 as in :func:`paged_gqa_prefill_pallas`.
    Returns the latent mix o_lat: (S, C, H, r)."""
    S, C, H, r = q_abs.shape
    rd = q_rope.shape[-1]
    bs = ckv_arena.shape[1]
    W = tables.shape[1]

    grid = (S, W)
    out = pl.pallas_call(
        functools.partial(_mla_prefill_kernel, bs=bs, n_bt=W, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, C, H, r),
                             lambda s, j, t, st, ln: (s, 0, 0, 0)),
                pl.BlockSpec((1, C, H, rd),
                             lambda s, j, t, st, ln: (s, 0, 0, 0)),
                pl.BlockSpec((1, bs, r),
                             lambda s, j, t, st, ln: (t[s, j], 0, 0)),
                pl.BlockSpec((1, bs, rd),
                             lambda s, j, t, st, ln: (t[s, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, C, H, r),
                                   lambda s, j, t, st, ln: (s, 0, 0, 0)),
            scratch_shapes=[pltpu.VMEM((1, C * H), jnp.float32),
                            pltpu.VMEM((1, C * H), jnp.float32),
                            pltpu.VMEM((C * H, r), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((S, C, H, r), q_abs.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, starts, lengths, q_abs, q_rope, ckv_arena, krope_arena)
    return out
