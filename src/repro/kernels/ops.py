"""Public jit'd wrappers for the Pallas kernels.

Pad-to-block handling, dtype plumbing, and the interpret switch live here.
The interpret default is backend-aware: ``interpret=None`` resolves to
compiled execution on TPU and Python interpret mode everywhere else, so
the same call sites run the real kernel on TPU with no flag plumbing.
Pass an explicit bool to override.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hw import OS
from repro.kernels.adaptnetx import adaptnetx_pallas
from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.linear_attn import linear_attn_pallas
from repro.kernels.rsa_gemm import rsa_gemm_pallas


def default_interpret() -> bool:
    """Compiled Pallas on TPU; interpret mode on every other backend."""
    return jax.default_backend() != "tpu"


def _interpret(flag: Optional[bool]) -> bool:
    return default_interpret() if flag is None else flag


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "mode", "interpret"))
def rsa_gemm(a: jnp.ndarray, b: jnp.ndarray, *, block_m: int = 128,
             block_n: int = 128, block_k: int = 256, mode: int = OS,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """(M, K) @ (K, N) with SARA-configurable tiling; arbitrary shapes."""
    M, N = a.shape[0], b.shape[1]
    a2 = _pad_to(_pad_to(a, 0, block_m), 1, block_k)
    b2 = _pad_to(_pad_to(b, 0, block_k), 1, block_n)
    out = rsa_gemm_pallas(a2, b2, block_m=block_m, block_n=block_n,
                          block_k=block_k, mode=mode,
                          interpret=_interpret(interpret))
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("interpret",))
def adaptnetx_recommend(ids: jnp.ndarray, params: dict, *,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """One fused recommendation query.  ids: (3,) int32 -> logits."""
    return adaptnetx_pallas(
        ids, params["emb_m"], params["emb_k"], params["emb_n"],
        params["w1"], params["b1"], params["w2"], params["b2"],
        interpret=_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: Optional[bool] = None):
    """Flash attention with arbitrary Sq/Skv (pads to block multiples).

    q: (B, Sq, H, hd); k: (B, Skv, KVH, hd); v: (B, Skv, KVH, hd_v)
    -> (B, Sq, H, hd_v).  Differentiable (custom-vjp Pallas backward).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    bq = min(block_q, max(Sq, 1))
    bk = min(block_k, max(Skv, 1))
    scale = 1.0 / (hd ** 0.5)
    q2 = _pad_to(q, 1, bq)
    k2 = _pad_to(k, 1, bk)
    v2 = _pad_to(v, 1, bk)
    o = flash_attention_pallas(q2, k2, v2, causal, scale, Skv, bq, bk,
                               _interpret(interpret))
    return o[:, :Sq]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linear_attn(r, k, v, logw, u, *, chunk: int = 64,
                interpret: Optional[bool] = None):
    """Chunked linear attention; pads S to the chunk multiple.

    r,k,logw: (BH, S, K); v: (BH, S, V); u: (BH, K) -> (BH, S, V).
    """
    S = r.shape[1]
    rr = _pad_to(r, 1, chunk)
    kk = _pad_to(k, 1, chunk)
    vv = _pad_to(v, 1, chunk)
    ww = _pad_to(logw, 1, chunk)
    o = linear_attn_pallas(rr, kk, vv, ww, u, chunk=chunk,
                           interpret=_interpret(interpret))
    return o[:, :S]


def default_paged_impl() -> str:
    """Compiled Pallas paged kernel on TPU; jitted XLA gather elsewhere
    (mirrors dispatch ``execute="auto"`` — interpret-mode Pallas in the
    per-step decode hot loop would be pure Python overhead off-TPU)."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _paged_impl(impl: Optional[str]) -> str:
    return default_paged_impl() if impl is None else impl


@functools.partial(jax.jit, static_argnames=("logit_cap", "impl",
                                             "interpret"))
def paged_attention(q, k_arena, v_arena, tables, lengths, *,
                    logit_cap: float = 0.0,
                    impl: Optional[str] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Paged flash-decode (GQA/MQA): each lane attends only to the KV pages
    its block table names.

    q: (S, H, hd) one query token per lane; k_arena: (NB, bs, KVH, hd);
    v_arena: (NB, bs, KVH, hd_v); tables: (S, W) int32 physical block ids
    in logical order (tail-pad with the last live id); lengths: (S,) int32.
    Returns (S, H, hd_v); lanes with length 0 yield zeros.
    """
    S, H, hd = q.shape
    KVH = k_arena.shape[2]
    scale = 1.0 / (hd ** 0.5)
    if _paged_impl(impl) == "xla":
        from repro.kernels.ref import paged_attention_ref
        return paged_attention_ref(q, k_arena, v_arena, tables, lengths,
                                   scale=scale, logit_cap=logit_cap)
    from repro.kernels.paged_attn import paged_gqa_decode_pallas
    qg = q.reshape(S, KVH, H // KVH, hd)
    o = paged_gqa_decode_pallas(qg, k_arena, v_arena, tables, lengths,
                                scale, _interpret(interpret),
                                logit_cap=logit_cap)
    return o.reshape(S, H, v_arena.shape[-1])


@functools.partial(jax.jit, static_argnames=("logit_cap", "impl",
                                             "interpret"))
def shared_paged_attention(q, k_arena, v_arena, unique_tables, unique_lens,
                           prefix_pages, prefix_lens, *,
                           logit_cap: float = 0.0,
                           impl: Optional[str] = None,
                           interpret: Optional[bool] = None) -> jnp.ndarray:
    """Cascade decode for shared prefixes: one softmax pass over a lane's
    shared-prefix rows (streamed ONCE for every sharing lane via
    ``prefix_pages``) plus one over its unique suffix rows (per-lane
    ``unique_tables``).  Mathematically equal to :func:`paged_attention`
    over the concatenated page lists.  The XLA reference rebuilds each
    lane's combined table and runs ONE masked softmax, so it is BITWISE
    equal to the plain path (greedy cascade parity is asserted, not
    approximate); the Pallas path keeps the two-phase online-softmax
    merge — streaming the shared pages once per group is its point — and
    matches numerically.

    q: (S, H, hd) one query token per lane; prefix_pages: (P,) int32 pages
    every sharing lane's table starts with (tail-pad with the last id);
    prefix_lens: (S,) int32 prefix rows lane s attends (0 = lane not in
    the sharing group); unique_tables: (S, W) int32 each lane's pages PAST
    the prefix (its full table shifted left; non-members keep their whole
    table here); unique_lens: (S,) int32 valid suffix rows.  Returns
    (S, H, hd_v); lanes empty in both phases yield zeros.
    """
    S, H, hd = q.shape
    KVH = k_arena.shape[2]
    scale = 1.0 / (hd ** 0.5)
    if _paged_impl(impl) == "xla":
        from repro.kernels.ref import shared_paged_attention_ref
        return shared_paged_attention_ref(
            q, k_arena, v_arena, unique_tables, unique_lens, prefix_pages,
            prefix_lens, scale=scale, logit_cap=logit_cap)
    from repro.kernels.paged_attn import (paged_gqa_decode_lse_pallas,
                                          paged_gqa_prefix_pallas)
    from repro.kernels.ref import merge_softmax_states
    qg = q.reshape(S, KVH, H // KVH, hd)
    itp = _interpret(interpret)
    o_p, m_p, l_p = paged_gqa_prefix_pallas(
        qg, k_arena, v_arena, prefix_pages, prefix_lens, scale, itp,
        logit_cap=logit_cap)
    o_u, m_u, l_u = paged_gqa_decode_lse_pallas(
        qg, k_arena, v_arena, unique_tables, unique_lens, scale, itp,
        logit_cap=logit_cap)
    o, _, _ = merge_softmax_states(o_p, m_p, l_p, o_u, m_u, l_u)
    return o.astype(q.dtype).reshape(S, H, v_arena.shape[-1])


@functools.partial(jax.jit, static_argnames=("qk_dim", "impl", "interpret"))
def mla_paged_attention(q_abs, q_rope, ckv_arena, krope_arena, tables,
                        lengths, *, qk_dim: int,
                        impl: Optional[str] = None,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """Paged flash-decode for absorbed MLA: attend in the compressed latent
    space through the block table; ``qk_dim`` is the full per-head query-key
    dim (nope + rope) setting the softmax scale.  Returns o_lat (S, H, r).
    """
    scale = 1.0 / (qk_dim ** 0.5)
    if _paged_impl(impl) == "xla":
        from repro.kernels.ref import paged_mla_attention_ref
        return paged_mla_attention_ref(q_abs, q_rope, ckv_arena, krope_arena,
                                       tables, lengths, scale=scale)
    from repro.kernels.paged_attn import paged_mla_decode_pallas
    return paged_mla_decode_pallas(q_abs, q_rope, ckv_arena, krope_arena,
                                   tables, lengths, scale,
                                   _interpret(interpret))


@functools.partial(jax.jit, static_argnames=("logit_cap", "impl",
                                             "interpret"))
def paged_prefill_attention(q, k_arena, v_arena, tables, starts, lengths, *,
                            logit_cap: float = 0.0,
                            impl: Optional[str] = None,
                            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Chunked paged prefill (GQA/MQA): each lane's prompt chunk attends
    causally through its block table to every page written so far,
    including the chunk's own rows (which the caller wrote before calling).

    q: (S, C, H, hd) one chunk of queries per lane; k_arena: (NB, bs, KVH,
    hd); v_arena: (NB, bs, KVH, hd_v); tables: (S, W) int32 physical block
    ids in logical order (tail-pad with the last live id); starts: (S,)
    int32 absolute position of chunk row 0; lengths: (S,) int32 valid
    tokens including the chunk.  Returns (S, C, H, hd_v); rows at or past
    a lane's chunk length are garbage the caller discards, and lanes with
    length 0 yield zeros.
    """
    S, C, H, hd = q.shape
    KVH = k_arena.shape[2]
    scale = 1.0 / (hd ** 0.5)
    if _paged_impl(impl) == "xla":
        from repro.kernels.ref import paged_prefill_attention_ref
        return paged_prefill_attention_ref(q, k_arena, v_arena, tables,
                                           starts, lengths, scale=scale,
                                           logit_cap=logit_cap)
    from repro.kernels.paged_attn import paged_gqa_prefill_pallas
    qg = q.reshape(S, C, KVH, H // KVH, hd)
    o = paged_gqa_prefill_pallas(qg, k_arena, v_arena, tables, starts,
                                 lengths, scale, _interpret(interpret),
                                 logit_cap=logit_cap)
    return o.reshape(S, C, H, v_arena.shape[-1])


@functools.partial(jax.jit, static_argnames=("qk_dim", "impl", "interpret"))
def mla_paged_prefill_attention(q_abs, q_rope, ckv_arena, krope_arena,
                                tables, starts, lengths, *, qk_dim: int,
                                impl: Optional[str] = None,
                                interpret: Optional[bool] = None
                                ) -> jnp.ndarray:
    """Chunked paged prefill for absorbed MLA: attend in the compressed
    latent space through the block table with causal chunk masking;
    ``qk_dim`` is the full per-head query-key dim (nope + rope) setting the
    softmax scale.  Shapes as in :func:`paged_prefill_attention` with
    q_abs (S, C, H, r) / q_rope (S, C, H, rd).  Returns o_lat (S, C, H, r).
    """
    scale = 1.0 / (qk_dim ** 0.5)
    if _paged_impl(impl) == "xla":
        from repro.kernels.ref import paged_mla_prefill_attention_ref
        return paged_mla_prefill_attention_ref(
            q_abs, q_rope, ckv_arena, krope_arena, tables, starts, lengths,
            scale=scale)
    from repro.kernels.paged_attn import paged_mla_prefill_pallas
    return paged_mla_prefill_pallas(q_abs, q_rope, ckv_arena, krope_arena,
                                    tables, starts, lengths, scale,
                                    _interpret(interpret))


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def wkv_attention(r, k, v, logw, u, state0, chunk: int = 64,
                  interpret: Optional[bool] = None):
    """RWKV6/GLA chunked linear attention, Pallas fwd + reference-VJP bwd.

    r, k, logw: (B, S, H, K); v: (B, S, H, V); u: (H, K);
    state0: (B, H, K, V) -> (o: (B, S, H, V), state: (B, H, K, V)).
    Backward recomputes through the pure-jnp chunked scan (models/ssm.py),
    so train cells stay differentiable; the fwd-only prefill/decode path is
    the §Perf target the kernel accelerates.
    """
    return _wkv_fwd_impl(r, k, v, logw, u, state0, chunk, interpret)


def _wkv_fwd_impl(r, k, v, logw, u, state0, chunk, interpret):
    from repro.kernels.linear_attn import linear_attn_bshk_pallas
    S = r.shape[1]
    rr = _pad_to(r, 1, chunk)
    kk = _pad_to(k, 1, chunk)
    vv = _pad_to(v, 1, chunk)
    ww = _pad_to(logw, 1, chunk)
    o, sf = linear_attn_bshk_pallas(rr, kk, vv, ww, u, state0, chunk=chunk,
                                    interpret=_interpret(interpret))
    return o[:, :S], sf


def _wkv_vjp_fwd(r, k, v, logw, u, state0, chunk, interpret):
    out = _wkv_fwd_impl(r, k, v, logw, u, state0, chunk, interpret)
    return out, (r, k, v, logw, u, state0)


def _wkv_vjp_bwd(chunk, interpret, res, cts):
    from repro.models.ssm import _wkv_chunked
    r, k, v, logw, u, state0 = res
    _, vjp = jax.vjp(
        lambda r_, k_, v_, w_, u_, s_: _wkv_chunked(r_, k_, v_, w_, u_, s_,
                                                    chunk),
        r, k, v, logw, u, state0)
    return vjp(cts)


wkv_attention.defvjp(_wkv_vjp_fwd, _wkv_vjp_bwd)
