"""SARA dispatcher: recommendations are feasible + execution is exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tpu_costmodel as tcm
from repro.core.hw import TPU_V5E
from repro.core.sara import SaraDispatcher


def test_tile_space_enumeration():
    assert tcm.NUM_TILE_CLASSES == len(tcm.TILE_CONFIGS) == 3 * 3 * 5 * 3


def test_recommendations_feasible():
    d = SaraDispatcher()
    for M, K, N in [(128, 128, 128), (4096, 4096, 4096), (37, 9000, 222)]:
        cfg = d.recommend(M, K, N)
        vmem = (cfg.block_m * cfg.block_k + cfg.block_k * cfg.block_n
                + cfg.block_m * cfg.block_n) * 2 * tcm.DTYPE_BYTES
        assert vmem <= TPU_V5E.vmem_bytes


def test_recommendation_cached_constant_time():
    d = SaraDispatcher()
    c1 = d.recommend(512, 512, 512)
    c2 = d.recommend(512, 512, 512)
    assert c1 is c2


def test_oracle_beats_fixed_config_on_average():
    rng = np.random.default_rng(0)
    M, K, N = (rng.integers(64, 8192, 200) for _ in range(3))
    costs = tcm.tile_cost_seconds(M, K, N)
    best = costs.min(-1)
    fixed = costs[:, 0]
    assert np.mean(best / fixed) < 1.0


def test_dispatcher_gemm_matches_einsum():
    d = SaraDispatcher(use_pallas=False)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 96, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 48))
    np.testing.assert_allclose(np.asarray(d.gemm(x, w)),
                               np.asarray(jnp.einsum("bmk,kn->bmn", x, w)),
                               rtol=1e-5, atol=1e-5)


def test_dispatcher_gemm_pallas_path():
    d = SaraDispatcher(use_pallas=True)
    x = jax.random.normal(jax.random.PRNGKey(0), (160, 192))
    w = jax.random.normal(jax.random.PRNGKey(1), (192, 130))
    np.testing.assert_allclose(np.asarray(d.gemm(x, w)),
                               np.asarray(x @ w), rtol=2e-4, atol=2e-4)


def test_sharding_planner_sensible():
    # huge square GEMM -> use the whole mesh (2d)
    assert tcm.plan_gemm_sharding(8192, 8192, 8192).name in ("2d",)
    # tiny GEMM -> replicated beats paying collectives
    assert tcm.plan_gemm_sharding(64, 64, 64).name in ("replicated", "row_dp")
    # M indivisible by data -> no row sharding chosen
    p = tcm.plan_gemm_sharding(63, 4096, 4096)
    assert p.x_spec[0] != "data"


def test_adaptnet_tpu_learns_tile_space():
    """Scaled-down training run on the (harder, 135-class) TPU tile space;
    the full-scale numbers live in benchmarks/bench_sara_tpu."""
    from repro.core.sara import train_adaptnet_tpu
    params, acc, geo = train_adaptnet_tpu(n_samples=40_000, epochs=8)
    assert acc >= 0.5
    assert geo <= 1.15
    d = SaraDispatcher(mode="adaptnet", adaptnet_params=params)
    cfg = d.recommend(1024, 1024, 1024)
    assert cfg in tcm.TILE_CONFIGS
