"""SARA dispatcher: recommendations are feasible + execution is exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptnet as A
from repro.core import tpu_costmodel as tcm
from repro.core.hw import TPU_V5E
from repro.core.sara import SaraDispatcher


def _logbucket_params(max_dim=4096, num_buckets=32, seed=0):
    return A.init_params(jax.random.PRNGKey(seed), A.AdaptNetConfig(
        num_classes=tcm.NUM_TILE_CLASSES, encoding="logbucket",
        num_buckets=num_buckets, max_dim=max_dim))


def test_tile_space_enumeration():
    assert tcm.NUM_TILE_CLASSES == len(tcm.TILE_CONFIGS) == 3 * 3 * 5 * 3


def test_recommendations_feasible():
    d = SaraDispatcher()
    for M, K, N in [(128, 128, 128), (4096, 4096, 4096), (37, 9000, 222)]:
        cfg = d.recommend(M, K, N)
        vmem = (cfg.block_m * cfg.block_k + cfg.block_k * cfg.block_n
                + cfg.block_m * cfg.block_n) * 2 * tcm.DTYPE_BYTES
        assert vmem <= TPU_V5E.vmem_bytes


def test_recommendation_cached_constant_time():
    d = SaraDispatcher()
    c1 = d.recommend(512, 512, 512)
    c2 = d.recommend(512, 512, 512)
    assert c1 is c2


def test_oracle_beats_fixed_config_on_average():
    rng = np.random.default_rng(0)
    M, K, N = (rng.integers(64, 8192, 200) for _ in range(3))
    costs = tcm.tile_cost_seconds(M, K, N)
    best = costs.min(-1)
    fixed = costs[:, 0]
    assert np.mean(best / fixed) < 1.0


def test_dispatcher_gemm_matches_einsum():
    d = SaraDispatcher(use_pallas=False)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 96, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 48))
    np.testing.assert_allclose(np.asarray(d.gemm(x, w)),
                               np.asarray(jnp.einsum("bmk,kn->bmn", x, w)),
                               rtol=1e-5, atol=1e-5)


def test_dispatcher_gemm_pallas_path():
    d = SaraDispatcher(use_pallas=True)
    x = jax.random.normal(jax.random.PRNGKey(0), (160, 192))
    w = jax.random.normal(jax.random.PRNGKey(1), (192, 130))
    np.testing.assert_allclose(np.asarray(d.gemm(x, w)),
                               np.asarray(x @ w), rtol=2e-4, atol=2e-4)


def test_sharding_planner_sensible():
    # huge square GEMM -> use the whole mesh (2d)
    assert tcm.plan_gemm_sharding(8192, 8192, 8192).name in ("2d",)
    # tiny GEMM -> replicated beats paying collectives
    assert tcm.plan_gemm_sharding(64, 64, 64).name in ("replicated", "row_dp")
    # M indivisible by data -> no row sharding chosen
    p = tcm.plan_gemm_sharding(63, 4096, 4096)
    assert p.x_spec[0] != "data"


def test_cache_invalidated_on_mode_or_params_change():
    """Regression: flipping ``mode`` or installing ``adaptnet_params`` on a
    live dispatcher used to keep serving stale cached recommendations from
    the previous source."""
    d = SaraDispatcher()
    d.recommend(512, 512, 512)
    assert d.cache_info()["size"] == 1
    assert d.source_of(512, 512, 512) == "oracle"

    d.mode = "adaptnet"
    d.adaptnet_params = _logbucket_params()
    assert d.cache_info()["size"] == 0         # stale oracle recs dropped
    d.recommend(512, 512, 512)
    assert d.source_of(512, 512, 512) == "adaptnet"
    assert d.cache_info()["hits"] == 0         # re-decided, not replayed

    d.mode = "oracle"
    assert d.cache_info()["size"] == 0
    d.recommend(512, 512, 512)
    assert d.source_of(512, 512, 512) == "oracle"


def test_out_of_range_falls_back_to_oracle():
    """Legacy raw-encoding params clip every dim > 10^4 to one embedding
    row, so lm_head-scale shapes must take the explicit oracle path, never
    the aliased lookup."""
    raw = A.init_params(jax.random.PRNGKey(0), A.AdaptNetConfig(
        num_classes=tcm.NUM_TILE_CLASSES))          # raw: vocab 10001
    d = SaraDispatcher(mode="adaptnet", adaptnet_params=raw)
    assert not d.in_trained_range(64, 2048, 128256)
    cfg = d.recommend(64, 2048, 128256)             # gemma/llama lm_head
    assert d.source_of(64, 2048, 128256) == "oracle_fallback"
    assert cfg is tcm.TILE_CONFIGS[int(tcm.best_tile_config(64, 2048,
                                                            128256))]
    d.recommend(100, 200, 300)                      # within [1, 10^4]
    assert d.source_of(100, 200, 300) == "adaptnet"
    assert d.source_info() == {"adaptnet": 1, "oracle": 0,
                               "oracle_fallback": 1}
    # logbucket params carry their coverage bound instead
    d2 = SaraDispatcher(mode="adaptnet",
                        adaptnet_params=_logbucket_params(max_dim=4096))
    assert d2.in_trained_range(64, 2048, 4096)
    assert not d2.in_trained_range(64, 2048, 4097)


def test_recommend_batch_matches_scalar():
    shapes = [(64, 2048, 128256), (1, 64, 128), (1, 64, 128),
              (512, 512, 512), (300_000, 1, 1)]
    d_batch = SaraDispatcher(mode="adaptnet",
                             adaptnet_params=_logbucket_params(
                                 max_dim=A.MAX_DIM_SERVING))
    d_one = SaraDispatcher(mode="adaptnet",
                           adaptnet_params=d_batch.adaptnet_params)
    batch = d_batch.recommend_batch(shapes)
    singles = [d_one.recommend(*s) for s in shapes]
    assert batch == singles
    for s in shapes:
        assert d_batch.source_of(*s) == d_one.source_of(*s)
    assert d_batch.source_of(300_000, 1, 1) == "oracle_fallback"
    # second pass is pure cache hits
    info = d_batch.cache_info()
    assert d_batch.recommend_batch(shapes) == batch
    assert d_batch.cache_info()["hits"] == info["hits"] + len(shapes)
    # oracle mode batches through the vectorized cost-model sweep
    d_orc = SaraDispatcher()
    assert d_orc.recommend_batch(shapes) == \
        [SaraDispatcher().recommend(*s) for s in shapes]


def test_adaptnet_tpu_learns_tile_space():
    """Scaled-down training run on the (harder, 135-class) TPU tile space;
    the full-scale numbers live in benchmarks/bench_sara_tpu."""
    from repro.core.sara import train_adaptnet_tpu
    params, acc, geo = train_adaptnet_tpu(n_samples=40_000, epochs=8)
    assert acc >= 0.5
    assert geo <= 1.15
    d = SaraDispatcher(mode="adaptnet", adaptnet_params=params)
    cfg = d.recommend(1024, 1024, 1024)
    assert cfg in tcm.TILE_CONFIGS
