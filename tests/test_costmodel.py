"""Cost-model unit + property tests: the paper's Fig. 3 claims and the
structural invariants of the SCALE-Sim-equivalent closed forms."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st

from repro.core import costmodel as cm
from repro.core.hw import IS, OS, WS
from repro.core.rsa import SAGAR_INSTANCE, enumerate_configs


# ---------------------------------------------------------------------------
# paper Fig. 3 (motivating experiment): 256x64 @ 64x256
# ---------------------------------------------------------------------------

class TestFig3:
    M, K, N = 256, 64, 256

    def test_monolithic_reference(self):
        mono = cm.monolithic_cost(self.M, self.K, self.N, 128, 128, OS)
        assert float(mono.runtime) == 1784.0
        assert float(mono.sram_reads) == 65536.0

    def test_distributed_32x32_is_optimal_and_2x(self):
        """Paper: the 32x32 distributed config is the most performant,
        beating monolithic by about 2x."""
        mono = cm.monolithic_cost(self.M, self.K, self.N, 128, 128, OS)
        runtimes = {}
        for units, dim in [(4, 64), (16, 32), (64, 16), (256, 8), (1024, 4)]:
            d = cm.distributed_cost(self.M, self.K, self.N, dim, dim,
                                    units, OS)
            runtimes[dim] = float(d.runtime)
        assert min(runtimes, key=runtimes.get) == 32
        speedup = float(mono.runtime) / runtimes[32]
        assert 1.8 <= speedup <= 2.3          # paper: "about 2x"

    def test_distributed_32x32_4x_reads(self):
        """Paper: the 32x32 config performs about 4x more SRAM reads."""
        mono = cm.monolithic_cost(self.M, self.K, self.N, 128, 128, OS)
        d = cm.distributed_cost(self.M, self.K, self.N, 32, 32, 16, OS)
        assert float(d.sram_reads / mono.sram_reads) == pytest.approx(4.0)

    def test_rsa_preserves_monolithic_reads(self):
        """The RSA headline: distributed-level runtime at monolithic-level
        reads (unified SRAM + multicast collation)."""
        mono = cm.monolithic_cost(self.M, self.K, self.N, 128, 128, OS)
        rsa = cm.gemm_cost(self.M, self.K, self.N, 32, 32, 4, 4, OS,
                           system=cm.RSA)
        assert float(rsa.sram_reads) == float(mono.sram_reads)
        assert float(rsa.runtime) < float(mono.runtime)

    def test_rsa_beats_both_baselines(self):
        mono = cm.monolithic_cost(self.M, self.K, self.N, 128, 128, OS)
        dist = cm.distributed_cost(self.M, self.K, self.N, 32, 32, 16, OS)
        best_rsa = cm.oracle_runtime(SAGAR_INSTANCE,
                                     [self.M], [self.K], [self.N])[0]
        assert best_rsa <= float(dist.runtime)
        assert best_rsa < float(mono.runtime)


# ---------------------------------------------------------------------------
# property tests (hypothesis)
# ---------------------------------------------------------------------------

dims = st.integers(min_value=1, max_value=8192)


@settings(max_examples=60, deadline=None)
@given(M=dims, K=dims, N=dims)
def test_runtime_at_least_theoretical_min(M, K, N):
    cost = cm.sweep_configs(SAGAR_INSTANCE, [M], [K], [N])
    assert np.all(cost.runtime >= cost.theoretical_min_cycles - 1e-9)


@settings(max_examples=60, deadline=None)
@given(M=dims, K=dims, N=dims)
def test_reads_at_least_compulsory(M, K, N):
    cost = cm.sweep_configs(SAGAR_INSTANCE, [M], [K], [N])
    # every config must read each operand element at least once
    assert np.all(cost.sram_reads >= cost.theoretical_min_reads - 1e-9)


@settings(max_examples=40, deadline=None)
@given(M=dims, K=dims, N=dims)
def test_distributed_reads_dominate_rsa(M, K, N):
    rsa = cm.sweep_configs(SAGAR_INSTANCE, [M], [K], [N], system=cm.RSA)
    dist = cm.sweep_configs(SAGAR_INSTANCE, [M], [K], [N],
                            system=cm.DISTRIBUTED)
    assert np.all(dist.sram_reads >= rsa.sram_reads - 1e-9)


@settings(max_examples=40, deadline=None)
@given(M=dims, K=dims, N=dims, df=st.sampled_from([OS, WS, IS]))
def test_runtime_monotone_in_dims(M, K, N, df):
    base = cm.gemm_cost(M, K, N, 32, 32, 4, 4, df, system=cm.RSA)
    bigger = cm.gemm_cost(M + 64, K + 64, N + 64, 32, 32, 4, 4, df,
                          system=cm.RSA)
    assert float(bigger.runtime) >= float(base.runtime)
    assert float(bigger.sram_reads) >= float(base.sram_reads)


@settings(max_examples=30, deadline=None)
@given(M=dims, K=dims, N=dims)
def test_energy_positive_and_edp_consistent(M, K, N):
    cost = cm.sweep_configs(SAGAR_INSTANCE, [M], [K], [N])
    assert np.all(cost.energy_pj > 0)
    assert np.allclose(cost.edp, cost.energy_pj * cost.runtime)


def test_best_config_deterministic():
    M = np.array([100, 2000, 64])
    K = np.array([64, 512, 4096])
    N = np.array([256, 2000, 64])
    a = cm.best_config(SAGAR_INSTANCE, M, K, N)
    b = cm.best_config(SAGAR_INSTANCE, M, K, N)
    assert np.array_equal(a, b)


def test_oracle_no_worse_than_any_fixed_config():
    rng = np.random.default_rng(3)
    M, K, N = (rng.integers(1, 4096, 50) for _ in range(3))
    cost = cm.sweep_configs(SAGAR_INSTANCE, M, K, N)
    best = cm.oracle_runtime(SAGAR_INSTANCE, M, K, N)
    assert np.all(best <= cost.runtime.min(axis=-1) + 1e-9)
