"""Stateful property tests for the KV block pool + prefix-cache trie.

Speculative decoding made rollback-into-reserved-pages a new client of
the pool's sharing machinery, so the invariants stop being something
individual unit tests can cover path-by-path: any interleaving of
reserve / extend / share / ensure_writable / free / pin (cache insert) /
evict / defrag must preserve

  * ``KVBlockPool.check()``: per-table page uniqueness, refcounts that
    match the tables exactly, no negative pins, and free list ==
    the unreferenced AND unpinned block set;
  * landmark immobility: defrag never relocates a shared (refcount > 1)
    or pinned page — other tables and the cache index hold physical ids;
  * conservation: after every table is freed and the cache cleared, all
    blocks are back on the free list.

Two drivers generate the interleavings: a seeded random-walk driver that
always runs (CI has no extra deps), and a Hypothesis
``RuleBasedStateMachine`` that runs where ``hypothesis`` is installed —
same operations, but with shrinking when a counterexample is found.
"""

import random

import numpy as np
import pytest

from repro.serving.kv_pool import KVBlockPool, PoolError
from repro.serving.prefix_cache import PrefixCache

NUM_BLOCKS = 24
BLOCK_SIZE = 8


class PoolWorkout:
    """One random interleaving of pool + cache operations with the
    invariants asserted after every op.  Shared by the seeded driver and
    the Hypothesis machine (the machine calls the ops directly)."""

    def __init__(self, seed: int):
        self.rng = random.Random(seed)
        self.pool = KVBlockPool(NUM_BLOCKS, BLOCK_SIZE)
        self.cache = PrefixCache(self.pool)
        self.tokens = {}          # rid -> token array backing its pages
        self.next_rid = 0
        self.inserted = []        # token arrays the cache has indexed

    # -- operations (each safe to call in any state) ------------------------
    def op_alloc(self):
        rid = f"q{self.next_rid}"
        self.next_rid += 1
        n = self.rng.randint(1, 6 * BLOCK_SIZE)
        try:
            self.pool.alloc(rid, n)
        except PoolError:
            return                # expected OOM under pressure
        self.tokens[rid] = np.asarray(
            self.rng.choices(range(1, 500), k=n), np.int32)

    def op_extend(self):
        rid = self._live()
        if rid is None:
            return
        t = self.pool.table(rid)
        n = t.num_tokens + self.rng.randint(1, 2 * BLOCK_SIZE)
        try:
            self.pool.extend(rid, n)
        except PoolError:
            return
        extra = np.asarray(
            self.rng.choices(range(1, 500), k=n - len(self.tokens[rid])),
            np.int32)
        self.tokens[rid] = np.concatenate([self.tokens[rid], extra])

    def op_free(self):
        rid = self._live()
        if rid is None:
            return
        self.pool.free(rid)
        del self.tokens[rid]

    def op_share(self):
        """Map a live request's leading pages into a fresh table — the
        raw version of a prefix-cache hit."""
        donor = self._live()
        if donor is None:
            return
        blocks = self.pool.table(donor).blocks
        if not blocks:
            return
        k = self.rng.randint(1, len(blocks))
        rid = f"q{self.next_rid}"
        self.next_rid += 1
        self.pool.share(rid, blocks[:k])
        self.tokens[rid] = self.tokens[donor][:k * BLOCK_SIZE].copy()

    def op_cow(self):
        """ensure_writable on a random page — exclusive pages pass
        through, shared/pinned ones fork (spec decode's rollback write
        path does exactly this before rewinding into a page)."""
        rid = self._live()
        if rid is None:
            return
        blocks = self.pool.table(rid).blocks
        if not blocks:
            return
        try:
            self.pool.ensure_writable(
                rid, self.rng.randrange(len(blocks)))
        except PoolError:
            return                # no free block for the copy


    def op_insert(self):
        """Index a live request's fully-covered pages in the cache
        (pins them, like a completed prefill does)."""
        rid = self._live()
        if rid is None:
            return
        toks = self.tokens[rid]
        nfull = len(toks) // BLOCK_SIZE
        blocks = self.pool.table(rid).blocks[:nfull]
        if not blocks:
            return
        self.cache.insert(toks[:nfull * BLOCK_SIZE], blocks)
        self.inserted.append(toks[:nfull * BLOCK_SIZE].copy())

    def op_cache_hit(self):
        """Look a previously inserted prompt up and share the match into
        a fresh table — the admission path of a cache hit."""
        if not self.inserted:
            return
        toks = self.rng.choice(self.inserted)
        pages = self.cache.match(toks)
        if not pages:
            return                # evicted since insertion
        rid = f"q{self.next_rid}"
        self.next_rid += 1
        self.pool.share(rid, pages)
        self.tokens[rid] = np.asarray(toks[:len(pages) * BLOCK_SIZE],
                                      np.int32)

    def op_evict(self):
        self.cache.evict(self.rng.randint(1, 4))

    def op_defrag(self):
        """Defrag must keep every shared/pinned page exactly where other
        owners expect it (landmarks immovable)."""
        pool = self.pool
        landmarks = {b for b in range(NUM_BLOCKS)
                     if pool.pincount(b) > 0 or pool.refcount(b) > 1}
        moves = pool.defrag()
        moved = set(moves)
        assert not (landmarks & moved), \
            f"defrag moved landmark pages {sorted(landmarks & moved)}"

    OPS = ("alloc", "alloc", "extend", "extend", "free", "share", "cow",
           "cow", "insert", "cache_hit", "evict", "defrag")

    def step(self):
        getattr(self, f"op_{self.rng.choice(self.OPS)}")()
        self.pool.check()

    def teardown(self):
        for rid in list(self.tokens):
            self.pool.free(rid)
        self.cache.clear()
        self.pool.check()
        assert self.pool.num_free == NUM_BLOCKS, \
            f"leak: {NUM_BLOCKS - self.pool.num_free} blocks unreclaimed"

    def _live(self):
        live = sorted(self.tokens)
        return self.rng.choice(live) if live else None


@pytest.mark.parametrize("seed", range(8))
def test_random_interleaving_preserves_invariants(seed):
    w = PoolWorkout(seed)
    for _ in range(300):
        w.step()
    w.teardown()


# ---------------------------------------------------------------------------
# Hypothesis state machine: the same operation set, generatively driven
# with shrinking.  Skipped where hypothesis isn't installed.
# ---------------------------------------------------------------------------

try:
    from hypothesis import settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    class PoolMachine(RuleBasedStateMachine):
        @initialize(seed=st.integers(0, 2**32 - 1))
        def init_pool(self, seed):
            # Hypothesis drives WHICH op runs; the workout's internal rng
            # (seeded by a drawn value, so shrinkable) picks operands
            self.w = PoolWorkout(seed)

        @rule()
        def alloc(self):
            self.w.op_alloc()

        @rule()
        def extend(self):
            self.w.op_extend()

        @rule()
        def free(self):
            self.w.op_free()

        @rule()
        def share(self):
            self.w.op_share()

        @rule()
        def cow(self):
            self.w.op_cow()

        @rule()
        def insert(self):
            self.w.op_insert()

        @rule()
        def cache_hit(self):
            self.w.op_cache_hit()

        @rule()
        def evict(self):
            self.w.op_evict()

        @rule()
        def defrag(self):
            self.w.op_defrag()

        @invariant()
        def pool_invariants(self):
            if hasattr(self, "w"):
                self.w.pool.check()

        def teardown(self):
            if hasattr(self, "w"):
                self.w.teardown()

    PoolMachine.TestCase.settings = settings(
        max_examples=25, stateful_step_count=60, deadline=None)
    TestPoolMachine = PoolMachine.TestCase
else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_pool_state_machine():
        pass
