"""Crash-safe engine snapshot/restore acceptance tests.

Kill-and-resume is the contract: snapshot a mid-trace engine, build a
fresh engine from the same configs, restore, keep stepping — every
surviving request must finish with greedy tokens identical to the
uninterrupted run (temperature=0 decode has no sampling noise, so any
divergence is corrupted KV/scheduler state, not randomness).  The
restored prefix-cache trie must keep serving hits without re-prefill
(ROADMAP: prefix-cache persistence), and restore must refuse engines
whose shapes/configs cannot possibly hold the snapshot.
"""

import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.serving import EngineConfig, Request, ServingEngine

ARCH = "llama3.2-1b"


def _cfg():
    return get_arch(ARCH).reduced()


def _prompts(cfg, n, prompt_len, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
            for _ in range(n)]


_PAGED = dict(num_slots=2, max_len=31, block_size=8, temperature=0.0,
              kv_layout="paged", prefill_chunk=8, max_prefills_per_step=2)


def _reqs(cfg, n=4, gen=6, seed=3):
    return [Request(f"r{i}", p, gen)
            for i, p in enumerate(_prompts(cfg, n, 12, seed=seed))]


def _drain(eng):
    while eng.step():
        pass


# ---------------------------------------------------------------------------
# kill-and-resume greedy parity
# ---------------------------------------------------------------------------

def test_snapshot_restore_greedy_parity_paged(tmp_path):
    cfg = _cfg()
    baseline = ServingEngine(cfg, EngineConfig(**_PAGED)).run(_reqs(cfg))

    # run the same trace, "crash" after 4 steps, snapshot at the kill point
    victim = ServingEngine(cfg, EngineConfig(**_PAGED))
    for r in _reqs(cfg):
        victim.submit(r)
    for _ in range(4):
        victim.step()
    step = victim.snapshot(str(tmp_path))
    assert step == 4
    # mid-trace on purpose: some lanes decoding, some still queued
    assert victim.requests and any(r.slot >= 0
                                   for r in victim.requests.values())

    resumed = ServingEngine(cfg, EngineConfig(**_PAGED))
    assert resumed.restore(str(tmp_path)) == 4
    _drain(resumed)
    survivors = list(resumed.requests.values())
    assert survivors and all(r.outcome == "done" for r in survivors)
    for r in survivors:
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32), baseline[r.rid])
    assert resumed.pool.num_free == resumed.pool.num_blocks
    assert resumed.summary()["engine_restores"] == 1
    # lifecycle spans re-opened at restore close exactly once at retire
    assert resumed.req_spans.closed == len(survivors)


def test_snapshot_restore_greedy_parity_dense(tmp_path):
    cfg = _cfg()
    ecfg = dict(num_slots=2, max_len=24, temperature=0.0, kv_layout="dense",
                max_prefills_per_step=2)
    baseline = ServingEngine(cfg, EngineConfig(**ecfg)).run(
        _reqs(cfg, n=3, gen=5))

    victim = ServingEngine(cfg, EngineConfig(**ecfg))
    for r in _reqs(cfg, n=3, gen=5):
        victim.submit(r)
    for _ in range(3):
        victim.step()
    victim.snapshot(str(tmp_path))

    resumed = ServingEngine(cfg, EngineConfig(**ecfg))
    resumed.restore(str(tmp_path))
    _drain(resumed)
    for r in resumed.requests.values():
        assert r.outcome == "done"
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32), baseline[r.rid])


# ---------------------------------------------------------------------------
# prefix-cache persistence
# ---------------------------------------------------------------------------

def test_restored_prefix_cache_serves_hits_without_reprefill(tmp_path):
    cfg = _cfg()
    ecfg = dict(_PAGED, prefix_cache=True)
    shared = _prompts(cfg, 1, 16, seed=11)[0]

    donor_eng = ServingEngine(cfg, EngineConfig(**ecfg))
    donor_res = donor_eng.run([Request("donor", shared, 5)])
    assert donor_eng.prefix_cache.num_entries == 2      # 16 tok / 8 per page
    donor_eng.snapshot(str(tmp_path))

    resumed = ServingEngine(cfg, EngineConfig(**ecfg))
    resumed.restore(str(tmp_path))
    assert resumed.prefix_cache.num_entries == 2
    res = resumed.run([Request("again", shared, 5)])
    # the restored trie served the whole cached prefix: no KV rows were
    # re-prefilled for those pages and the lookup counted as a hit
    # (a whole-prompt hit still recomputes the final prompt token, hence 15)
    assert resumed.prefix_cache.hits >= 1
    assert resumed.metrics.cache_hit_tokens >= 15
    np.testing.assert_array_equal(res["again"], donor_res["donor"])


# ---------------------------------------------------------------------------
# auto-snapshot (EngineConfig.snapshot_dir / snapshot_every)
# ---------------------------------------------------------------------------

def test_auto_snapshot_kill_and_resume(tmp_path):
    cfg = _cfg()
    baseline = ServingEngine(cfg, EngineConfig(**_PAGED)).run(_reqs(cfg))

    auto = dict(_PAGED, snapshot_dir=str(tmp_path), snapshot_every=2)
    victim = ServingEngine(cfg, EngineConfig(**auto))
    for r in _reqs(cfg):
        victim.submit(r)
    for _ in range(5):                       # snapshots land at steps 2, 4
        victim.step()
    assert victim.summary()["engine_snapshots"] == 2
    del victim                               # the "crash"

    resumed = ServingEngine(cfg, EngineConfig(**auto))
    assert resumed.restore() == 4            # latest auto-snapshot
    _drain(resumed)
    for r in resumed.requests.values():
        assert r.outcome == "done"
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32), baseline[r.rid])


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_restore_rejects_non_fresh_engine(tmp_path):
    cfg = _cfg()
    eng = ServingEngine(cfg, EngineConfig(**_PAGED))
    for r in _reqs(cfg):
        eng.submit(r)
    eng.step()
    eng.snapshot(str(tmp_path))
    with pytest.raises(ValueError, match="fresh"):
        eng.restore(str(tmp_path))


def test_restore_rejects_config_mismatch(tmp_path):
    cfg = _cfg()
    eng = ServingEngine(cfg, EngineConfig(**_PAGED))
    for r in _reqs(cfg):
        eng.submit(r)
    eng.step()
    eng.snapshot(str(tmp_path))
    other = ServingEngine(cfg, EngineConfig(**dict(_PAGED, max_len=39)))
    with pytest.raises(ValueError, match="max_len"):
        other.restore(str(tmp_path))
    dense = ServingEngine(cfg, EngineConfig(num_slots=2, max_len=31,
                                            temperature=0.0,
                                            kv_layout="dense"))
    with pytest.raises(ValueError, match="kv_layout"):
        dense.restore(str(tmp_path))


def test_snapshot_requires_directory():
    cfg = _cfg()
    eng = ServingEngine(cfg, EngineConfig(**_PAGED))
    with pytest.raises(ValueError, match="directory"):
        eng.snapshot()
