"""SSM correctness: chunked closed forms == exact sequential recurrences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st

from repro.models.ssm import _ssd_chunked, _wkv_chunked


def _naive_wkv(r, k, v, logw, u, state0):
    """Definition-level sequential RWKV6 recurrence."""
    B, S, H, K = r.shape
    V = v.shape[-1]

    def per_t(h, t):
        rt, kt, vt, wt = t
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt,
                       h + u[None, :, :, None] * kv)
        h = jnp.exp(wt)[..., None] * h + kv
        return h, o

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, logw))
    h, o = jax.lax.scan(per_t, state0, xs)
    return jnp.moveaxis(o, 0, 1), h


def _naive_ssd(xh, Bm, Cm, loga, state0):
    def per_t(h, t):
        xt, bt, ct, at = t
        h = jnp.exp(at)[..., None, None] * h + \
            jnp.einsum("bhp,bn->bhpn", xt, bt)
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    xs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(Bm, 1, 0),
          jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(loga, 1, 0))
    h, y = jax.lax.scan(per_t, state0, xs)
    return jnp.moveaxis(y, 0, 1), h


@pytest.mark.parametrize("S", [7, 32, 65])
@pytest.mark.parametrize("lc", [8, 16, 64])
def test_wkv_chunked_equals_sequential(S, lc):
    B, H, K = 2, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, K))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) - 2.0)
    u = jax.random.normal(ks[4], (H, K)) * 0.2
    s0 = jax.random.normal(ks[5], (B, H, K, K)) * 0.1

    out_c, st_c = _wkv_chunked(r, k, v, logw, u, s0, lc)
    out_n, st_n = _naive_wkv(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_n),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_n),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S", [5, 33, 64])
@pytest.mark.parametrize("lc", [8, 32])
def test_ssd_chunked_equals_sequential(S, lc):
    B, H, P, N = 2, 4, 8, 6
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    Bm = jax.random.normal(ks[1], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    loga = -jnp.exp(jax.random.normal(ks[3], (B, S, H)) - 2.0)
    s0 = jax.random.normal(ks[4], (B, H, P, N)) * 0.1

    y_c, st_c = _ssd_chunked(xh, Bm, Cm, loga, s0, lc)
    y_n, st_n = _naive_ssd(xh, Bm, Cm, loga, s0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_n),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(2, 40), lc=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 100))
def test_wkv_chunk_size_invariance(S, lc, seed):
    B, H, K = 1, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.3
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, K))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) - 2.0)
    u = jnp.zeros((H, K))
    s0 = jnp.zeros((B, H, K, K))
    a, _ = _wkv_chunked(r, k, v, logw, u, s0, lc)
    b, _ = _wkv_chunked(r, k, v, logw, u, s0, 64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_rwkv_streaming_equals_batch():
    """Processing a sequence in two prefill chunks == one pass (state carry)."""
    from repro.configs.registry import get_arch
    from repro.models.ssm import init_rwkv_state, rwkv_block_apply
    cfg = get_arch("rwkv6-1.6b").reduced()
    key = jax.random.PRNGKey(0)
    from repro.models.ssm import init_rwkv_block
    params = init_rwkv_block(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    s0 = init_rwkv_state(cfg, 2, jnp.float32)
    full, _ = rwkv_block_apply(params, x, cfg, s0)
    a, s_mid = rwkv_block_apply(params, x[:, :11], cfg, s0)
    b, _ = rwkv_block_apply(params, x[:, 11:], cfg, s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([a, b], 1)),
                               np.asarray(full), rtol=2e-3, atol=2e-3)
