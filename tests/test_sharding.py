"""Sharding rules + true multi-device execution (subprocess with 8 virtual
devices — XLA device count must be set before jax imports, hence subprocess)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_arch
from repro.launch.mesh import make_host_mesh
from repro.models.api import build_model
from repro.parallel.sharding import batch_specs, param_specs


def _axis_sz(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= _axis_sz(mesh, a)
        return n
    return mesh.devices.shape[mesh.axis_names.index(ax)]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen2-moe-a2.7b",
                                  "rwkv6-1.6b", "zamba2-7b",
                                  "deepseek-v3-671b"])
def test_param_specs_divide_shapes(arch):
    """Every sharded dim divides its mesh axis (we never rely on GSPMD
    padding) — checked on the FULL configs against the production mesh
    geometry (16, 16) without touching device state."""
    cfg = get_arch(arch)
    model = build_model(cfg)
    params = model.init_abstract()

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), dtype=object)

    specs = param_specs(params, cfg, FakeMesh())
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    n_sharded = 0
    for (path, leaf), spec in zip(flat_p, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            sz = _axis_sz(FakeMesh, ax)
            assert dim % sz == 0, (path, leaf.shape, spec)
            if sz > 1:
                n_sharded += 1
    # the big matrices must actually be sharded
    assert n_sharded > 10


def test_batch_specs_b1_replicates():
    """long_500k has global_batch=1: indivisible batch dims replicate."""
    import jax.numpy as jnp

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), dtype=object)

    specs = batch_specs({"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32),
                         "big": jax.ShapeDtypeStruct((32, 8), jnp.int32)},
                        FakeMesh())
    assert tuple(specs["tokens"]) == (None, None)
    assert tuple(specs["big"])[0] == "data"


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.configs.registry import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_train_step, make_optimizer
    from repro.configs.shapes import input_specs, ShapeSpec
    from repro.parallel.sharding import batch_specs, to_named
    from repro.parallel.hints import use_mesh

    cfg = get_arch("llama3.2-1b").reduced().replace(
        num_heads=4, num_kv_heads=2, d_model=64, head_dim=16)
    results = {}
    for axes in [(1, 1), (4, 2), (2, 4), (8, 1)]:
        mesh = make_host_mesh(*axes)
        model, step, (pa, oa), (p_sh, o_sh) = build_train_step(cfg, mesh)
        params = jax.device_put(model.init(jax.random.PRNGKey(0)), p_sh)
        opt = make_optimizer(cfg)
        opt_state = jax.device_put(opt.init(params), o_sh)
        shape = ShapeSpec("t", 32, 8, "train")
        b_sh = to_named(batch_specs(input_specs(cfg, shape), mesh), mesh)
        toks = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (8, 33)).astype(np.int32)
        batch = jax.device_put({"tokens": toks}, b_sh)
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
        with mesh, use_mesh(mesh):
            _, _, metrics = jitted(params, opt_state, batch)
        results[str(axes)] = float(metrics["loss"])
    print("RESULT " + json.dumps(results))
""")


@pytest.mark.slow
def test_train_step_mesh_invariance():
    """The sharded train step computes the SAME loss on (1,1), (4,2), (2,4)
    and (8,1) meshes — the distribution layer is semantics-preserving."""
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    results = json.loads(line[len("RESULT "):])
    losses = list(results.values())
    assert len(losses) == 4
    np.testing.assert_allclose(losses, losses[0], rtol=2e-4)
