"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st

from repro.core.hw import IS, OS, WS
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# rsa_gemm
# ---------------------------------------------------------------------------

GEMM_SHAPES = [
    (128, 256, 128),      # exact blocks
    (256, 256, 256),
    (300, 520, 260),      # padding on every dim
    (64, 64, 64),         # smaller than one block
    (129, 257, 131),      # prime-ish
]


@pytest.mark.parametrize("mode", [OS, WS, IS], ids=["OS", "WS", "IS"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("shape", GEMM_SHAPES)
def test_rsa_gemm_matches_ref(mode, dtype, shape):
    M, K, N = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (M, K), jnp.float32).astype(dtype)
    b = jax.random.normal(k2, (K, N), jnp.float32).astype(dtype)
    out = ops.rsa_gemm(a, b, block_m=128, block_n=128, block_k=256,
                       mode=mode)
    gold = ref.rsa_gemm_ref(a, b)
    tol = 2e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("blocks", [(128, 128, 128), (256, 128, 512),
                                    (128, 256, 128)])
def test_rsa_gemm_block_configs(blocks):
    """Different SARA-recommended tilings compute the same function."""
    bm, bn, bk = blocks
    a = jax.random.normal(jax.random.PRNGKey(1), (384, 512), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (512, 384), jnp.float32)
    out = ops.rsa_gemm(a, b, block_m=bm, block_n=bn, block_k=bk, mode=OS)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.rsa_gemm_ref(a, b)),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(M=st.integers(8, 300), K=st.integers(8, 300), N=st.integers(8, 300),
       mode=st.sampled_from([OS, WS, IS]))
def test_rsa_gemm_property_shapes(M, K, N, mode):
    a = jnp.ones((M, K), jnp.float32)
    b = jnp.full((K, N), 0.5, jnp.float32)
    out = ops.rsa_gemm(a, b, block_m=128, block_n=128, block_k=128,
                       mode=mode)
    assert out.shape == (M, N)
    np.testing.assert_allclose(np.asarray(out), 0.5 * K, rtol=1e-5)


# ---------------------------------------------------------------------------
# adaptnetx
# ---------------------------------------------------------------------------

def _adaptnet_params(num_classes, seed=0):
    from repro.core.adaptnet import AdaptNetConfig, init_params
    return init_params(jax.random.PRNGKey(seed),
                       AdaptNetConfig(num_classes=num_classes))


@pytest.mark.parametrize("num_classes", [75, 108])
def test_adaptnetx_matches_ref(num_classes):
    p = _adaptnet_params(num_classes)
    for ids in ([1, 1, 1], [9999, 5000, 1], [123, 4567, 8910]):
        ids = jnp.asarray(ids, jnp.int32)
        out = ops.adaptnetx_recommend(ids, p)
        gold = ref.adaptnetx_ref(ids, p["emb_m"], p["emb_k"], p["emb_n"],
                                 p["w1"], p["b1"], p["w2"], p["b2"])
        np.testing.assert_allclose(np.asarray(out), np.asarray(gold),
                                   rtol=1e-5, atol=1e-5)


def test_adaptnetx_matches_host_adaptnet():
    """The hardware kernel computes exactly the software ADAPTNET."""
    from repro.core.adaptnet import logits_fn
    p = _adaptnet_params(108, seed=3)
    feats = jnp.array([[300, 4000, 77]], jnp.int32)
    sw = logits_fn(p, feats)[0]
    hw = ops.adaptnetx_recommend(feats[0], p)
    np.testing.assert_allclose(np.asarray(hw), np.asarray(sw),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# linear_attn
# ---------------------------------------------------------------------------

LA_SHAPES = [(2, 64, 16, 16), (4, 100, 16, 32), (1, 257, 32, 32)]


@pytest.mark.parametrize("shape", LA_SHAPES)
@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_linear_attn_matches_sequential_ref(shape, chunk):
    BH, S, K, V = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (BH, S, K)) * 0.5
    k = jax.random.normal(ks[1], (BH, S, K)) * 0.5
    v = jax.random.normal(ks[2], (BH, S, V))
    logw = -jnp.exp(jax.random.normal(ks[3], (BH, S, K)) * 0.5 - 3.0)
    u = jax.random.normal(ks[4], (BH, K)) * 0.1
    out = ops.linear_attn(r, k, v, logw, u, chunk=chunk)
    gold = ref.linear_attn_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold),
                               rtol=2e-4, atol=2e-4)


def test_linear_attn_chunk_invariance():
    BH, S, K, V = 2, 96, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    r = jax.random.normal(ks[0], (BH, S, K)) * 0.5
    k = jax.random.normal(ks[1], (BH, S, K)) * 0.5
    v = jax.random.normal(ks[2], (BH, S, V))
    logw = -jnp.exp(jax.random.normal(ks[3], (BH, S, K)) - 3.0)
    u = jnp.zeros((BH, K))
    a = ops.linear_attn(r, k, v, logw, u, chunk=16)
    b = ops.linear_attn(r, k, v, logw, u, chunk=48)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_linear_attn_no_decay_is_cumulative_attention():
    """With w=1 (logw=0) and u=0, o_t = r_t @ sum_{j<t} k_j v_j^T."""
    BH, S, K, V = 1, 40, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    r = jax.random.normal(ks[0], (BH, S, K))
    k = jax.random.normal(ks[1], (BH, S, K))
    v = jax.random.normal(ks[2], (BH, S, V))
    logw = jnp.zeros((BH, S, K))
    u = jnp.zeros((BH, K))
    out = ops.linear_attn(r, k, v, logw, u, chunk=16)
    kv = jnp.cumsum(jnp.einsum("bsk,bsv->bskv", k, v), axis=1)
    kv_prev = jnp.concatenate([jnp.zeros_like(kv[:, :1]), kv[:, :-1]], 1)
    gold = jnp.einsum("bsk,bskv->bsv", r, kv_prev)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold),
                               rtol=1e-4, atol=1e-4)
