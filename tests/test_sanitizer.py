"""KV-arena sanitizer acceptance tests.

- poison-on-free / unpoison-on-malloc: a freed page is NaN-filled in the
  bound arena, and re-allocation restores the fresh-arena (zero) state so
  masked whole-page kernel reads stay finite for live lanes
- generation tags: a block table snapshot taken before a free+realloc
  cycle trips ``assert_generations`` (use-after-free through a stale
  table) as ``SanitizerError``
- leak audit: surviving tables and pin/trie disagreements raise; a
  drained pool returns the totals the engine folds into ``summary()``
- engine: a clean sanitized run reports zero poison hits / generation
  faults / leaks, and an injected UAF (poisoning a page a live decode
  lane still reads) is trapped at the very next step, attributed to the
  victim lane, and contained — the victim fails, the engine keeps serving
"""

import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.serving import (EngineConfig, KVArena, KVBlockPool, Request,
                           SanitizerError, ServingEngine)

ARCH = "llama3.2-1b"


def _arena(num_blocks, bs):
    L, KVH, hd = 2, 1, 4
    base = np.ones((L, num_blocks + 1, bs, KVH, hd), np.float32)
    import jax.numpy as jnp
    return KVArena({"k": jnp.asarray(base), "v": jnp.asarray(base + 0.5)},
                   block_size=bs)


# ---------------------------------------------------------------------------
# pool: poison / generations / audit
# ---------------------------------------------------------------------------

def test_poison_on_free_unpoison_on_realloc():
    pool = KVBlockPool(4, 2, sanitize=True)
    arena = _arena(4, 2)
    pool.bind_arena(arena)
    t = pool.alloc("a", 2)
    bid = t.blocks[0]
    pool.free("a")
    assert pool.poison_fills == 1
    assert np.isnan(np.asarray(arena.leaves["k"])[:, bid]).all()
    # the trash page is never poisoned (masked lanes write there)
    assert np.isfinite(np.asarray(arena.leaves["k"])[:, pool.num_blocks]).all()
    # exhaust the pool so the poisoned page is re-handed-out
    pool.alloc("b", 8)
    assert (np.asarray(arena.leaves["k"])[:, bid] == 0).all()
    pool.free("b")


def test_sanitize_off_keeps_arena_untouched():
    pool = KVBlockPool(4, 2)
    arena = _arena(4, 2)
    pool.bind_arena(arena)
    pool.alloc("a", 2)
    pool.free("a")
    assert pool.poison_fills == 0
    assert np.isfinite(np.asarray(arena.leaves["k"])).all()


def test_generation_trap_on_stale_table():
    pool = KVBlockPool(8, 4, sanitize=True)
    pool.alloc("r1", 8)                       # 2 pages
    tab = pool.dense_block_table(["r1"], 4)
    gens = pool.table_generations(["r1"], 4)
    pool.assert_generations(["r1"], tab, gens)    # fresh: passes
    pool.free("r1")
    pool.alloc("r2", 32)                      # wraps: r1's pages re-used
    with pytest.raises(SanitizerError, match="use-after-free"):
        pool.assert_generations(["r1"], tab, gens)
    assert pool.generation_faults == 1
    # None lanes are skipped entirely
    pool.assert_generations([None], tab, gens)
    pool.free("r2")


def test_leak_audit_paths():
    pool = KVBlockPool(6, 2, sanitize=True)
    totals = pool.audit_leaks([])
    assert totals["kv_leaked_tables"] == 0 and totals["kv_leaked_refs"] == 0

    t = pool.alloc("a", 2)
    with pytest.raises(SanitizerError, match="never freed"):
        pool.audit_leaks([])
    bid = t.blocks[0]
    pool.pin(bid)
    pool.free("a")
    # pinned page survives the free; audit must be told who pinned it
    with pytest.raises(SanitizerError, match="pinned pages disagree"):
        pool.audit_leaks([])
    totals = pool.audit_leaks([bid])
    assert totals["kv_pinned_pages"] == 1
    pool.unpin(bid)
    assert pool.audit_leaks([])["kv_pinned_pages"] == 0


# ---------------------------------------------------------------------------
# engine: clean run + injected UAF
# ---------------------------------------------------------------------------

def _engine(**kw):
    cfg = get_arch(ARCH).reduced()
    ecfg = EngineConfig(num_slots=2, max_len=24, temperature=0.0, seed=0,
                        kv_layout="paged", sanitize=True, **kw)
    return ServingEngine(cfg, ecfg), cfg


def _requests(cfg, n, prompt_len, gen):
    rng = np.random.default_rng(0)
    return [Request(f"r{i}",
                    rng.integers(0, cfg.vocab_size, prompt_len)
                    .astype(np.int32), gen)
            for i in range(n)]


def test_engine_sanitized_run_is_clean():
    eng, cfg = _engine()
    outs = eng.run(_requests(cfg, 3, 12, 4))
    assert all(len(v) == 4 for v in outs.values())
    s = eng.summary()
    assert s["kv_sanitize_checks"] > 0
    assert s["kv_poison_hits"] == 0
    assert s["kv_generation_faults"] == 0
    assert s["kv_leaked_tables"] == 0 and s["kv_leaked_refs"] == 0
    assert s["kv_poison_fills"] > 0           # retirements poisoned pages
    assert eng.pool.num_free == eng.pool.num_blocks


def test_engine_traps_injected_uaf():
    eng, cfg = _engine()
    reqs = _requests(cfg, 2, 12, 6)
    for r in reqs:
        eng.submit(r)
    # step until a lane is decoding (prefill done, >= 1 token committed)
    for _ in range(8):
        assert eng.step()
        live = [r for r in eng.sched.active.values()
                if not r.prefilling and r.generated]
        if live:
            break
    assert live, "no decoding lane after 8 steps"
    victim = live[0]
    # inject the UAF: poison a page the lane's table still names, as if
    # it had been freed while referenced — the rows are inside kv_len,
    # so the very next decode streams NaN into this lane's logits.  The
    # sanitizer traps it AND attributes it to the lane, so the engine's
    # step error boundary fails only the victim and keeps serving.
    eng.arena.poison_page(eng.pool.table(victim.rid).blocks[0])
    assert eng.step()                        # contained, not crashed
    assert victim.outcome == "failed"
    assert victim.rid not in eng.pool.live_requests()
    assert int(eng.obs.counters.get("kv_poison_hits", 0)) >= 1
    assert int(eng.obs.counters.get("faults_contained", 0)) >= 1
    # the surviving request still completes its full budget
    while eng.step():
        pass
    other = [r for r in reqs if r is not victim][0]
    assert other.outcome == "done"
    assert len(other.generated) == 6
