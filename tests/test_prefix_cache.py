"""Cross-request prefix caching acceptance tests.

- pool: refcounted sharing (a shared page outlives its donor's free and
  reclaims on the last release), pin/unpin cache references, the
  copy-on-write gate diverging a writer without perturbing sibling
  reads, and defrag treating shared/pinned pages as immovable landmarks
  while content still follows every remapped table
- trie: longest-prefix match at page granularity, insert pinning only
  new spans, LRU leaf-first eviction that never touches a page a live
  table still references, clear() returning the pool to fully free
- kernels: shared-prefix (cascade) attention — XLA reference and Pallas
  interpret — equals plain paged attention over the concatenated
  prefix+suffix tables; softmax-state merge degenerates on empty sides
- scheduler: suffix-only reservation on a cache hit; over-capacity
  prompts rejected at submit with PoolError
- engine: exact greedy parity cache-on vs cache-off with COW exercised
  (whole-prompt hit resumes inside a shared page), cascade decode
  end-to-end, auto-defrag from the step loop
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.kernels import ops
from repro.kernels.ref import (merge_softmax_states, paged_attention_lse_ref,
                               shared_paged_attention_ref)
from repro.serving import EngineConfig, KVArena, KVBlockPool, Request, \
    ServingEngine
from repro.serving.kv_pool import PoolError
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import ContinuousScheduler

GQA_ARCH = "llama3.2-1b"


def _stamped_arena(num_blocks, bs):
    """Every row carries (page_id, row) so moves/copies are detectable."""
    L, KVH, hd = 2, 1, 4
    base = np.zeros((L, num_blocks + 1, bs, KVH, hd), np.float32)
    for b in range(num_blocks + 1):
        for r in range(bs):
            base[:, b, r] = b * 100 + r
    return {"k": jnp.asarray(base), "v": jnp.asarray(base + 0.5)}


# ---------------------------------------------------------------------------
# pool: refcounts, pins, copy-on-write, defrag landmarks
# ---------------------------------------------------------------------------

def test_pool_share_refcount_free_order():
    pool = KVBlockPool(num_blocks=6, block_size=4)
    a = pool.alloc("a", 8)                       # pages [0, 1]
    pool.share("b", a.blocks[:1])                # b maps page 0
    assert pool.refcount(a.blocks[0]) == 2
    assert pool.shared_pages == 1
    # donor frees first: only its exclusive page returns
    assert pool.free("a") == 1
    assert pool.num_free == 5
    pool.check()
    # last table reference reclaims the shared page
    assert pool.free("b") == 1
    assert pool.num_free == 6
    pool.check()


def test_pool_pin_outlives_tables_and_unpin_reclaims():
    pool = KVBlockPool(num_blocks=4, block_size=4)
    t = pool.alloc("a", 4)
    bid = t.blocks[0]
    pool.pin(bid)
    assert pool.free("a") == 0                   # pinned page stays held
    assert pool.num_free == 3
    pool.check()
    with pytest.raises(PoolError):
        pool.unpin(bid + 1)                      # never pinned
    assert pool.unpin(bid) is True               # last reference reclaims
    assert pool.num_free == 4
    pool.check()
    with pytest.raises(PoolError):
        pool.pin(bid)                            # cannot pin a free page


def test_pool_cow_diverges_writer_without_perturbing_sibling():
    pool = KVBlockPool(num_blocks=6, block_size=2)
    arena = KVArena(_stamped_arena(6, 2), block_size=2)
    pool.bind_arena(arena)
    a = pool.alloc("a", 4)                       # pages [0, 1]
    pool.share("b", a.blocks)
    before_a = np.asarray(arena.leaves["k"])[:, a.blocks].copy()

    new = pool.ensure_writable("b", 1)
    assert new != a.blocks[1]                    # b got a private copy
    assert pool.cow_copies == 1
    assert pool.table("b").blocks[0] == a.blocks[0]   # page 0 still shared
    # the copy starts as a bitwise clone of the source page
    np.testing.assert_array_equal(np.asarray(arena.leaves["k"])[:, new],
                                  np.asarray(arena.leaves["k"])[:, a.blocks[1]])
    # b mutates its copy; a's rows are untouched
    arena.leaves = {n: leaf.at[:, new].set(-1.0)
                    for n, leaf in arena.leaves.items()}
    np.testing.assert_array_equal(
        np.asarray(arena.leaves["k"])[:, a.blocks], before_a)
    pool.check()
    # exclusive unpinned pages pass through without copying
    assert pool.ensure_writable("b", 1) == new
    assert pool.cow_copies == 1


def test_pool_cow_oom_raises():
    pool = KVBlockPool(num_blocks=2, block_size=2)
    a = pool.alloc("a", 2)
    pool.share("b", a.blocks)
    pool.extend("b", 4)                          # pool now fully allocated
    with pytest.raises(PoolError):
        pool.ensure_writable("b", 0)             # shared, but no free page


def test_pool_defrag_shared_and_pinned_are_landmarks():
    pool = KVBlockPool(num_blocks=10, block_size=2)
    arena = KVArena(_stamped_arena(10, 2), block_size=2)
    pool.bind_arena(arena)
    a = pool.alloc("a", 4)                       # pages [0, 1]
    pool.alloc("f", 2)                           # page [2] (filler)
    pool.share("b", a.blocks[:1])                # page 0 shared (refs 2)
    pool.extend("b", 4)                          # + page 3
    c = pool.alloc("c", 2)                       # page 4
    pool.pin(c.blocks[0])
    shared_bid, pinned_bid = a.blocks[0], c.blocks[0]
    pool.free("f")                               # page 2 gap -> fragmentation

    def read(rid):
        return np.asarray(arena.leaves["k"])[:, pool.table(rid).blocks]

    before = {rid: read(rid) for rid in pool.live_requests()}
    assert pool.fragmentation() > 0.0
    moves = pool.defrag()
    pool.check()
    # shared and pinned pages kept their physical ids (other tables and
    # the cache index hold them by id); movable pages compacted around
    assert pool.table("a").blocks[0] == shared_bid
    assert pool.table("b").blocks[0] == shared_bid
    assert pool.table("c").blocks[0] == pinned_bid
    assert shared_bid not in moves and pinned_bid not in moves
    # every table still reads the same rows through its remapped blocks
    for rid in pool.live_requests():
        np.testing.assert_array_equal(read(rid), before[rid])


# ---------------------------------------------------------------------------
# trie: match / insert / LRU eviction / clear
# ---------------------------------------------------------------------------

def _cached_prompt(pool, cache, rid, tokens):
    """Donor lifecycle: alloc, 'prefill', index full pages, retire."""
    t = pool.alloc(rid, len(tokens))
    nfull = len(tokens) // pool.block_size
    cache.insert(tokens, t.blocks[:nfull])
    pool.free(rid)
    return t.blocks[:nfull]


def test_prefix_cache_match_insert_partial_pages():
    pool = KVBlockPool(num_blocks=8, block_size=4)
    cache = PrefixCache(pool)
    toks = np.arange(10, dtype=np.int32)         # 2 full pages + 2 spare
    pages = _cached_prompt(pool, cache, "d", toks)
    assert len(pages) == 2 and cache.inserted_pages == 2
    # full match, prefix match, first-page-only match, miss
    assert cache.match(toks) == pages
    assert cache.match(toks[:8]) == pages
    assert cache.match(np.concatenate([toks[:4],
                                       toks[:4] + 90])) == pages[:1]
    assert cache.match(toks + 50) == []
    assert cache.match(toks[:3]) == []           # shorter than one page
    # re-inserting the same span pins nothing new
    t2 = pool.alloc("d2", 8)
    assert cache.insert(toks[:8], t2.blocks) == 0
    pool.free("d2")
    assert cache.num_entries == 2
    cache.record_lookup(2)
    cache.record_lookup(0)
    assert cache.hits == 1 and cache.misses == 1 and cache.reused_pages == 2
    assert cache.stats()["prefix_cache_hit_rate"] == 0.5


def test_prefix_cache_lru_evicts_leaf_first_and_skips_referenced():
    pool = KVBlockPool(num_blocks=8, block_size=4)
    cache = PrefixCache(pool)
    chain = _cached_prompt(pool, cache, "d0",
                           np.arange(8, dtype=np.int32))      # 2-node chain
    solo = _cached_prompt(pool, cache, "d1",
                          np.arange(100, 104, dtype=np.int32))  # 1 leaf
    cache.match(np.arange(100, 104, dtype=np.int32))   # touch solo (MRU)
    free0 = pool.num_free
    # LRU leaf is the chain's tail; its parent only evicts after it
    assert cache.evict(2) == 2
    assert pool.num_free == free0 + 2
    assert cache.match(np.arange(8, dtype=np.int32)) == []
    assert cache.match(np.arange(100, 104, dtype=np.int32)) == solo
    # a page a live table references is not reclaimable
    pool.share("r", solo)
    assert cache.evict(1) == 0
    pool.free("r")
    assert cache.evict(1) == 1
    assert pool.num_free == pool.num_blocks
    pool.check()
    assert cache.evicted_pages == 4 - 1          # chain(2) + solo(1)


def test_prefix_cache_evict_exclude_protects_pages():
    pool = KVBlockPool(num_blocks=8, block_size=4)
    cache = PrefixCache(pool)
    chain = _cached_prompt(pool, cache, "d",
                           np.arange(12, dtype=np.int32))  # 3-node chain
    # excluding the head spares it even once eviction exposes it as a leaf
    assert cache.evict(3, exclude=chain[:1]) == 2
    assert cache.match(np.arange(12, dtype=np.int32)) == chain[:1]
    assert cache.evict(3) == 1
    assert pool.num_free == pool.num_blocks
    pool.check()


def test_prefix_cache_clear_returns_pool_to_free():
    pool = KVBlockPool(num_blocks=8, block_size=4)
    cache = PrefixCache(pool)
    _cached_prompt(pool, cache, "d0", np.arange(12, dtype=np.int32))
    _cached_prompt(pool, cache, "d1", np.arange(50, 58, dtype=np.int32))
    assert pool.num_free < pool.num_blocks
    assert cache.clear() == 5                    # 3 + 2 nodes
    assert cache.num_entries == 0
    assert pool.num_free == pool.num_blocks
    pool.check()


# ---------------------------------------------------------------------------
# kernels: cascade attention == plain paged attention over concat tables
# ---------------------------------------------------------------------------

def _cascade_case(seed=0):
    """3 lanes over one arena: lanes 0/1 share prefix pages [0, 1]
    (8 rows), lane 2 is a non-member; ragged unique suffixes."""
    rng = np.random.default_rng(seed)
    S, KVH, G, hd, bs, NB = 3, 2, 2, 8, 4, 8
    q = jnp.asarray(rng.standard_normal((S, KVH * G, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((NB, bs, KVH, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((NB, bs, KVH, hd)), jnp.float32)
    prefix_pages = jnp.asarray([0, 1], jnp.int32)
    prefix_lens = jnp.asarray([8, 8, 0], jnp.int32)
    utables = jnp.asarray([[2, 3], [4, 4], [5, 6]], jnp.int32)
    ulens = jnp.asarray([5, 3, 6], jnp.int32)
    full_tables = jnp.asarray([[0, 1, 2, 3], [0, 1, 4, 4], [5, 6, 6, 6]],
                              jnp.int32)
    full_lens = jnp.asarray([13, 11, 6], jnp.int32)
    return (q, k, v, utables, ulens, prefix_pages, prefix_lens,
            full_tables, full_lens)


def test_shared_prefix_ref_matches_concatenated_paged():
    """BITWISE, not allclose: the ref rebuilds one gap-free combined
    table per lane and runs a single masked softmax, so greedy decode
    over the cascade path must produce the exact floats the plain paged
    path does (the engine's shared-prefix greedy-parity proof leans on
    this)."""
    (q, k, v, ut, ul, pp, pl, ft, fl) = _cascade_case()
    o_full = ops.paged_attention(q, k, v, ft, fl, impl="xla")
    o_casc = shared_paged_attention_ref(q, k, v, ut, ul, pp, pl)
    np.testing.assert_array_equal(np.asarray(o_casc), np.asarray(o_full))


def test_shared_prefix_ref_bitwise_with_padded_tables():
    """Pad-width mismatch must not perturb the floats: widening the
    unique tables (and the prefix page list) with garbage page ids past
    the real lengths changes only masked lanes, so the output stays
    bit-identical to the unpadded call."""
    (q, k, v, ut, ul, pp, pl, ft, fl) = _cascade_case(seed=6)
    o_ref = shared_paged_attention_ref(q, k, v, ut, ul, pp, pl)
    ut_wide = jnp.concatenate(
        [ut, jnp.full((ut.shape[0], 3), 7, jnp.int32)], axis=1)
    pp_wide = jnp.concatenate([pp, jnp.asarray([7, 7], jnp.int32)])
    o_wide = shared_paged_attention_ref(q, k, v, ut_wide, ul, pp_wide, pl)
    np.testing.assert_array_equal(np.asarray(o_wide), np.asarray(o_ref))
    o_full = ops.paged_attention(q, k, v, ft, fl, impl="xla")
    np.testing.assert_array_equal(np.asarray(o_wide), np.asarray(o_full))


def test_shared_paged_attention_pallas_matches_xla():
    (q, k, v, ut, ul, pp, pl, ft, fl) = _cascade_case(seed=3)
    o_xla = ops.shared_paged_attention(q, k, v, ut, ul, pp, pl, impl="xla")
    o_pal = ops.shared_paged_attention(q, k, v, ut, ul, pp, pl,
                                       impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_xla),
                               rtol=1e-5, atol=1e-5)
    o_full = ops.paged_attention(q, k, v, ft, fl, impl="xla")
    np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_full),
                               rtol=1e-5, atol=1e-5)


def test_shared_paged_attention_all_empty_lane():
    """prefix 0 + unique 0 -> zero output (the merge's empty identity)."""
    (q, k, v, ut, _, pp, _, _, _) = _cascade_case(seed=4)
    zeros = jnp.zeros((3,), jnp.int32)
    o = ops.shared_paged_attention(q, k, v, ut, zeros, pp, zeros,
                                   impl="xla")
    assert np.allclose(np.asarray(o), 0.0)
    o_p = ops.shared_paged_attention(q, k, v, ut, zeros, pp, zeros,
                                     impl="pallas", interpret=True)
    assert np.allclose(np.asarray(o_p), 0.0)


def test_merge_softmax_states_empty_side_is_identity():
    rng = np.random.default_rng(2)
    S, H, hd = 2, 3, 4
    q = jnp.asarray(rng.standard_normal((S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((3, 4, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((3, 4, H, hd)), jnp.float32)
    t = jnp.asarray([[0, 1], [2, 2]], jnp.int32)
    lens = jnp.asarray([6, 4], jnp.int32)
    o, m, l = paged_attention_lse_ref(q, k, v, t, lens)
    empty_o = jnp.zeros_like(o, jnp.float32)
    empty_m = jnp.full_like(m, -1e30)
    empty_l = jnp.zeros_like(l)
    merged, _, _ = merge_softmax_states(o, m, l, empty_o, empty_m, empty_l)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(o),
                               rtol=1e-6, atol=1e-6)
    merged2, _, _ = merge_softmax_states(empty_o, empty_m, empty_l, o, m, l)
    np.testing.assert_allclose(np.asarray(merged2), np.asarray(o),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# scheduler: suffix reservation + submit rejection
# ---------------------------------------------------------------------------

def test_scheduler_submit_rejects_prompt_exceeding_pool():
    pool = KVBlockPool(num_blocks=2, block_size=4)
    sched = ContinuousScheduler(1, pool)
    with pytest.raises(PoolError, match="can never be admitted"):
        sched.submit(Request("big", np.zeros((40,), np.int32), 4))
    assert sched.pending() == 0                  # rejected, not queued


def test_scheduler_cache_hit_reserves_suffix_only():
    pool = KVBlockPool(num_blocks=8, block_size=4)
    cache = PrefixCache(pool)
    sched = ContinuousScheduler(2, pool, max_prefills_per_step=2,
                                reserve="incremental", prefill_chunk=4,
                                prefix_cache=cache)
    donor_prompt = np.arange(8, dtype=np.int32)
    pages = _cached_prompt(pool, cache, "donor", donor_prompt)
    free_before = pool.num_free                  # 6: two pages pinned

    prompt = np.concatenate([donor_prompt,
                             np.arange(90, 94, dtype=np.int32)])
    req = Request("hit", prompt.astype(np.int32), 4)
    sched.submit(req)
    plan = sched.plan()
    assert plan.prefills == [req]
    # shared pages head the table; only the suffix chunk was newly reserved
    table = pool.table("hit")
    assert table.blocks[:2] == pages
    assert pool.num_free == free_before - 1      # 1 new page, not 3
    assert req.prefill_pos == 8
    assert req.cached_prefix_tokens == 8 and req.cached_pages == 2
    assert cache.hits == 1 and cache.reused_pages == 2
    # a miss resets nothing it shouldn't
    miss = Request("miss", (prompt + 7).astype(np.int32), 4)
    sched.submit(miss)
    sched.plan()
    assert miss.cached_prefix_tokens == 0 and cache.misses == 1
    sched.retire(req)
    sched.retire(miss)
    cache.clear()
    pool.check()
    assert pool.num_free == pool.num_blocks


def test_scheduler_pressure_eviction_spares_matched_pages():
    """Regression: under pool pressure plan() evicts cache entries to
    admit the head, but the pages ``_match_prefix`` just returned are
    pin-only (no table references them yet) — once their trie
    descendants evicted they became evictable leaves themselves, and
    ``share()`` then raised ``cannot share dead page`` out of plan().
    The matched pages must survive the eviction pass."""
    pool = KVBlockPool(num_blocks=8, block_size=4)
    cache = PrefixCache(pool)
    donor_prompt = np.arange(16, dtype=np.int32)
    pages = _cached_prompt(pool, cache, "donor", donor_prompt)  # 4 pinned
    pool.alloc("live", 16)                       # 4 blocks held -> 0 free

    sched = ContinuousScheduler(2, pool, reserve="incremental",
                                prefill_chunk=4, prefix_cache=cache)
    prompt = np.concatenate([donor_prompt[:8],
                             np.arange(90, 102, dtype=np.int32)])
    req = Request("hit", prompt.astype(np.int32), 4)
    sched.submit(req)
    plan = sched.plan(0.0)                       # must not raise
    assert plan.prefills == [req]
    # the two matched pages head the table; only the unmatched chain
    # tail (donor page 3) was evicted to fund the suffix chunk
    assert pool.table("hit").blocks[:2] == pages[:2]
    assert req.cached_pages == 2 and cache.hits == 1
    assert cache.evicted_pages == 1
    pool.check()


def test_scheduler_pressure_falls_back_to_cache_miss():
    """When sparing the matched pages cannot free enough pool, admission
    gives the hit up and retries as a cache miss (the matched pages
    become reclaimable) instead of crashing or starving the head."""
    pool = KVBlockPool(num_blocks=8, block_size=4)
    cache = PrefixCache(pool)
    donor_prompt = np.arange(16, dtype=np.int32)
    _cached_prompt(pool, cache, "donor", donor_prompt)  # 4 pinned pages
    pool.alloc("live", 16)                       # 4 blocks held -> 0 free

    # chunk 12: the hit path needs 5 blocks (8 cached + one chunk) with
    # only the two unmatched tail pages evictable — short by one
    sched = ContinuousScheduler(2, pool, reserve="incremental",
                                prefill_chunk=12, prefix_cache=cache)
    prompt = np.concatenate([donor_prompt[:8],
                             np.arange(90, 102, dtype=np.int32)])
    req = Request("fb", prompt.astype(np.int32), 4)
    sched.submit(req)
    plan = sched.plan(0.0)                       # must not raise
    assert plan.prefills == [req]
    assert req.cached_pages == 0 and req.cached_prefix_tokens == 0
    assert len(pool.table("fb").blocks) == 3     # fresh first-chunk table
    assert cache.misses == 1 and cache.hits == 0
    assert cache.evicted_pages == 3              # tail pair + one matched
    pool.check()


def test_scheduler_submit_full_reserve_rejects_impossible_reservation():
    """reserve='full' reserves prompt + max_new + 1 at admission, so a
    request whose full reservation exceeds the pool livelocked at the
    queue head even though the prompt alone fits; the submit floor now
    follows the reservation policy."""
    pool = KVBlockPool(num_blocks=2, block_size=4)
    sched = ContinuousScheduler(1, pool, reserve="full")
    with pytest.raises(PoolError, match="can never be admitted"):
        sched.submit(Request("big", np.zeros((4,), np.int32), 16))
    assert sched.pending() == 0
    # the same request is admissible under incremental reservations
    # (it can stop at EOS well inside the pool)
    inc = ContinuousScheduler(1, pool, reserve="incremental")
    inc.submit(Request("ok", np.zeros((4,), np.int32), 16, eos_id=0))
    assert inc.pending() == 1


# ---------------------------------------------------------------------------
# engine: end-to-end parity, COW, cascade, auto-defrag
# ---------------------------------------------------------------------------

def _engine(cfg, **kw):
    base = dict(num_slots=2, max_len=23, block_size=8, temperature=0.0,
                kv_layout="paged", prefill_chunk=8)
    base.update(kw)
    return ServingEngine(cfg, EngineConfig(**base))


def _run(eng, prompts, gen=6):
    res = eng.run([Request(f"r{i}", p, gen) for i, p in enumerate(prompts)])
    if eng.prefix_cache is not None:
        eng.prefix_cache.clear()
    eng.pool.check()
    assert eng.pool.num_free == eng.pool.num_blocks
    return res


def test_engine_prefix_cache_parity_and_cow():
    """Three identical 16-token prompts (page-aligned): recipients match
    the whole prompt, resume at the minus-one offset INSIDE the last
    shared page — the write that must copy-on-write — and still emit
    exactly the cache-off greedy tokens."""
    cfg = get_arch(GQA_ARCH).reduced()
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    prompts = [prompt.copy() for _ in range(3)]
    # one slot serializes the requests, so each recipient admits after
    # the donor's insert; num_blocks leaves headroom for the COW copy
    kw = dict(num_slots=1, num_blocks=6)

    res_off = _run(_engine(cfg, **kw), prompts)
    eng = _engine(cfg, prefix_cache=True, **kw)
    res_on = _run(eng, prompts)
    for rid in res_off:
        np.testing.assert_array_equal(res_on[rid], res_off[rid])
    assert eng.prefix_cache.hits == 2            # both recipients hit
    assert eng.prefix_cache.reused_pages == 4
    assert eng.pool.cow_copies >= 2              # last shared page diverged
    assert eng.metrics.cache_hit_tokens == 2 * 15    # minus-one offset
    assert eng.metrics.prefill_flops_saved > 0
    s = eng.summary()
    assert s["prefix_cache_hit_rate"] > 0.5
    assert s["kv_cow_copies"] == eng.pool.cow_copies
    assert s["kv_shared_pages"] > 0
    # recipients wrote only their suffixes: fewer KV rows than cache-off
    off_rows = 3 * 16
    assert eng.metrics.prefill_kv_write_rows < off_rows


def test_engine_shared_prefix_decode_cascade():
    """Cascade decode takes over when >= 2 lanes' tables open with the
    same physical pages; generations complete and match the plain
    prefix-cache engine."""
    cfg = get_arch(GQA_ARCH).reduced()
    rng = np.random.default_rng(12)
    shared = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    # 4 requests on 2 slots: r0/r1 prefill concurrently (r1 misses — r0
    # inserts only at its final chunk), then r2/r3 both hit and decode
    # side by side through the donor's physical pages — the group the
    # cascade detector needs
    prompts = []
    for i in range(4):
        p = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
        p[:16] = shared
        prompts.append(p)

    eng_p = _engine(cfg, max_len=30, prefix_cache=True)
    res_p = _run(eng_p, prompts)
    eng_c = _engine(cfg, max_len=30, prefix_cache=True,
                    shared_prefix_decode=True)
    res_c = _run(eng_c, prompts)
    assert int(eng_c.obs.counters.get("shared_prefix_steps", 0)) > 0
    for rid in res_p:
        np.testing.assert_array_equal(res_c[rid], res_p[rid])


def test_engine_auto_defrag_from_step_loop():
    """A sub-zero threshold trips auto-defrag every step; the counter
    advances and generations are unchanged."""
    cfg = get_arch(GQA_ARCH).reduced()
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 14, 11)]
    res_base = _run(_engine(cfg), prompts)
    eng = _engine(cfg, defrag_threshold=-1.0)
    res = _run(eng, prompts)
    assert int(eng.obs.counters.get("kv_defrag_auto", 0)) > 0
    for rid in res_base:
        np.testing.assert_array_equal(res[rid], res_base[rid])


def test_engine_prefix_cache_requires_chunked_prefill():
    cfg = get_arch(GQA_ARCH).reduced()
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(cfg, EngineConfig(
            num_slots=2, max_len=23, kv_layout="paged", prefix_cache=True))
    with pytest.raises(ValueError, match="prefix_cache"):
        ServingEngine(cfg, EngineConfig(
            num_slots=2, max_len=23, kv_layout="paged", prefill_chunk=8,
            shared_prefix_decode=True))


def test_metrics_cache_hit_accounting():
    from repro.serving.metrics import ServingMetrics
    m = ServingMetrics()
    m.on_cache_hit(15, 2, flops_per_token=10.0)
    m.on_cache_hit(8, 1, flops_per_token=10.0)
    s = m.summary()
    assert s["cache_hit_tokens"] == 23
    assert s["cache_hit_pages"] == 3
    assert s["prefill_flops_saved"] == 230.0
