"""Trip-count-aware HLO analysis: validated against hand-computed programs
(subprocess — the virtual-device flag must precede jax import)."""

import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, json
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch.hlo_analysis import analyze_hlo

    mesh = jax.make_mesh((4, 2), ("data", "model"))

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y.sum()

    x = jax.ShapeDtypeStruct((256, 512), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
    with mesh:
        c = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P(None, "model")))).lower(x, w).compile()
    s = analyze_hlo(c.as_text())
    print("RESULT " + json.dumps({
        "flops": s.flops,
        "coll": s.collective_bytes_by_op,
        "hbm": s.hbm_bytes,
    }))
""")


def test_trip_weighted_flops_and_collectives():
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    r = json.loads(line[len("RESULT "):])
    # 5 loop trips x (256x512x512 MACs x2) / 8 devices
    expected = 5 * 2 * 256 * 512 * 512 / 8
    assert abs(r["flops"] - expected) / expected < 0.02
    # the loop all-gather: f32[64,512] per trip x 5
    assert abs(r["coll"]["all-gather"] - 5 * 64 * 512 * 4) < 1e-6
    assert r["hbm"] > expected / 512 * 2      # traffic is nonzero & scaled


def test_parser_handles_empty_module():
    from repro.launch.hlo_analysis import analyze_hlo
    s = analyze_hlo("")
    assert s.flops == 0.0 and s.collective_bytes == 0.0


def test_shape_bytes():
    from repro.launch.hlo_analysis import _type_bytes
    assert _type_bytes("bf16[64,256]{1,0}") == 64 * 256 * 2
    assert _type_bytes("(s32[], f32[8,8])") == 4 + 8 * 8 * 4
    assert _type_bytes("pred[16]") == 16


def test_roofline_terms_math():
    from repro.launch.hlo_analysis import HLOStats, roofline_from_stats
    st = HLOStats(flops=197e12, hbm_bytes=819e9,
                  collective_bytes_by_op={"all-reduce": 50e9})
    t = roofline_from_stats(st, chips=256, model_flops=197e12 * 256 * 0.5)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 1.0) < 1e-9
    assert t.roofline_fraction == 0.5
    assert t.dominant in ("compute", "memory", "collective")
