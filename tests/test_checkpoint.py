"""Checkpoint manager: roundtrip, atomicity, GC, async, elastic restore."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(5, t, metadata={"loss": 1.25})
    step, restored, meta = mgr.restore(jax.eval_shape(lambda: t))
    assert step == 5 and meta["loss"] == 1.25
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    assert mgr.steps() == [3, 4]        # GC keeps last 2


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree(7)
    mgr.save(9, t, blocking=False)
    mgr.wait()
    step, restored, _ = mgr.restore(jax.eval_shape(lambda: t))
    assert step == 9
    np.testing.assert_array_equal(np.asarray(t["a"]),
                                  np.asarray(restored["a"]))


def test_no_partial_checkpoint_visible(tmp_path):
    """A crash mid-save must not surface a corrupt step: temp dirs are
    invisible to steps()."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    tmp = mgr.dir / ".tmp_step_00000002_999"
    tmp.mkdir()
    (tmp / "data.npz").write_bytes(b"garbage")
    assert mgr.steps() == [1]
    assert mgr.latest_step() == 1


def test_restore_missing_key_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        mgr.restore({"a": jnp.zeros((2,)), "b": jnp.zeros((3,))})


def test_dtype_cast_on_restore(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"a": jnp.ones((4,), jnp.float32)})
    _, restored, _ = mgr.restore(
        {"a": jax.ShapeDtypeStruct((4,), jnp.bfloat16)})
    assert restored["a"].dtype == jnp.bfloat16


def test_elastic_restore_new_sharding(tmp_path):
    """Save unsharded, restore with an explicit (1,1)-mesh sharding — the
    single-device stand-in for the re-mesh path (multi-device covered by
    test_sharding.py subprocess)."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.sharding import to_named
    mgr = CheckpointManager(tmp_path)
    t = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    mgr.save(3, t)
    mesh = make_host_mesh(1, 1)
    sh = to_named({"w": P(None, None)}, mesh)
    step, restored, _ = mgr.restore(jax.eval_shape(lambda: t), shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))
